"""Family-parameterized serving conformance suite.

The spine of the "serve every registry family" claim: one representative
smoke config per architecture family runs through BOTH engines (fixed-slot
``Engine`` over dense per-slot cache windows, ``ContinuousEngine`` over the
paged pool) in every serving quant mode, and greedy decode must be
token-for-token identical across the engine/cache pair.  Full-context
forwards are NOT the reference for MoE-bearing families — expert capacity
``ceil(T·k/E·cf)`` depends on the static batch token count, so incremental
decode legitimately diverges from a monolithic forward; the serving
invariant is cross-engine identity, plus reference equality where the
math allows it (non-MoE families).

Recurrent regressions ride along:

* chunked prefill == chunk-1 prefill **bit-for-bit** for ssm/hybrid (the
  lifted fallback): the engines pass a per-row valid-length mask and the
  recurrent mixers advance state through a strictly sequential per-token
  scan of the exact chunk math, so the decode state after a C-token chunk
  equals C single-token steps — property-tested over prompt lengths and
  chunk sizes, including the raw cache arrays.
* staggered prefill-join and preemption + bit-identical resume on
  recurrent state (ssm white-box via ``_preempt`` — an ssm lane holds no
  pages, so page pressure never evicts it organically; hybrid organically
  under a tight pool).
* shared-prefix guards: the one remaining family exclusion must name the
  exact blocking feature in its error.

Fast lane runs one SSM and one MoE representative at native/int4_packed;
the full (family x mode) matrix and the tuned/mixed columns are slow-lane.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, integers, sampled_from
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import ContinuousEngine, Engine, ServeConfig

# one representative smoke config per serving-relevant family axis
# (h2o rides along for the sliding-window attention variant of dense —
# its ring cache is a distinct serving code path)
FAMILY_ARCHS = (
    "qwen1.5-110b",           # dense
    "h2o-danube-3-4b",        # dense + sliding window
    "moonshot-v1-16b-a3b",    # moe
    "xlstm-1.3b",             # ssm
    "jamba-v0.1-52b",         # hybrid (mamba + attn + moe)
    "whisper-large-v3",       # encdec decoder
    "llava-next-mistral-7b",  # vlm
)
FAST_ARCHS = ("xlstm-1.3b", "moonshot-v1-16b-a3b")
MODES = ("native", "int4_packed", "dsp_tuned", "dsp_mixed")
FAST_MODES = ("native", "int4_packed")
# shrunken sensitivity pass for the dsp_mixed column (the eager probe
# forwards dominate; two widths and a few calib tokens pin the plumbing)
MIXED_KW = dict(width_candidates=((4, 4), (8, 8)), calib_tokens=8)

MAX_LEN = 32
PROMPT = list(range(5, 14))
N_NEW = 6


@functools.lru_cache(maxsize=None)
def _model(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


@functools.lru_cache(maxsize=None)
def _mixed_allocation(arch):
    """One sensitivity pass per arch, shared by both engines' builds."""
    from repro.tuning import allocate_mixed_plans, measure_layer_sensitivity

    cfg, params = _model(arch)
    cfg_q = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, mode="dsp_tuned")
    )
    sens = measure_layer_sensitivity(
        params, cfg_q, widths=MIXED_KW["width_candidates"],
        n_calib_tokens=MIXED_KW["calib_tokens"],
    )
    return allocate_mixed_plans(sens, widths=MIXED_KW["width_candidates"])


def _engines(arch, quant, chunk=4, slots=2, **kw):
    cfg, params = _model(arch)
    base = dict(n_slots=slots, max_len=MAX_LEN, prefill_chunk=chunk,
                quant_mode=quant, **kw)
    if quant == "dsp_mixed":
        base.update(MIXED_KW)
        mixed = {"mixed_allocation": _mixed_allocation(arch)}
    else:
        mixed = {}
    fifo = Engine(cfg, params, ServeConfig(**base), **mixed)
    cont = ContinuousEngine(
        cfg, params, ServeConfig(page_size=8, **base), **mixed
    )
    return fifo, cont


def _gen_one(eng, prompt, max_new):
    """Single-prompt generate on a possibly reused (lru-cached) engine:
    outputs are keyed by request id, which advances across reuses, so
    ``[0]`` only works on a fresh engine."""
    (toks,) = eng.generate([list(prompt)], max_new=max_new).values()
    return toks


def _matrix_params():
    out = []
    for arch in FAMILY_ARCHS:
        for mode in MODES:
            fast = arch in FAST_ARCHS and mode in FAST_MODES
            marks = () if fast else (pytest.mark.slow,)
            out.append(pytest.param(arch, mode, marks=marks,
                                    id=f"{arch}-{mode}"))
    return out


@pytest.mark.parametrize("arch,quant", _matrix_params())
def test_cross_engine_token_identity(arch, quant):
    """Every (family, quant mode): greedy decode through the dense-cache
    FIFO engine equals the paged continuous engine token-for-token."""
    fifo, cont = _engines(arch, quant)
    a = fifo.generate([list(PROMPT)], max_new=N_NEW)[0]
    b = cont.generate([list(PROMPT)], max_new=N_NEW)[0]
    assert len(a) == N_NEW
    assert a == b, f"{arch}/{quant}: fifo {a} != continuous {b}"


@pytest.mark.parametrize(
    "arch", ["qwen1.5-110b", "h2o-danube-3-4b", "xlstm-1.3b",
             "llava-next-mistral-7b"]
)
@pytest.mark.slow
def test_engines_match_full_context_reference(arch):
    """Non-MoE decoder-only families: both engines also equal the greedy
    full-context forward.  MoE capacity is batch-shape-dependent and
    whisper's decoder serves with chunk-local cross-attention (no encoder
    features in token-only serving, so ``kv_x=None`` degrades xattn to
    uncached non-causal self-attention over the current chunk — a
    monolithic forward attends over the whole sequence instead), so those
    families are pinned by cross-engine identity only."""
    cfg, params = _model(arch)
    seq, want = list(PROMPT), []
    for _ in range(N_NEW):
        logits, _, _ = T.forward(params, cfg, jnp.asarray([seq], jnp.int32))
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        want.append(nxt)
        seq.append(nxt)
    fifo, cont = _engines(arch, "native")
    assert fifo.generate([list(PROMPT)], max_new=N_NEW)[0] == want
    assert cont.generate([list(PROMPT)], max_new=N_NEW)[0] == want


def test_swa_ring_wraparound_cross_engine():
    """Sliding-window prompts longer than the window: the paged ring
    (slot = pos % window) must match the dense per-slot ring."""
    cfg, params = _model("h2o-danube-3-4b")
    assert cfg.sliding_window and cfg.sliding_window < 64
    prompt = list(range(5, 5 + cfg.sliding_window + 8))  # wraps the ring
    base = dict(n_slots=2, max_len=64, prefill_chunk=4, quant_mode="native")
    a = Engine(cfg, params, ServeConfig(**base)).generate(
        [list(prompt)], max_new=6)[0]
    b = ContinuousEngine(cfg, params, ServeConfig(page_size=8, **base)
                         ).generate([list(prompt)], max_new=6)[0]
    assert a == b


# ---- chunked prefill == chunk-1 prefill (the lifted fallback) -----------


@functools.lru_cache(maxsize=None)
def _chunk_engine(arch, chunk):
    cfg, params = _model(arch)
    return Engine(cfg, params, ServeConfig(
        n_slots=2, max_len=MAX_LEN, prefill_chunk=chunk, quant_mode="native"
    ))


def _prompt_from_seed(cfg, seed, length):
    rng = np.random.default_rng(seed)
    return [int(v) for v in rng.integers(2, cfg.vocab_size, size=length)]


@pytest.mark.parametrize("arch,state_atol", [
    ("xlstm-1.3b", 0.0),        # bitwise, even through XLA fusion
    ("jamba-v0.1-52b", 1e-5),   # ulp-level fusion drift (see docstring)
])
def test_chunked_prefill_matches_chunk1_state(arch, state_atol):
    """ssm/hybrid fixed-case regression: chunked prefill emits the same
    greedy tokens as chunk-1 prefill, and the recurrent decode state
    matches — bitwise for ssm; for hybrid within a few ulp, because XLA
    fuses the l=C and l=1 forward programs differently around mamba's
    exp/softplus chains (the mixer math itself is bit-exact per chunk
    size — ``mamba()`` called standalone matches bitwise — so the
    tolerance covers compiled-program fusion only, not the algorithm)."""
    cfg, _ = _model(arch)
    prompt = _prompt_from_seed(cfg, 7, 13)
    ref_eng = _chunk_engine(arch, 1)
    ref = _gen_one(ref_eng, prompt, 4)
    ref_cache = jax.tree.map(np.asarray, ref_eng.cache)
    for chunk in (4, 7, 16):
        eng = _chunk_engine(arch, chunk)
        got = _gen_one(eng, prompt, 4)
        assert got == ref, f"chunk={chunk}: {got} != {ref}"
        got_cache = jax.tree.map(np.asarray, eng.cache)
        flat_ref = jax.tree_util.tree_flatten_with_path(ref_cache)[0]
        flat_got = jax.tree.leaves(got_cache)
        for (path, r), g in zip(flat_ref, flat_got):
            # the dense attention window is max_len + chunk - 1 wide, so
            # KV leaves gain chunk-1 trailing slots — compare the common
            # position prefix (prompt + decode all land below max_len here)
            if r.shape != g.shape:
                (ax,) = [i for i, (a, b) in
                         enumerate(zip(r.shape, g.shape)) if a != b]
                n = min(r.shape[ax], g.shape[ax])
                r = np.take(r, np.arange(n), axis=ax)
                g = np.take(g, np.arange(n), axis=ax)
            ok = (np.array_equal(r, g) if state_atol == 0.0
                  else np.allclose(r, g, rtol=0, atol=state_atol))
            assert ok, (
                f"chunk={chunk}: decode state differs at "
                f"{jax.tree_util.keystr(path)}"
            )


@pytest.mark.slow
@given(arch=sampled_from(["xlstm-1.3b", "jamba-v0.1-52b"]),
       length=integers(2, 24),
       chunk=sampled_from([2, 3, 4, 5, 8, 16]),
       seed=integers(0, 2**31))
def test_chunked_prefill_matches_chunk1_property(arch, length, chunk, seed):
    """Property form over prompt length x chunk size x content: the
    recurrent-state chunking invariant holds for arbitrary prompts, not a
    blessed case (engines are cached per chunk size, so each case is two
    generate calls, not two rebuilds)."""
    cfg, _ = _model(arch)
    prompt = _prompt_from_seed(cfg, seed, length)
    ref = _gen_one(_chunk_engine(arch, 1), prompt, 3)
    got = _gen_one(_chunk_engine(arch, chunk), prompt, 3)
    assert got == ref


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "jamba-v0.1-52b"])
def test_continuous_chunked_prefill_matches_chunk1(arch):
    """The continuous engine honors the same invariant (its prefill path
    masks and merges differently from the FIFO engine's)."""
    cfg, params = _model(arch)
    prompt = _prompt_from_seed(cfg, 11, 13)
    outs = {}
    for chunk in (1, 4, 16):
        eng = ContinuousEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=MAX_LEN, prefill_chunk=chunk, page_size=8,
            quant_mode="native",
        ))
        outs[chunk] = eng.generate([list(prompt)], max_new=4)[0]
    assert outs[4] == outs[1] and outs[16] == outs[1], outs


# ---- recurrent lifecycle regressions ------------------------------------


def test_staggered_prefill_join_ssm():
    """A request admitted while another lane is mid-decode must not
    perturb either stream: per-row valid masking keeps a masked lane's
    recurrent state bit-unchanged through the joiner's prefill chunks."""
    cfg, params = _model("xlstm-1.3b")
    pa = _prompt_from_seed(cfg, 21, 11)
    pb = _prompt_from_seed(cfg, 22, 7)

    def fresh():
        return ContinuousEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=MAX_LEN, prefill_chunk=4, page_size=8,
            quant_mode="native",
        ))

    solo_a = fresh().generate([list(pa)], max_new=8)[0]
    solo_b = fresh().generate([list(pb)], max_new=8)[0]

    eng = fresh()
    ra = eng.submit(list(pa), max_new=8)
    for _ in range(3):
        eng.step()  # lane A is mid-decode when B arrives
    rb = eng.submit(list(pb), max_new=8)
    for _ in range(30):
        eng.step()
        if all(r.done for r in eng.scheduler.requests.values()):
            break
    assert eng.outputs[ra] == solo_a
    assert eng.outputs[rb] == solo_b


def test_preemption_resumes_recurrent_state_ssm():
    """ssm lanes hold zero pages, so page pressure never preempts them
    organically — evict one white-box and require the bit-identical
    resume that re-prefilling prompt+emitted guarantees through the
    sequential-state invariant (admission resets the lane's state)."""
    cfg, params = _model("xlstm-1.3b")
    prompts = [_prompt_from_seed(cfg, 31, 9), _prompt_from_seed(cfg, 32, 6)]

    def fresh():
        return ContinuousEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=MAX_LEN, prefill_chunk=4, page_size=8,
            quant_mode="native",
        ))

    calm = fresh().generate([list(p) for p in prompts], max_new=8)

    eng = fresh()
    rids = [eng.submit(list(p), max_new=8) for p in prompts]
    for _ in range(2):
        eng.step()
    victim = eng._youngest_lane()
    assert victim is not None
    eng._preempt(victim)
    for _ in range(40):
        eng.step()
        if all(r.done for r in eng.scheduler.requests.values()):
            break
    got = {r: eng.outputs[r] for r in rids}
    assert got == calm


@pytest.mark.slow
def test_preemption_resumes_recurrent_state_hybrid():
    """Hybrid lanes DO hold attention pages: a tight pool preempts
    organically, and the resumed stream must replay exactly — both the
    paged attention state and the re-prefilled mamba state."""
    cfg, params = _model("jamba-v0.1-52b")
    prompts = [_prompt_from_seed(cfg, 41, 12), _prompt_from_seed(cfg, 42, 9)]

    def run(n_pages, **kw):
        eng = ContinuousEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=MAX_LEN, prefill_chunk=4, page_size=8,
            quant_mode="native", n_pages=n_pages, **kw,
        ))
        out = eng.generate([list(p) for p in prompts], max_new=8)
        return eng, out

    _, calm = run(16)
    tight_eng, got = run(4, watermark_pages=0)
    assert tight_eng.stats()["preempted"] >= 1, "pool was not tight enough"
    assert got == calm
    tight_eng.alloc.check()


# ---- shared-prefix guards name the blocking feature (satellite c) -------


@pytest.mark.parametrize("arch,needle", [
    ("xlstm-1.3b", "recurrent state"),
    ("jamba-v0.1-52b", "mamba recurrent state"),
    ("h2o-danube-3-4b", "sliding_window"),
])
def test_shared_prefix_guard_names_blocking_feature(arch, needle):
    """Families are no longer rejected at engine construction; the one
    remaining exclusion (prefix sharing) must say exactly WHY."""
    cfg, params = _model(arch)
    eng = ContinuousEngine(cfg, params, ServeConfig(
        n_slots=2, max_len=MAX_LEN, prefill_chunk=4, page_size=8,
    ))
    with pytest.raises(ValueError) as exc:
        eng.register_shared_prefix([2, 3, 4])
    msg = str(exc.value)
    assert cfg.name in msg and "blocking feature" in msg and needle in msg


def test_continuous_engine_accepts_every_registry_family():
    """The engine.py:676 rejection is gone: construction succeeds for
    every conformance family (decode correctness is pinned above)."""
    for arch in FAMILY_ARCHS:
        cfg, params = _model(arch)
        eng = ContinuousEngine(cfg, params, ServeConfig(
            n_slots=2, max_len=MAX_LEN, prefill_chunk=4, page_size=8,
        ))
        assert eng.cfg.family == cfg.family
