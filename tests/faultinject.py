"""Fault-injection harness for the serving engines (helper module — must
register ZERO tests; ``test_collection_sanity`` enforces it).

Drives adversarial serving scenarios against either engine without
wall-clock sleeps: bursts that outrun capacity, page exhaustion,
deadline expiry forced by rewriting a request's ``deadline_at`` (the
scheduler's own shedding path then fires deterministically), and
tier-swap storms through the governor.  Tests compose these into the
burst → degrade → recover → verify scenarios in ``test_faultinject.py``
and ``test_governor.py``.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "burst",
    "drain",
    "force_expire",
    "run_steps",
    "step_until",
]


def burst(engine, n, rng=None, prompt_len=(4, 8), max_new=4,
          deadline_ms=None) -> list[int]:
    """Submit ``n`` requests at once without stepping (``admit=False``) —
    the queue depth the governor and the deadline machinery see is the
    whole burst.  Returns the rids in submission order."""
    rng = np.random.default_rng(0) if rng is None else rng
    lo, hi = prompt_len
    rids = []
    for _ in range(n):
        prompt = list(rng.integers(2, engine.cfg.vocab_size,
                                   size=int(rng.integers(lo, hi))))
        rids.append(engine.submit(prompt, max_new=max_new, admit=False,
                                  deadline_ms=deadline_ms))
    return rids


def drain(engine, max_steps=500) -> int:
    """Step until the engine is idle; returns the steps taken.  Raises if
    the engine fails to drain — a hung drain is itself the bug class this
    harness exists to catch (e.g. shed requests never freeing lanes)."""
    for steps in range(max_steps):
        if not (engine.active.any() or engine.scheduler.n_queued):
            return steps
        engine.step()
    raise AssertionError(
        f"engine failed to drain within {max_steps} steps: "
        f"{int(engine.active.sum())} active, "
        f"{engine.scheduler.n_queued} queued"
    )


def run_steps(engine, n) -> None:
    """Step exactly ``n`` times regardless of idleness (the governor
    observes every step, so calm observation windows need idle steps)."""
    for _ in range(n):
        engine.step()


def step_until(engine, predicate, max_steps=500) -> int:
    """Step until ``predicate(engine)`` holds; returns the steps taken."""
    for steps in range(max_steps):
        if predicate(engine):
            return steps
        engine.step()
    raise AssertionError(f"predicate never held within {max_steps} steps")


def force_expire(engine, rids) -> None:
    """Inject deadline expiry: backdate each request's ``deadline_at`` so
    the scheduler's next ``expired()`` scan sheds it — no sleeping, and
    the shedding path under test is the production one."""
    sched = engine.scheduler
    past = sched._clock() - 1.0
    for rid in rids:
        req = sched.requests[rid]
        if req.done:
            raise AssertionError(f"request {rid} already finished — "
                                 "cannot inject expiry")
        req.deadline_at = past
        sched._deadlined.add(rid)
