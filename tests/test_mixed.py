"""Mixed-precision serving: sensitivity measurement, the greedy width
allocator, checkpoint round-trips of per-layer plan maps, and the
``dsp_mixed`` engine mode (budget-0 equivalence with the uniform exact
plan, end-to-end serving with genuinely mixed widths)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.packed_params import (
    is_dsp_tuned_leaf,
    iter_packable_weights,
    quantize_for_serving,
)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import Engine, ServeConfig
from repro.tuning import (
    LayerSensitivity,
    allocate_mixed_plans,
    measure_layer_sensitivity,
    mixed_precision_plan,
    select_plan,
    suggest_budget,
)

# A deliberately tiny model: the sensitivity pass runs one eager forward
# per (layer, width) probe, so test volume scales with model size.  All
# projections clear MIN_DIM (n_kv_heads=2 keeps wk/wv at 32 columns).
CFG = ModelConfig(
    name="mixed-smoke", family="dense", n_layers=1, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
)
PARAMS = T.init_params(jax.random.PRNGKey(0), CFG)
CFG_Q = dataclasses.replace(
    CFG, quant=dataclasses.replace(CFG.quant, mode="dsp_tuned")
)
# two-candidate ladder keeps probe counts test-sized; (8, 8) is the
# reference, (4, 4) the demotion target
WIDTHS = ((4, 4), (8, 8))
CALIB = dict(widths=WIDTHS, n_calib_tokens=8, calib_batch=1)


@pytest.fixture(scope="module")
def sensitivities():
    return measure_layer_sensitivity(PARAMS, CFG_Q, **CALIB)


# ---- sensitivity measurement ---------------------------------------------


class TestSensitivity:
    def test_covers_every_packable_path(self, sensitivities):
        assert {s.path for s in sensitivities} == {
            p for p, _ in iter_packable_weights(PARAMS)
        }
        assert all(set(s.errors) == set(WIDTHS) for s in sensitivities)

    def test_narrower_widths_hurt_more(self, sensitivities):
        """In aggregate, 4-bit quantization of a layer must damage the
        logits at least as much as 8-bit (per-layer inversions would be
        measurement noise; the sum is the signal the allocator uses)."""
        narrow = sum(s.errors[(4, 4)] for s in sensitivities)
        wide = sum(s.errors[(8, 8)] for s in sensitivities)
        assert narrow > wide >= 0.0

    def test_deterministic_per_seed(self, sensitivities):
        again = measure_layer_sensitivity(PARAMS, CFG_Q, **CALIB)
        assert [s.path for s in again] == [s.path for s in sensitivities]
        for a, b in zip(again, sensitivities):
            assert a.errors == b.errors and a.n_values == b.n_values

    def test_metric_validation(self):
        with pytest.raises(ValueError, match="metric"):
            measure_layer_sensitivity(PARAMS, CFG_Q, metric="cosine", **CALIB)


# ---- the greedy allocator -------------------------------------------------


def _fake_sens(errs: dict[str, dict]) -> list[LayerSensitivity]:
    return [
        LayerSensitivity(path, n_values=1024, errors=e)
        for path, e in errs.items()
    ]


class TestAllocator:
    def test_budget_zero_is_uniform_base(self, sensitivities):
        alloc = allocate_mixed_plans(sensitivities, mixed_budget=0.0,
                                     widths=WIDTHS)
        assert set(alloc.assignments.values()) == {(8, 8)}
        assert alloc.predicted_error == 0.0
        assert alloc.cost == alloc.base_cost

    def test_generous_budget_demotes_everything(self, sensitivities):
        alloc = allocate_mixed_plans(sensitivities, mixed_budget=1e9,
                                     widths=WIDTHS)
        assert set(alloc.assignments.values()) == {(4, 4)}
        assert alloc.cost < alloc.base_cost

    def test_tolerant_layers_demoted_first(self):
        """With one tolerant and one sensitive layer and a budget that only
        fits the tolerant demotion, the allocator must pick it."""
        sens = _fake_sens({
            "/tolerant/w": {(4, 4): 0.011, (8, 8): 0.01},
            "/sensitive/w": {(4, 4): 0.51, (8, 8): 0.01},
        })
        alloc = allocate_mixed_plans(sens, mixed_budget=0.1, widths=WIDTHS)
        assert alloc.assignments == {
            "/tolerant/w": (4, 4), "/sensitive/w": (8, 8),
        }
        assert alloc.distinct_widths == 2
        assert 0 < alloc.predicted_error <= 0.1

    def test_deterministic_under_fixed_seed(self, sensitivities):
        budget = suggest_budget(sensitivities, widths=WIDTHS)
        a = allocate_mixed_plans(sensitivities, budget, widths=WIDTHS)
        b = allocate_mixed_plans(sensitivities, budget, widths=WIDTHS)
        assert a.assignments == b.assignments
        assert {p: r.name for p, r in a.plans.items()} == \
               {p: r.name for p, r in b.plans.items()}
        # and end to end through the measurement pass as well
        m1 = mixed_precision_plan(PARAMS, CFG_Q, mixed_budget=budget, **CALIB)
        m2 = mixed_precision_plan(PARAMS, CFG_Q, mixed_budget=budget, **CALIB)
        assert m1.assignments == m2.assignments
        assert m1.predicted_error == m2.predicted_error

    def test_plans_are_exact_at_assigned_widths(self, sensitivities):
        alloc = allocate_mixed_plans(
            sensitivities, suggest_budget(sensitivities, widths=WIDTHS),
            widths=WIDTHS,
        )
        for path, bits in alloc.assignments.items():
            plan = alloc.plans[path]
            assert (plan.spec.bits_a, plan.spec.bits_w) == bits
            assert plan.mae_per_extraction == 0.0

    def test_base_bits_must_be_a_candidate(self, sensitivities):
        with pytest.raises(ValueError, match="base_bits"):
            allocate_mixed_plans(sensitivities, widths=WIDTHS,
                                 base_bits=(6, 6))

    def test_suggest_budget_needs_two_layers(self):
        """One packable layer can never mix — the error must say so up
        front instead of blaming calibration volume."""
        sens = _fake_sens({"/only/w": {(4, 4): 0.02, (8, 8): 0.01}})
        with pytest.raises(ValueError, match="two packable layers"):
            suggest_budget(sens, widths=WIDTHS)


# ---- per-layer plan maps through conversion and checkpointing ------------


class TestPlanMapPlumbing:
    def test_mixed_plan_map_quantizes_per_layer_widths(self):
        paths = sorted(p for p, _ in iter_packable_weights(PARAMS))
        narrow, wide = (
            select_plan(4, 4, error_budget=0.0, exact_first=True),
            select_plan(8, 8, error_budget=0.0, exact_first=True),
        )
        plans = {p: (narrow if i % 2 else wide)
                 for i, p in enumerate(paths)}
        tree = quantize_for_serving(PARAMS, "dsp_mixed", plans=plans)
        leaves = dict(_tuned_leaves(tree))
        assert set(leaves) == set(paths)
        for i, p in enumerate(paths):
            want = narrow if i % 2 else wide
            assert leaves[p].spec == want.spec
            # narrow plans nibble-pack, wide plans store int8
            assert leaves[p].nibble_packed == (want.spec.bits_w <= 4)

    def test_only_planned_converts_exactly_one_path(self):
        paths = sorted(p for p, _ in iter_packable_weights(PARAMS))
        plan = select_plan(4, 4, error_budget=0.0, exact_first=True)
        probe = quantize_for_serving(
            PARAMS, "dsp_tuned", plans={paths[0]: plan}, only_planned=True,
        )
        leaves = dict(_tuned_leaves(probe))
        assert set(leaves) == {paths[0]}

    def test_leaf_specs_round_trip_through_checkpointer(self, tmp_path,
                                                        sensitivities):
        """A mixed per-layer plan tree must survive save/restore: payloads,
        scales AND the static plan aux (spec/block) — the treedef carries
        the plan, so `like` restores each layer onto ITS plan."""
        from repro.checkpoint.checkpointer import Checkpointer

        alloc = allocate_mixed_plans(
            sensitivities, suggest_budget(sensitivities, widths=WIDTHS),
            widths=WIDTHS,
        )
        tree = quantize_for_serving(PARAMS, "dsp_mixed", plans=alloc.plans)
        ck = Checkpointer(str(tmp_path))
        ck.save(0, tree)
        restored, _ = ck.restore(0, jax.tree.map(lambda x: x, tree))
        want, got = dict(_tuned_leaves(tree)), dict(_tuned_leaves(restored))
        assert set(want) == set(got)
        for path, leaf in want.items():
            r = got[path]
            assert r.spec == leaf.spec and r.block == leaf.block
            assert r.payload.dtype == leaf.payload.dtype
            np.testing.assert_array_equal(
                np.asarray(r.payload), np.asarray(leaf.payload)
            )
            np.testing.assert_array_equal(
                np.asarray(r.scale), np.asarray(leaf.scale)
            )
            np.testing.assert_array_equal(
                np.asarray(r.words), np.asarray(leaf.words)
            )


# ---- the dsp_mixed engine mode -------------------------------------------


def _engine(**kw):
    kw.setdefault("width_candidates", WIDTHS)
    kw.setdefault("calib_tokens", 8)
    return Engine(CFG, PARAMS, ServeConfig(
        n_slots=2, max_len=32, prefill_chunk=4, **kw
    ))


class TestMixedEngine:
    def test_plan_bits_auto_promotes_to_dsp_mixed(self):
        scfg = ServeConfig(quant_mode="dsp_tuned", plan_bits="auto")
        assert scfg.quant_mode == "dsp_mixed"
        with pytest.raises(ValueError, match="auto"):
            ServeConfig(quant_mode="int4_packed", plan_bits="auto")
        with pytest.raises(ValueError, match="plan_bits"):
            ServeConfig(quant_mode="dsp_tuned", plan_bits="4,4")
        with pytest.raises(ValueError, match="mixed_budget"):
            ServeConfig(quant_mode="dsp_mixed", mixed_budget=-1.0)
        with pytest.raises(ValueError, match="autotune_plans"):
            # silently dropping the flag would lie about what ran
            ServeConfig(quant_mode="dsp_mixed", autotune_plans=True)

    def test_precomputed_allocation_needs_dsp_mixed(self, sensitivities):
        """A caller-measured allocation handed to a non-dsp_mixed engine
        must raise, not silently serve different plans."""
        alloc = allocate_mixed_plans(sensitivities, mixed_budget=0.0,
                                     widths=WIDTHS)
        with pytest.raises(ValueError, match="mixed_allocation"):
            Engine(CFG, PARAMS,
                   ServeConfig(n_slots=2, max_len=32, prefill_chunk=4,
                               quant_mode="dsp_tuned"),
                   mixed_allocation=alloc)

    def test_budget_zero_equals_uniform_exact_plan(self):
        """plan_bits="auto" at mixed_budget 0 must serve the uniform
        widest-candidate plan: greedy tokens equal the dsp_tuned engine
        pinned to (8, 8) exact plans."""
        prompts = [[5, 6, 7], [8, 9]]
        mixed = _engine(quant_mode="dsp_tuned", plan_bits="auto",
                        mixed_budget=0.0)
        assert mixed.scfg.quant_mode == "dsp_mixed"
        assert set(mixed.mixed_allocation.assignments.values()) == {(8, 8)}
        uniform = Engine(CFG, PARAMS, ServeConfig(
            n_slots=2, max_len=32, prefill_chunk=4, quant_mode="dsp_tuned",
            plan_bits=(8, 8), error_budget=0.0,
        ))
        assert mixed.generate(prompts, max_new=4) == uniform.generate(
            prompts, max_new=4
        )

    def test_serves_mixed_widths_end_to_end(self):
        """With the suggested half-demotion budget the engine serves at
        least two distinct per-layer width pairs, and the leaves carry
        per-layer specs matching the allocation."""
        sens = measure_layer_sensitivity(PARAMS, CFG_Q, **CALIB)
        budget = suggest_budget(sens, widths=WIDTHS)
        eng = _engine(quant_mode="dsp_mixed", mixed_budget=budget)
        alloc = eng.mixed_allocation
        assert alloc.distinct_widths >= 2
        leaves = dict(_tuned_leaves(eng.params))
        for path, plan in alloc.plans.items():
            assert leaves[path].spec == plan.spec
        out = eng.generate([[5, 6, 7], [8, 9]], max_new=4)
        assert all(len(t) == 4 and np.isfinite(t).all()
                   for t in out.values())

    def test_mixed_tokens_match_reference_given_same_assignment(self):
        """dsp_mixed is dsp_tuned with an allocated plan map: serving the
        allocation through quantize_for_serving by hand reproduces the
        engine's tokens exactly — as does handing the engine a
        precomputed allocation (which skips the build-time sensitivity
        pass; the benchmark relies on that path)."""
        sens = measure_layer_sensitivity(PARAMS, CFG_Q, **CALIB)
        budget = suggest_budget(sens, widths=WIDTHS)
        eng = _engine(quant_mode="dsp_mixed", mixed_budget=budget)
        by_hand = Engine(
            CFG_Q, quantize_for_serving(
                PARAMS, "dsp_mixed", plans=eng.mixed_allocation.plans
            ),
            ServeConfig(n_slots=2, max_len=32, prefill_chunk=4),
        )
        precomputed = Engine(
            CFG, PARAMS,
            ServeConfig(n_slots=2, max_len=32, prefill_chunk=4,
                        quant_mode="dsp_mixed"),
            mixed_allocation=eng.mixed_allocation,
        )
        assert precomputed.mixed_allocation is eng.mixed_allocation
        prompts = [[5, 6, 7], [8, 9]]
        want = eng.generate(prompts, max_new=4)
        assert want == by_hand.generate(prompts, max_new=4)
        assert want == precomputed.generate(prompts, max_new=4)


def _tuned_leaves(tree, path=""):
    if is_dsp_tuned_leaf(tree):
        yield path, tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from _tuned_leaves(v, f"{path}/{k}")
