"""SSM mixers: chunked-parallel forms must agree with one-step decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import ssm

CFG = ModelConfig(
    name="t", family="ssm", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=64, dtype="float32", slstm_every=2,
)
HYB = dataclasses.replace(
    CFG, family="hybrid", attn_every=8, mamba_expand=2, mamba_d_state=4,
    mamba_d_conv=3,
)


def _roll(fn, params, x, cfg, cache):
    outs = []
    for t in range(x.shape[1]):
        o, cache = fn(params, x[:, t : t + 1], cfg, cache=cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def test_mamba_parallel_vs_decode():
    key = jax.random.PRNGKey(0)
    p = ssm.init_mamba(key, HYB)
    x = jax.random.normal(key, (2, 12, 32)) * 0.3
    full, _ = ssm.mamba(p, x, HYB, cache=None)
    dec = _roll(ssm.mamba, p, x, HYB, ssm.init_mamba_cache(HYB, 2))
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-3)


def test_mlstm_chunked_vs_decode():
    key = jax.random.PRNGKey(1)
    p = ssm.init_mlstm(key, CFG)
    x = jax.random.normal(key, (2, 10, 32)) * 0.5
    full, _ = ssm.mlstm(p, x, CFG, cache=None)
    dec = _roll(ssm.mlstm, p, x, CFG, ssm.init_mlstm_cache(CFG, 2))
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-3)


def test_slstm_scan_vs_decode():
    key = jax.random.PRNGKey(2)
    p = ssm.init_slstm(key, CFG)
    x = jax.random.normal(key, (2, 10, 32)) * 0.5
    full, _ = ssm.slstm(p, x, CFG, cache=None)
    dec = _roll(ssm.slstm, p, x, CFG, ssm.init_slstm_cache(CFG, 2))
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-3)


def test_mlstm_long_sequence_stable():
    """Exponential gating must not overflow over many chunks."""
    key = jax.random.PRNGKey(3)
    p = ssm.init_mlstm(key, CFG)
    x = jax.random.normal(key, (1, 1024, 32)) * 2.0
    out, _ = ssm.mlstm(p, x, CFG, cache=None)
    assert np.isfinite(np.asarray(out)).all()


def test_mamba_state_carries_information():
    key = jax.random.PRNGKey(4)
    p = ssm.init_mamba(key, HYB)
    cache = ssm.init_mamba_cache(HYB, 1)
    x1 = jnp.ones((1, 4, 32))
    _, c1 = ssm.mamba(p, x1, HYB, cache=cache)
    assert float(jnp.abs(c1["h"]).sum()) > 0
    assert c1["conv"].shape == cache["conv"].shape


# ---- masked sequential prefill (the serving chunking invariant) ----------


def _mixers():
    k = jax.random.PRNGKey(5)
    return [
        (ssm.mamba, ssm.init_mamba(k, HYB), HYB,
         lambda b: ssm.init_mamba_cache(HYB, b)),
        (ssm.mlstm, ssm.init_mlstm(k, CFG), CFG,
         lambda b: ssm.init_mlstm_cache(CFG, b)),
        (ssm.slstm, ssm.init_slstm(k, CFG), CFG,
         lambda b: ssm.init_slstm_cache(CFG, b)),
    ]


def test_masked_chunked_equals_sequential_bitwise():
    """With ``valid`` the mixers advance state through one exact chunk
    step per token, so a C-token masked call must equal C single-token
    masked calls BIT-FOR-BIT — state and outputs.  This is the invariant
    that lets the serving engines chunk recurrent prefill."""
    for fn, p, cfg, mk_cache in _mixers():
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 12, 32)) * 0.5
        ones = jnp.ones((2, 12), bool)
        y_all, c_all = fn(p, x, cfg, cache=mk_cache(2), valid=ones)
        cache = mk_cache(2)
        ys = []
        for t in range(12):
            y1, cache = fn(p, x[:, t:t + 1], cfg, cache=cache,
                           valid=ones[:, t:t + 1])
            ys.append(y1)
        y_seq = jnp.concatenate(ys, axis=1)
        name = fn.__name__
        assert bool(jnp.all(y_all == y_seq)), name
        for leaf_a, leaf_b in zip(jax.tree.leaves(c_all),
                                  jax.tree.leaves(cache)):
            assert bool(jnp.all(leaf_a == leaf_b)), name


def test_masked_rows_keep_state_bit_unchanged():
    """An all-invalid row's carry must come back bitwise identical — the
    property that lets a decoding lane sit masked through another lane's
    prefill chunks without a cache merge."""
    for fn, p, cfg, mk_cache in _mixers():
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 6, 32))
        cache = mk_cache(2)
        # warm both rows so the state is nonzero
        warm = jnp.ones((2, 6), bool)
        _, cache = fn(p, x, cfg, cache=cache, valid=warm)
        # row 1 masked out entirely; row 0 advances on fresh inputs
        x2 = jax.random.normal(jax.random.PRNGKey(9), (2, 6, 32))
        valid = jnp.stack([jnp.ones((6,), bool), jnp.zeros((6,), bool)])
        _, after = fn(p, x2, cfg, cache=cache, valid=valid)
        name = fn.__name__
        for leaf_a, leaf_b in zip(jax.tree.leaves(cache),
                                  jax.tree.leaves(after)):
            a, b = np.asarray(leaf_a), np.asarray(leaf_b)
            assert np.array_equal(a[1:2], b[1:2]), f"{name}: masked row moved"
            assert not np.array_equal(a[0:1], b[0:1]), (
                f"{name}: valid row did not advance"
            )


def test_masked_full_valid_matches_unmasked_decode():
    """valid=all-ones at l=1 must reproduce the unmasked decode path
    bitwise (the engines always pass valid; training never does)."""
    for fn, p, cfg, mk_cache in _mixers():
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 1, 32))
        y_a, c_a = fn(p, x, cfg, cache=mk_cache(2))
        y_b, c_b = fn(p, x, cfg, cache=mk_cache(2),
                      valid=jnp.ones((2, 1), bool))
        assert bool(jnp.all(y_a == y_b)), fn.__name__
        for leaf_a, leaf_b in zip(jax.tree.leaves(c_a), jax.tree.leaves(c_b)):
            assert bool(jnp.all(leaf_a == leaf_b)), fn.__name__
