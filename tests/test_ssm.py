"""SSM mixers: chunked-parallel forms must agree with one-step decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import ssm

CFG = ModelConfig(
    name="t", family="ssm", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=64, dtype="float32", slstm_every=2,
)
HYB = dataclasses.replace(
    CFG, family="hybrid", attn_every=8, mamba_expand=2, mamba_d_state=4,
    mamba_d_conv=3,
)


def _roll(fn, params, x, cfg, cache):
    outs = []
    for t in range(x.shape[1]):
        o, cache = fn(params, x[:, t : t + 1], cfg, cache=cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def test_mamba_parallel_vs_decode():
    key = jax.random.PRNGKey(0)
    p = ssm.init_mamba(key, HYB)
    x = jax.random.normal(key, (2, 12, 32)) * 0.3
    full, _ = ssm.mamba(p, x, HYB, cache=None)
    dec = _roll(ssm.mamba, p, x, HYB, ssm.init_mamba_cache(HYB, 2))
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-3)


def test_mlstm_chunked_vs_decode():
    key = jax.random.PRNGKey(1)
    p = ssm.init_mlstm(key, CFG)
    x = jax.random.normal(key, (2, 10, 32)) * 0.5
    full, _ = ssm.mlstm(p, x, CFG, cache=None)
    dec = _roll(ssm.mlstm, p, x, CFG, ssm.init_mlstm_cache(CFG, 2))
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-3)


def test_slstm_scan_vs_decode():
    key = jax.random.PRNGKey(2)
    p = ssm.init_slstm(key, CFG)
    x = jax.random.normal(key, (2, 10, 32)) * 0.5
    full, _ = ssm.slstm(p, x, CFG, cache=None)
    dec = _roll(ssm.slstm, p, x, CFG, ssm.init_slstm_cache(CFG, 2))
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-3)


def test_mlstm_long_sequence_stable():
    """Exponential gating must not overflow over many chunks."""
    key = jax.random.PRNGKey(3)
    p = ssm.init_mlstm(key, CFG)
    x = jax.random.normal(key, (1, 1024, 32)) * 2.0
    out, _ = ssm.mlstm(p, x, CFG, cache=None)
    assert np.isfinite(np.asarray(out)).all()


def test_mamba_state_carries_information():
    key = jax.random.PRNGKey(4)
    p = ssm.init_mamba(key, HYB)
    cache = ssm.init_mamba_cache(HYB, 1)
    x1 = jnp.ones((1, 4, 32))
    _, c1 = ssm.mamba(p, x1, HYB, cache=cache)
    assert float(jnp.abs(c1["h"]).sum()) > 0
    assert c1["conv"].shape == cache["conv"].shape
