"""Benchmark smoke tests: every ``benchmarks/*.py`` entry point runs at
tiny shapes through the ``benchmarks.run`` dispatcher, so the CSV contract
(``name,us_per_call,derived``) and the BENCH_*.json schemas — including the
new a8w8 column-packed row — cannot silently rot.

The heavy benchmarks (engine builds, autotune sweeps) are shrunk by
monkeypatching their module-level shape constants — the documented tuning
knobs — and carry the ``slow`` marker; the pure-numpy paper tables run in
the fast lane.  JSON goes to pytest temp dirs, never the repo root.
"""

from __future__ import annotations

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run as bench_run  # noqa: E402


def test_run_dispatcher_knows_every_module(capsys):
    """`--only` parsing covers exactly the modules run.py dispatches."""
    from benchmarks import (  # noqa: F401 — import check is the test
        fig9_density,
        kernel_bench,
        roofline,
        serving_bench,
        table1_packing,
        table2_per_result,
        table3_addpack,
        traffic_bench,
        tuning_bench,
    )

    assert callable(bench_run.main)


def test_run_only_rejects_unknown_names(monkeypatch, capsys):
    """A typo'd --only must exit with an error naming the bad entry, not
    silently skip it (a lane that produced no BENCH json looks green)."""
    monkeypatch.setattr(
        sys, "argv", ["run.py", "--only", "serving,tunign"]
    )
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 2  # argparse usage error
    assert "tunign" in capsys.readouterr().err


def _csv_rows(capsys):
    out = capsys.readouterr().out
    rows = [ln for ln in out.splitlines() if "," in ln]
    for row in rows:
        name, us, _ = row.split(",", 2)
        float(us)  # the us_per_call column must stay numeric
    return rows


def test_table1_emits_error_stats(capsys):
    from benchmarks import table1_packing

    table1_packing.run()
    rows = _csv_rows(capsys)
    assert any(r.startswith("table1/xilinx_int4_naive") for r in rows)
    assert any("MAE=" in r for r in rows)


def test_table2_runs(capsys):
    from benchmarks import table2_per_result

    table2_per_result.run()
    assert _csv_rows(capsys)


def test_table3_emits_addpack_stats(capsys):
    from benchmarks import table3_addpack

    table3_addpack.run()
    rows = _csv_rows(capsys)
    assert any("WCE=" in r for r in rows)
    assert any("guard_bit_variant" in r and "exact=True" in r for r in rows)


def test_fig9_emits_densities(capsys):
    from benchmarks import fig9_density

    fig9_density.run()
    rows = _csv_rows(capsys)
    assert any("rho=" in r for r in rows)


def test_roofline_handles_empty_dryrun_dir(tmp_path, monkeypatch, capsys):
    from benchmarks import roofline

    monkeypatch.chdir(tmp_path)
    rows = roofline.run(out_dir=str(tmp_path / "nothing"))
    assert rows == []
    assert (tmp_path / "artifacts" / "roofline.json").exists()


@pytest.mark.slow
def test_kernel_bench_runs_at_tiny_shapes(capsys):
    from benchmarks import kernel_bench

    kernel_bench.run()
    rows = _csv_rows(capsys)
    assert any(r.startswith("kernel/packed_int4_exact") for r in rows)
    assert any(r.startswith("kernel/flash_attention") for r in rows)


@pytest.mark.slow
def test_serving_bench_schema(tmp_path, monkeypatch, capsys):
    """Pins the prepacked-decode benchmark schema: the packed decode rows
    declare the prepacked path, carry the vs-float ratios (dsp_mixed adds
    the vs-uniform-int4 ratio and its per-layer width allocation), and the
    per-phase tuned blocks (small-M decode GEMV vs prefill grid) ride in
    ``tuned_blocks``, and the non-dense family rows (one SSM, one MoE)
    land under ``families`` keyed by family name."""
    from benchmarks import serving_bench

    monkeypatch.setattr(serving_bench, "SLOTS", 2)
    monkeypatch.setattr(serving_bench, "MAX_LEN", 64)
    monkeypatch.setattr(serving_bench, "PROMPT_LEN", 12)
    monkeypatch.setattr(serving_bench, "CHUNK", 8)
    monkeypatch.setattr(serving_bench, "DECODE_STEPS", 2)
    monkeypatch.setattr(serving_bench, "DECODE_TRIALS", 1)
    monkeypatch.setattr(serving_bench, "MIXED_WIDTHS", ((4, 4), (8, 8)))
    monkeypatch.setattr(serving_bench, "CALIB_TOKENS", 8)
    monkeypatch.setattr(serving_bench, "FAMILY_MAX_LEN", 48)
    out = tmp_path / "BENCH_serving.json"
    result = serving_bench.run(out_path=str(out))
    blob = json.loads(out.read_text())
    assert blob == result
    assert {"config", "prefill", "decode", "mixed",
            "tuned_blocks", "families"} <= set(blob)
    assert blob["prefill"]["chunked_tok_s"] > 0
    dec = blob["decode"]
    assert dec["decode_path"] == "prepacked"
    assert dec["int4_packed_tok_s"] > 0 and dec["dsp_tuned_tok_s"] > 0
    assert dec["int4_packed_vs_float"] > 0 and dec["dsp_tuned_vs_float"] > 0
    assert dec["dsp_mixed_tok_s"] > 0
    assert dec["dsp_mixed_vs_float"] > 0
    assert dec["dsp_mixed_vs_uniform_int4"] > 0
    # the acceptance claim: the bench model serves a genuinely mixed
    # per-layer width assignment
    mixed = blob["mixed"]
    assert mixed["distinct_widths"] >= 2
    assert len(set(mixed["assignments"].values())) == mixed["distinct_widths"]
    for phase in ("prefill", "decode"):
        row = blob["tuned_blocks"][phase]
        assert len(row["block"]) == 3 and row["us_per_call"] > 0
    # the decode phase tunes to a small-M GEMV block, prefill to a wide one
    assert blob["tuned_blocks"]["decode"]["block"][0] <= 16
    # the family rows: one SSM and one MoE registry smoke config, each
    # carrying float + prepacked-int4 decode and the gated ratio
    fams = blob["families"]
    assert {"ssm", "moe"} <= set(fams)
    for fam, row in fams.items():
        assert row["family"] == fam
        assert row["float_tok_s"] > 0 and row["int4_packed_tok_s"] > 0
        assert row["int4_packed_vs_float"] > 0
    assert fams["ssm"]["arch"] == "xlstm-1.3b"
    assert fams["moe"]["arch"] == "moonshot-v1-16b-a3b"
    assert _csv_rows(capsys)


def test_check_bench_gate(tmp_path):
    """The slow-lane regression gate: passes on healthy ratios, fails (with
    the offending gate named) on a regression or a missing key."""
    from benchmarks import check_bench

    healthy = {"decode": {"int4_packed_vs_float": 1.05,
                          "dsp_mixed_vs_uniform_int4": 1.01},
               "families": {"moe": {"int4_packed_vs_float": 0.8}}}
    p = tmp_path / "ok.json"
    p.write_text(json.dumps(healthy))
    assert check_bench.check(str(p)) == []
    assert check_bench.main(["--bench", str(p)]) == 0

    regressed = {"decode": {"int4_packed_vs_float": 0.8,
                            "dsp_mixed_vs_uniform_int4": 1.2},
                 "families": {"moe": {"int4_packed_vs_float": 0.8}}}
    p2 = tmp_path / "bad.json"
    p2.write_text(json.dumps(regressed))
    failures = check_bench.check(str(p2))
    assert len(failures) == 1 and "int4_packed_vs_float" in failures[0]
    assert check_bench.main(["--bench", str(p2)]) == 1

    # the per-expert MoE row below its documented floor: the repack/
    # per-token regression class the family gate exists for
    moe_bad = {"decode": {"int4_packed_vs_float": 1.05,
                          "dsp_mixed_vs_uniform_int4": 1.01},
               "families": {"moe": {"int4_packed_vs_float": 0.29}}}
    pm = tmp_path / "moe_bad.json"
    pm.write_text(json.dumps(moe_bad))
    failures = check_bench.check(str(pm))
    assert len(failures) == 1
    assert "families.moe.int4_packed_vs_float" in failures[0]

    # within-slack parity passes by default but fails under --strict
    # (the moe row sits above its own floor so the strict failures are
    # exactly the two decode parity keys)
    parity = {"decode": {"int4_packed_vs_float": 0.99,
                         "dsp_mixed_vs_uniform_int4": 0.995},
              "families": {"moe": {"int4_packed_vs_float": 0.76}}}
    p3 = tmp_path / "parity.json"
    p3.write_text(json.dumps(parity))
    assert check_bench.main(["--bench", str(p3)]) == 0
    assert check_bench.main(["--bench", str(p3), "--strict"]) == 1

    missing = {"decode": {"int4_packed_vs_float": 1.2}}
    p4 = tmp_path / "missing.json"
    p4.write_text(json.dumps(missing))
    failures = check_bench.check(str(p4))
    assert len(failures) == 2  # every absent gated key is named
    assert "dsp_mixed_vs_uniform_int4" in failures[0]
    assert "families.moe.int4_packed_vs_float" in failures[1]
    assert check_bench.check(str(tmp_path / "nope.json"))  # unreadable fails

    # multiple --bench files: ALL failures reported in one pass
    assert check_bench.main(
        ["--bench", str(p2), "--bench", str(p4)]) == 1

    # tuning certificate-coherence gate
    coherent = {"plan_table": [
        {"plan": "a4w4-p11-n4-full", "provably_exact": True,
         "mae_per_extraction": 0, "wce": 0,
         "certificate": {"verdict": "exact", "wce_per_extraction": 0,
                         "mae_per_extraction": 0.0, "mae_kind": "exact"}},
        {"plan": "a4w4-p11-n4-naive", "provably_exact": False,
         "mae_per_extraction": 0.37, "wce": 4,
         "certificate": {"verdict": "bounded", "wce_per_extraction": 1,
                         "mae_per_extraction": 0.57, "mae_kind": "exact"}},
    ]}
    pt = tmp_path / "tuning_ok.json"
    pt.write_text(json.dumps(coherent))
    assert check_bench.check_tuning(str(pt)) == []
    assert check_bench.main(
        ["--bench", str(p), "--tuning", str(pt)]) == 0

    incoherent = {"plan_table": [
        # provably_exact but certified bounded: verifier/measurement split
        {"plan": "a4w4-p11-n4-full", "provably_exact": True,
         "mae_per_extraction": 0, "wce": 0,
         "certificate": {"verdict": "bounded", "wce_per_extraction": 1,
                         "mae_per_extraction": 0.1, "mae_kind": "exact"}},
        # certified exact but measured nonzero error
        {"plan": "a4w4-p10-n16-mr+full", "provably_exact": False,
         "mae_per_extraction": 0.01, "wce": 2,
         "certificate": {"verdict": "exact", "wce_per_extraction": 0,
                         "mae_per_extraction": 0.0, "mae_kind": "exact"}},
        # no certificate at all
        {"plan": "a4w4-p11-n4-naive", "provably_exact": False,
         "mae_per_extraction": 0.37, "wce": 4},
    ]}
    pb = tmp_path / "tuning_bad.json"
    pb.write_text(json.dumps(incoherent))
    failures = check_bench.check_tuning(str(pb))
    assert len(failures) == 3
    assert check_bench.main(
        ["--bench", str(p), "--tuning", str(pb)]) == 1


def test_traffic_bench_schema_tiny(tmp_path, monkeypatch, capsys):
    """Fast-lane traffic smoke: a handful of requests through both engines
    at tiny shapes pins the BENCH_traffic.json schema and the gated-ratio
    keys (the slow lane runs the full saturating workload)."""
    from repro.models.config import ModelConfig

    from benchmarks import traffic_bench

    tiny = ModelConfig(
        name="traffic-smoke", family="dense", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64, dtype="float32",
    )
    monkeypatch.setattr(traffic_bench, "CFG", tiny)
    monkeypatch.setattr(traffic_bench, "MAX_LEN", 48)
    monkeypatch.setattr(traffic_bench, "FIFO_SLOTS", 2)
    monkeypatch.setattr(traffic_bench, "CONT_LANES", 3)
    monkeypatch.setattr(traffic_bench, "WATERMARK", 2)
    monkeypatch.setattr(traffic_bench, "N_REQUESTS", 6)
    monkeypatch.setattr(traffic_bench, "RATE_HZ", 1000.0)
    monkeypatch.setattr(traffic_bench, "SHORT_MAX_NEW", (3, 5))
    monkeypatch.setattr(traffic_bench, "LONG_PROMPT", (10, 15))
    monkeypatch.setattr(traffic_bench, "LONG_MAX_NEW", (4, 6))
    monkeypatch.setattr(traffic_bench, "XL_PROMPT", (16, 25))
    monkeypatch.setattr(traffic_bench, "XL_MAX_NEW", (4, 6))
    monkeypatch.setattr(traffic_bench, "DEGRADE_REQUESTS", 4)
    out = tmp_path / "BENCH_traffic.json"
    result = traffic_bench.run(out_path=str(out))
    blob = json.loads(out.read_text())
    assert blob == result
    assert {"config", "fifo", "continuous", "degradation",
            "ratios"} <= set(blob)
    for row in (blob["fifo"], blob["continuous"]):
        assert row["finished"] == 6
        assert row["total_tokens"] > 0 and row["sustained_tok_s"] > 0
        assert row["p99_ttft_s"] >= row["p50_ttft_s"] >= 0
        assert {"p50_tpot_s", "p99_tpot_s", "mean_latency_s",
                "preempted", "makespan_s"} <= set(row)
    # degradation replay schema: the ungoverned twin serves everything;
    # the governed engine accounts every burst request as served or shed
    # (whether anything is actually shed at smoke speed is timing-
    # dependent — the slow lane's saturating burst pins the ratio)
    deg = blob["degradation"]
    assert deg["deadline_ms"] > 0
    assert deg["ungoverned"]["finished"] == 4
    assert deg["ungoverned"]["shed"] == 0
    assert deg["governed"]["finished"] + deg["governed"]["shed"] == 4
    assert {"governor_swaps", "final_tier"} <= set(deg["governed"])
    # the gated keys must exist (no throughput assertion at smoke shapes)
    assert blob["ratios"]["continuous_vs_fifo_tok_s"] > 0
    assert blob["ratios"]["fifo_vs_continuous_ttft_p99"] > 0
    assert blob["ratios"]["ungoverned_vs_governed_ttft_p99"] >= 0
    assert _csv_rows(capsys)


def test_check_bench_traffic_gate(tmp_path):
    from benchmarks import check_bench

    healthy = {"ratios": {"continuous_vs_fifo_tok_s": 1.1,
                          "fifo_vs_continuous_ttft_p99": 1.2,
                          "ungoverned_vs_governed_ttft_p99": 2.4}}
    p = tmp_path / "traffic_ok.json"
    p.write_text(json.dumps(healthy))
    assert check_bench.check(
        str(p), gates=check_bench.TRAFFIC_GATES) == []
    ok_serving = {"decode": {"int4_packed_vs_float": 1.05,
                             "dsp_mixed_vs_uniform_int4": 1.01},
                  "families": {"moe": {"int4_packed_vs_float": 0.8}}}
    ps = tmp_path / "serving_ok.json"
    ps.write_text(json.dumps(ok_serving))
    assert check_bench.main(
        ["--bench", str(ps), "--traffic", str(p)]) == 0

    regressed = {"ratios": {"continuous_vs_fifo_tok_s": 0.7,
                            "fifo_vs_continuous_ttft_p99": 1.2,
                            "ungoverned_vs_governed_ttft_p99": 2.4}}
    p2 = tmp_path / "traffic_bad.json"
    p2.write_text(json.dumps(regressed))
    failures = check_bench.check(str(p2), gates=check_bench.TRAFFIC_GATES)
    assert len(failures) == 1
    assert "continuous_vs_fifo_tok_s" in failures[0]
    assert check_bench.main(
        ["--bench", str(ps), "--traffic", str(p2)]) == 1

    # the degradation machinery not engaging (nothing shed, no swap)
    # collapses the governed ratio to ~1.0 — below the 1.2 floor even
    # with the default slack
    no_degrade = {"ratios": {"continuous_vs_fifo_tok_s": 1.1,
                             "fifo_vs_continuous_ttft_p99": 1.2,
                             "ungoverned_vs_governed_ttft_p99": 1.0}}
    p3 = tmp_path / "traffic_no_degrade.json"
    p3.write_text(json.dumps(no_degrade))
    failures = check_bench.check(str(p3), gates=check_bench.TRAFFIC_GATES)
    assert len(failures) == 1
    assert "ungoverned_vs_governed_ttft_p99" in failures[0]


def test_fast_prepacked_engine_decodes(tmp_path):
    """Fast-lane smoke: a tiny engine with prepacked weights builds and
    decodes a few steps off the stored representation (no slow marker — on
    every PR)."""
    import jax
    import numpy as np

    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.serving import Engine, ServeConfig

    cfg = ModelConfig(
        name="prepack-smoke", family="dense", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64, dtype="float32",
    )
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(
        n_slots=2, max_len=32, prefill_chunk=4, quant_mode="int4_packed",
    ))
    leaves = jax.tree_util.tree_flatten_with_path(eng.params)[0]
    assert any("w_f32" in str(p) for p, _ in leaves)  # prepacked operands
    out = eng.generate([[2, 3, 4], [5, 6]], max_new=4)
    assert all(len(v) == 4 and np.isfinite(v).all() for v in out.values())


@pytest.mark.slow
def test_tuning_bench_schema_has_a8w8_column_row(tmp_path, monkeypatch, capsys):
    """The acceptance row: BENCH_tuning.json carries an a8w8 column-packed
    entry next to the int8 dense baseline."""
    from benchmarks import tuning_bench

    monkeypatch.setattr(tuning_bench, "DECODE_STEPS", 2)
    monkeypatch.setattr(tuning_bench, "MAX_LEN", 64)
    monkeypatch.setattr(tuning_bench, "KERNEL_SHAPE", (8, 64, 32))
    monkeypatch.setattr(tuning_bench, "KERNEL_BLOCKS", ((8, 32, 32),))
    out = tmp_path / "BENCH_tuning.json"
    result = tuning_bench.run(out_path=str(out))
    blob = json.loads(out.read_text())
    assert blob == result
    assert {"config", "plan_table", "kernel_timings", "a8w8_column_packed",
            "decode"} <= set(blob)
    a8 = blob["a8w8_column_packed"]
    assert a8["bits_a"] == a8["bits_w"] == 8
    assert a8["n_columns"] > 1 and a8["provably_exact"]
    assert a8["us_per_call"] > 0 and a8["int8_dense_us_per_call"] > 0
    # every plan-table row carries the column axis and its certificate
    # summary (self-describing error pedigree)
    assert all("n_columns" in row for row in blob["plan_table"])
    for row in blob["plan_table"]:
        cert = row["certificate"]
        assert cert["verdict"] in ("exact", "bounded")
        if row["provably_exact"]:
            assert cert["verdict"] == "exact"
        if cert["verdict"] == "exact":
            assert row["mae_per_extraction"] == 0 and row["wce"] == 0
    from benchmarks import check_bench

    assert check_bench.check_tuning(str(out)) == []
    assert blob["decode"]["dsp_tuned_tok_s"] > 0
    assert _csv_rows(capsys)
