"""Pipeline parallelism: 2-stage GPipe schedule over 8 fake devices must
equal the sequential forward."""

import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_two_stage_pipeline_matches_sequential(tmp_path):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import transformer as T
        from repro.models.registry import get_config
        from repro.runtime.jax_compat import use_mesh
        from repro.runtime.pipeline import pipeline_forward, split_stages

        cfg = dataclasses.replace(
            get_config("qwen1.5-110b", smoke=True), dtype="float32",
            remat="none",
        )
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)

        n_micro, mb, s = 3, 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (n_micro, mb, s), 0, cfg.vocab_size)

        # sequential reference
        ref = []
        for i in range(n_micro):
            logits, _, _ = T.forward(params, cfg, toks[i])
            ref.append(logits)
        ref = jnp.stack(ref)

        staged = split_stages(params, 2)
        with use_mesh(mesh):
            got = pipeline_forward(staged, cfg, toks, mesh)
        err = float(jnp.abs(got - ref).max())
        assert err < 2e-3, err
        print("PIPELINE_OK", err)
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=560,
    )
    assert "PIPELINE_OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
