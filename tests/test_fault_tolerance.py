"""Fault-tolerance machinery: heartbeats, stragglers, restart policy,
gradient compression error feedback."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.compression import compressed_grads, init_error_feedback
from repro.runtime.fault_tolerance import (
    Heartbeat,
    HeartbeatMonitor,
    RestartPolicy,
    StragglerDetector,
)


def test_heartbeat_liveness(tmp_path):
    d = str(tmp_path)
    hb0 = Heartbeat(d, 0)
    hb1 = Heartbeat(d, 1)
    hb0.beat(10)
    hb1.beat(10)
    mon = HeartbeatMonitor(d, deadline_s=60)
    assert mon.healthy()
    # host 1 goes silent: check against a future clock
    hb0.beat(11)
    future = time.time() + 120
    hb0.beat(12)  # host 0 beats fresh... but timestamps are wall-clock
    dead = mon.dead_hosts(now=future)
    assert 1 in dead


def test_straggler_detection():
    det = StragglerDetector(window=8, threshold=1.5)
    for step in range(8):
        for host in range(4):
            det.record(host, 1.0 if host != 2 else 2.5)
    assert det.stragglers() == [2]


def test_straggler_window_validation():
    with pytest.raises(ValueError, match="window"):
        StragglerDetector(window=0)
    with pytest.raises(ValueError, match="window"):
        StragglerDetector(window=-3)


def test_rolling_median_empty_then_correct():
    det = StragglerDetector(window=4)
    # empty buffer: 0.0 means "no signal", never a crash
    assert det.rolling_median() == 0.0
    assert det.n_recorded() == 0
    for t in (1.0, 3.0, 2.0):
        det.record(0, t)
    assert det.rolling_median() == 2.0  # odd count: middle element
    det.record(0, 10.0)
    assert det.rolling_median() == 2.5  # even count: mean of middle pair


def test_straggler_buffer_bounded_at_window():
    """The retained history is O(window) no matter how long the job runs,
    and the median tracks only the newest window."""
    det = StragglerDetector(window=4)
    for t in range(1000):
        det.record(7, float(t))
    assert det.n_recorded(7) == 4
    assert det.rolling_median(7) == 997.5  # median of 996..999


def test_restart_policy():
    pol = RestartPolicy(max_restarts=2)
    d1 = pol.on_fault([3], latest_step=400)
    assert d1 == {"action": "restart", "from_step": 400, "replace_hosts": [3]}
    pol.on_fault([1], latest_step=500)
    assert pol.on_fault([], latest_step=600)["action"] == "abort"


def test_compression_error_feedback_is_lossless_on_average():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(512) * 1e-3)}
    err = init_error_feedback(g)
    total_true = np.zeros(512)
    total_sent = np.zeros(512)
    for _ in range(50):
        sent, err = compressed_grads(g, err)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(sent["w"])
    # error feedback: accumulated compressed sum tracks the true sum
    np.testing.assert_allclose(total_sent, total_true, atol=2e-4)


def test_compression_values_int8_representable():
    g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal(256))}
    sent, _ = compressed_grads(g, init_error_feedback(g))
    v = np.asarray(sent["w"])
    scale = np.abs(v).max() / 127.0
    q = v / max(scale, 1e-12)
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)
