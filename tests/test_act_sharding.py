"""Activation-sharding policy rules (pure spec logic, no devices)."""

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.act_sharding import activation_sharding, constrain


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _spec_for(mesh, kind, shape):
    with activation_sharding(mesh):
        from repro.runtime import act_sharding

        _, spec_for, _ = act_sharding._policy()
        return spec_for(kind, shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESHP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_residual_batch_sharded():
    assert _spec_for(MESH, "residual", (256, 4096, 3840)) == P("data", None, None)
    assert _spec_for(MESHP, "residual", (256, 4096, 3840)) == P(("pod", "data"), None, None)


def test_residual_indivisible_batch_replicates():
    assert _spec_for(MESH, "residual", (7, 64, 128)) == P(None, None, None)


def test_hidden_feature_sharded():
    assert _spec_for(MESH, "hidden", (256, 4096, 10240)) == P("data", None, "model")


def test_heads_divisible():
    assert _spec_for(MESH, "heads", (256, 4096, 32, 120)) == P("data", None, "model", None)


def test_heads_indivisible_batch_only():
    # 36 heads on 16-way model: hd-shard fallback would force S^2 psums;
    # only the (divisible) batch axis is sharded
    assert _spec_for(MESH, "heads", (32, 4096, 36, 128)) == P("data", None, None, None)


def test_heads_decode_single_position():
    assert _spec_for(MESH, "heads", (128, 1, 64, 128)) == P("data", None, None, None)


def test_scores_decode_seq_sharded():
    spec = _spec_for(MESH, "scores_decode", (128, 64, 1, 32768))
    assert spec == P("data", None, None, "model")


def test_constrain_is_noop_without_policy():
    x = jnp.ones((4, 4))
    assert constrain(x, "residual") is x


def test_expert_sharding():
    assert _spec_for(MESH, "expert", (16, 1024, 6144)) == P("model", None, None)
    assert _spec_for(MESH, "expert", (6, 64, 64)) == P(None, None, None)
