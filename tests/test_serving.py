"""Serving engine: scheduler lifecycle, batched chunked prefill, sampling,
and the packed-weight decode path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import (
    ContinuousEngine,
    Engine,
    SamplingParams,
    ServeConfig,
)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import Scheduler

KEY = jax.random.PRNGKey(0)
CFG = dataclasses.replace(get_config("qwen1.5-110b", smoke=True), dtype="float32")
PARAMS = T.init_params(KEY, CFG)


def _engine(quant="native", slots=3, chunk=4, **kw):
    return Engine(CFG, PARAMS, ServeConfig(
        n_slots=slots, max_len=32, prefill_chunk=chunk, quant_mode=quant, **kw
    ))


def _cengine(quant="native", slots=3, chunk=4, **kw):
    kw.setdefault("page_size", 8)
    return ContinuousEngine(CFG, PARAMS, ServeConfig(
        n_slots=slots, max_len=32, prefill_chunk=chunk, quant_mode=quant, **kw
    ))


def _greedy_reference(prompt, n):
    """Greedy continuation via full-context uncached forwards."""
    seq, want = list(prompt), []
    for _ in range(n):
        logits, _, _ = T.forward(PARAMS, CFG, jnp.asarray([seq], jnp.int32))
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        want.append(nxt)
        seq.append(nxt)
    return want


# ---- lifecycle / scheduler ----------------------------------------------


def test_submit_and_step():
    eng = _engine()
    rid = eng.submit([5, 6, 7])
    assert rid == 0 and eng.active[0]
    assert len(eng.outputs[rid]) == 1  # prefill samples the first token
    eng.step()
    assert len(eng.outputs[rid]) == 2


def test_queue_admission_when_slots_full():
    eng = _engine(slots=2)
    r0 = eng.submit([1, 2], max_new=2)
    r1 = eng.submit([3, 4], max_new=2)
    r2 = eng.submit([5, 6], max_new=2)  # no free slot: queued, not active
    assert eng.scheduler.n_queued == 1
    assert not eng.scheduler.requests[r2].tokens
    eng.step()  # r0/r1 hit max_new=2 and free their slots
    eng.step()  # r2 admitted and prefilled
    assert eng.scheduler.n_queued == 0
    assert eng.scheduler.requests[r2].tokens
    for _ in range(4):
        eng.step()
    assert all(eng.scheduler.requests[r].done for r in (r0, r1, r2))


def test_termination_single_path_frees_bookkeeping():
    eng = _engine(slots=2)
    outs = eng.generate([[2, 3], [4, 5, 6], [7]], max_new=4)
    assert len(outs) == 3
    assert not eng.active.any()
    assert (eng._slot_rid == -1).all()
    st = eng.stats()
    assert st["finished"] == 3 and st["running"] == 0 and st["queued"] == 0
    for req in eng.scheduler.requests.values():
        assert req.finish_reason == "length"
        assert len(req.tokens) == 4


def test_eos_finishes_request():
    # find the greedy first token, then serve with it as the EOS id: the
    # request must finish during admission through the same path
    first = _engine().generate([[2, 3, 4]], max_new=1)[0][0]
    eng = _engine(eos_token=first)
    outs = eng.generate([[2, 3, 4]], max_new=8)
    assert outs[0] == [first]
    assert eng.scheduler.requests[0].finish_reason == "eos"


def test_max_new_one_needs_no_decode():
    eng = _engine()
    outs = eng.generate([[9, 8, 7]], max_new=1)
    assert [len(v) for v in outs.values()] == [1]
    assert eng.stats()["decode_tokens"] == 0


def test_stats_counters():
    eng = _engine(slots=2)
    prompts = [[2, 3], [4, 5, 6]]
    eng.generate(prompts, max_new=3)
    st = eng.stats()
    assert st["prefill_tokens"] == sum(len(p) for p in prompts)
    assert st["decode_tokens"] > 0
    assert st["prefill_tok_s"] > 0 and st["decode_tok_s"] > 0
    assert st["mean_ttft_s"] > 0 and st["mean_latency_s"] >= st["mean_ttft_s"]


def test_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(quant_mode="float16")
    with pytest.raises(ValueError):
        ServeConfig(fuse_projections="qkv")  # typo'd fusion site
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        _engine().submit(list(range(40)))  # prompt longer than max_len
    with pytest.raises(ValueError):
        _engine().submit([2, 3], max_new=0)  # zero budget is an error


# ---- decode correctness --------------------------------------------------


def test_greedy_decode_matches_full_forward_multi_slot():
    """Cached greedy decode must equal argmax over uncached full forwards at
    every step — with slots at different depths (per-row cache positions)."""
    prompts = [[3, 7, 11, 2], [5, 9], [13, 4, 8, 6, 1]]
    got = _engine(chunk=4).generate(prompts, max_new=6)
    for rid, prompt in enumerate(prompts):
        assert got[rid] == _greedy_reference(prompt, 6)


def test_chunked_prefill_matches_per_token():
    prompts = [[2, 3, 4, 5, 6, 7, 8], [9, 10]]
    a = _engine(chunk=1).generate(prompts, max_new=5)
    b = _engine(chunk=8).generate(prompts, max_new=5)
    assert a == b


def test_chunk_grid_overhanging_max_len():
    """A prompt whose padded chunk grid overhangs max_len must still prefill
    correctly (the cache is allocated on the chunk grid, writes never clamp)."""
    prompt = list(range(2, 32))  # 30 tokens; ceil(30/7)*7 = 35 > max_len 32
    a = _engine(slots=1, chunk=1).generate([prompt], max_new=2)
    b = _engine(slots=1, chunk=7).generate([prompt], max_new=2)
    assert a == b


def test_greedy_decode_is_deterministic():
    out1 = _engine().generate([[2, 3, 4]], max_new=5)
    out2 = _engine().generate([[2, 3, 4]], max_new=5)
    assert list(out1.values()) == list(out2.values())


# ---- packed-weight serving ----------------------------------------------


def test_packed_int4_serving_runs():
    eng = _engine(quant="int4_packed")
    outs = eng.generate([[2, 3, 4]], max_new=4)
    assert all(np.isfinite(t).all() for t in outs.values())


def test_packed_decode_matches_float_within_tolerance():
    """The packed decode path must agree with float decode within int4
    quantization noise, conditioned on the same prompt and next token."""
    prompt = [3, 7, 11, 2, 9, 14]
    ref_eng = _engine(slots=1)
    packed_eng = _engine(slots=1, quant="int4_packed")
    ref_eng.submit(list(prompt), max_new=8)
    packed_eng.submit(list(prompt), max_new=8)
    # force the same conditioning token so the logits are comparable even if
    # quantization flipped the sampled first token
    packed_eng.last_token[:] = ref_eng.last_token
    ref_logits = ref_eng.peek_logits()[0]
    got_logits = packed_eng.peek_logits()[0]
    assert np.isfinite(got_logits).all()
    rel = float(np.abs(got_logits - ref_logits).mean() / np.abs(ref_logits).mean())
    # int4 weights + int8 activations on a tiny *random* smoke net amplify
    # quantization noise (cf. the family-dependent bounds in
    # test_packed_params); calibrated serving bounds (measured rel 0.51,
    # cos 0.87).  The cosine bound also rules out degenerate outputs
    # (all-zero logits would pass a pure mean-relative bound).
    cos = float(
        np.dot(got_logits, ref_logits)
        / (np.linalg.norm(got_logits) * np.linalg.norm(ref_logits))
    )
    assert rel < 1.0, rel
    assert cos > 0.6, cos


def test_prepacked_decode_equals_per_call_int4():
    """Packing once at engine build must reproduce the per-call int4 path
    token for token — same arithmetic, no per-step repacking."""
    import dataclasses as _dc

    from repro.core.packed_linear import LinearSpec

    prompt = [3, 7, 11, 2, 9, 14]
    prepacked = _engine(slots=1, quant="int4_packed")
    percall_cfg = _dc.replace(CFG, quant=LinearSpec(mode="int4_packed"))
    percall = Engine(percall_cfg, PARAMS, ServeConfig(
        n_slots=1, max_len=32, prefill_chunk=4
    ))
    a = prepacked.generate([list(prompt)], max_new=8)
    b = percall.generate([list(prompt)], max_new=8)
    assert a[0] == b[0]


def test_packed_params_are_packed_once():
    eng = _engine(quant="int4_packed")
    leaves = jax.tree_util.tree_flatten_with_path(eng.params)[0]
    assert any("packed" in str(p) for p, _ in leaves)
    assert eng.cfg.quant.mode == "int4_packed"
    # engine build also prepared the decode fast-path operand
    assert any("w_f32" in str(p) for p, _ in leaves)


def test_prepack_toggle_is_bit_transparent():
    """prepack=False (storage-only leaves, per-step packing) and the
    default prepacked engine must emit identical token streams — the
    fast path changes where work happens, never a bit of output."""
    prompts = [[3, 7, 11, 2], [5, 9]]
    for quant in ("int4_packed", "dsp_tuned"):
        hot = _engine(slots=2, quant=quant).generate(prompts, max_new=6)
        cold = _engine(slots=2, quant=quant, prepack=False).generate(
            prompts, max_new=6
        )
        assert hot == cold, quant


def test_dsp_tuned_prepacked_leaves_skip_per_step_packing():
    eng = _engine(quant="dsp_tuned")
    from repro.core.packed_params import is_dsp_tuned_leaf

    def leaves(t):
        if isinstance(t, dict) and not is_dsp_tuned_leaf(t):
            for v in t.values():
                yield from leaves(v)
        elif is_dsp_tuned_leaf(t):
            yield t

    tuned = list(leaves(eng.params))
    assert tuned
    for leaf in tuned:
        assert leaf.prepacked          # words built once at engine build
        assert leaf.zp_row is not None  # zero-point row precomputed
        assert leaf.nibble_packed       # int4 plans store sub-byte payload


def test_projection_fusion_preserves_greedy_stream():
    """Engine-build projection fusion is numerics-preserving: fused and
    unfused packed engines emit identical greedy tokens."""
    prompts = [[3, 7, 11, 2], [5, 9]]
    base = _engine(slots=2, quant="int4_packed").generate(prompts, max_new=6)
    for fuse in ("mlp", "all"):
        got = _engine(slots=2, quant="int4_packed",
                      fuse_projections=fuse).generate(prompts, max_new=6)
        assert got == base, fuse


# ---- non-dense families --------------------------------------------------


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "jamba-v0.1-52b",
                                  "h2o-danube-3-4b"])
def test_recurrent_and_swa_families_serve(arch):
    """SSM/hybrid (recurrent state → chunk-1 prefill fallback) and
    sliding-window models must serve, and a reused slot must behave exactly
    like a fresh engine (admission resets the previous occupant's state)."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    params = T.init_params(KEY, cfg)
    scfg = ServeConfig(n_slots=2, max_len=32, prefill_chunk=8)
    eng = Engine(cfg, params, scfg)
    first = eng.generate([[2, 3, 4], [5, 6]], max_new=4)
    assert all(len(v) == 4 and np.isfinite(v).all() for v in first.values())
    reused = eng.generate([[2, 3, 4]], max_new=4)
    fresh = Engine(cfg, params, scfg).generate([[2, 3, 4]], max_new=4)
    assert list(reused.values()) == list(fresh.values())


# ---- sampling ------------------------------------------------------------


def _sample(logits, temp, top_k, top_p, position=0, seed=0):
    b = logits.shape[0]
    keys = jnp.asarray(
        np.stack([np.asarray(jax.random.PRNGKey(seed + i)) for i in range(b)])
    )
    return np.asarray(sample_tokens(
        jnp.asarray(logits, jnp.float32), keys,
        jnp.full((b,), position, jnp.int32),
        jnp.full((b,), temp, jnp.float32),
        jnp.full((b,), top_k, jnp.int32),
        jnp.full((b,), top_p, jnp.float32),
    ))


def test_temperature_zero_is_argmax():
    logits = np.asarray(jax.random.normal(KEY, (4, 50)))
    assert (_sample(logits, 0.0, 0, 1.0) == logits.argmax(-1)).all()


def test_top_k_one_is_argmax():
    logits = np.asarray(jax.random.normal(KEY, (4, 50)))
    assert (_sample(logits, 1.0, 1, 1.0) == logits.argmax(-1)).all()


def test_top_k_restricts_support():
    logits = np.zeros((1, 50), np.float32)
    logits[0, :3] = [5.0, 4.5, 4.0]  # the only plausible tokens
    draws = {int(_sample(logits, 1.0, 3, 1.0, position=p)[0]) for p in range(50)}
    assert draws <= {0, 1, 2} and len(draws) > 1


def test_top_p_keeps_nucleus_only():
    logits = np.zeros((1, 50), np.float32)
    logits[0, 0] = 10.0  # p(token 0) ~ 1
    draws = {int(_sample(logits, 1.0, 0, 0.5, position=p)[0]) for p in range(20)}
    assert draws == {0}


def test_sampling_reproducible_per_seed():
    sp = SamplingParams(temperature=0.9, top_k=8, top_p=0.95)
    o1 = _engine(seed=7).generate([[2, 3, 4]], max_new=6, sampling=sp)
    o2 = _engine(seed=7).generate([[2, 3, 4]], max_new=6, sampling=sp)
    assert list(o1.values()) == list(o2.values())
    assert all(0 <= t < CFG.vocab_size for t in o1[0])


def test_mixed_sampling_per_slot():
    """One greedy and one sampled request share a decode batch."""
    eng = _engine(slots=2)
    r_greedy = eng.submit([2, 3, 4], max_new=5)
    r_sampled = eng.submit(
        [2, 3, 4], max_new=5,
        sampling=SamplingParams(temperature=1.0, top_k=10),
    )
    for _ in range(6):
        eng.step()
    assert eng.scheduler.requests[r_greedy].tokens == _greedy_reference(
        [2, 3, 4], 5
    )
    assert len(eng.scheduler.requests[r_sampled].tokens) == 5


# ---- determinism regression (per-slot PRNG invariant from PR 1) ----------


class TestServingDeterminism:
    """Same requests + seed must reproduce identical token streams — no
    matter how admission interleaves them onto slots.  Guards the
    (request id, position)-keyed PRNG invariant: a replayed request's draws
    depend only on its own identity, never on co-resident slots."""

    PROMPTS = [[7, 8, 9, 10], [11, 12], [13, 14, 15, 16, 17], [18, 19, 20]]
    SAMPLING = SamplingParams(temperature=0.9, top_k=8, top_p=0.95)

    def _run_batch(self, **kw):
        eng = _engine(slots=kw.pop("slots", 2), seed=kw.pop("seed", 3), **kw)
        return eng.generate(
            [list(p) for p in self.PROMPTS], max_new=6, sampling=self.SAMPLING
        )

    def test_same_requests_same_seed_identical_streams(self):
        assert self._run_batch() == self._run_batch()

    def test_admission_interleaving_does_not_change_streams(self):
        # A: all four submitted upfront, two slots -> two admission waves.
        want = self._run_batch(slots=2)
        # B: staggered submission while decode is mid-flight, four slots.
        eng = _engine(slots=4, seed=3)
        first = [eng.submit(list(p), max_new=6, sampling=self.SAMPLING)
                 for p in self.PROMPTS[:2]]
        eng.step()
        eng.step()
        later = [eng.submit(list(p), max_new=6, sampling=self.SAMPLING)
                 for p in self.PROMPTS[2:]]
        for _ in range(40):
            if not (eng.active.any() or eng.scheduler.n_queued):
                break
            eng.step()
        got = {r: list(eng.scheduler.requests[r].tokens)
               for r in first + later}
        assert got == want

    def test_different_seed_changes_sampled_streams(self):
        # sanity: the determinism above is not vacuous greedy behaviour
        assert self._run_batch(seed=3) != self._run_batch(seed=4)

    def test_greedy_streams_immune_to_slot_count(self):
        greedy = SamplingParams()
        a = _engine(slots=2).generate([list(p) for p in self.PROMPTS],
                                      max_new=5, sampling=greedy)
        b = _engine(slots=4).generate([list(p) for p in self.PROMPTS],
                                      max_new=5, sampling=greedy)
        assert a == b


# ---- continuous batching / paged KV --------------------------------------


MIXED_PROMPTS = [[3, 7, 11, 2], [5, 9], [13, 4, 8, 6, 1, 12, 10, 2, 4, 9]]


def _fake_clock(scheduler):
    """Deterministic monotone clock: each read advances by 1.0."""
    counter = {"t": 0.0}

    def clock():
        counter["t"] += 1.0
        return counter["t"]

    scheduler._clock = clock


@pytest.mark.parametrize("quant", [
    "native",
    "int4_packed",
    pytest.param("dsp_tuned", marks=pytest.mark.slow),
    pytest.param("dsp_mixed", marks=pytest.mark.slow),
])
def test_paged_decode_matches_dense_per_quant_mode(quant):
    """The paged engine must be token-identical to the fixed-slot engine
    for the same requests in every quant mode — paging changes where KV
    lives, never a bit of output."""
    dense = _engine(quant=quant, slots=3)
    paged = _cengine(quant=quant, slots=3)
    want = dense.generate([list(p) for p in MIXED_PROMPTS], max_new=6)
    got = paged.generate([list(p) for p in MIXED_PROMPTS], max_new=6)
    assert got == want, quant
    paged.alloc.check()
    assert paged.alloc.n_free == paged.alloc.n_pages


def test_paged_sampled_matches_dense():
    sp = SamplingParams(temperature=0.8, top_k=10, top_p=0.95)
    want = _engine(seed=5).generate(
        [list(p) for p in MIXED_PROMPTS], max_new=6, sampling=sp
    )
    got = _cengine(seed=5).generate(
        [list(p) for p in MIXED_PROMPTS], max_new=6, sampling=sp
    )
    assert got == want


def test_staggered_prefill_join_regression():
    """A lane whose prefill completes in a step where other lanes are
    already decoding must join the decode batch next step — regression
    for the cached device mask freezing it out (it then decoded from a
    stale state and emitted garbage)."""
    prompts = [[5, 9], [13, 4, 8, 6, 1, 12, 10, 2, 4, 9, 3, 7, 11]]
    want = _engine(slots=2).generate([list(p) for p in prompts], max_new=6)
    got = _cengine(slots=2).generate([list(p) for p in prompts], max_new=6)
    assert got == want


def test_continuous_admission_is_fifo_strict():
    """A queued request that does not fit must not be overtaken by a
    later, smaller one (no head-of-line skipping)."""
    eng = _cengine(slots=2, chunk=4, n_pages=6, watermark_pages=0)
    big = list(range(2, 27))      # 25 toks -> padded 28 -> 4 blocks
    mid = [3, 4, 5, 6, 7, 8, 9, 10, 11]  # 9 -> padded 12 -> 2 blocks
    ra = eng.submit(big, max_new=2, admit=False)
    rb = eng.submit(list(mid), max_new=2, admit=False)
    rc = eng.submit(list(mid), max_new=2, admit=False)  # won't fit yet
    rd = eng.submit([5, 6, 7], max_new=2, admit=False)  # would fit, must wait
    reqs = eng.scheduler.requests
    for _ in range(40):
        eng.step()
        # FIFO invariant: rd never starts before rc
        if reqs[rd].tokens:
            assert reqs[rc].tokens, "later request overtook the queue front"
        if all(reqs[r].done for r in (ra, rb, rc, rd)):
            break
    assert all(reqs[r].done for r in (ra, rb, rc, rd))
    eng.alloc.check()
    assert eng.alloc.n_free == eng.alloc.n_pages


def test_preemption_resumes_bit_identical():
    """Under page pressure the youngest lane is preempted and re-prefilled
    later; its final stream must equal the unpressured run exactly."""
    prompts = [[2, 3, 4, 5, 6, 7, 8, 9, 10], [11, 12, 13, 14, 15, 16, 17]]
    sp = SamplingParams(temperature=0.9, top_k=8, top_p=0.95)
    calm = _cengine(slots=2, seed=3, n_pages=16).generate(
        [list(p) for p in prompts], max_new=10, sampling=sp
    )
    tight = _cengine(slots=2, seed=3, n_pages=4, watermark_pages=0)
    got = tight.generate([list(p) for p in prompts], max_new=10, sampling=sp)
    assert tight.stats()["preempted"] >= 1, "pool was not tight enough"
    assert got == calm
    tight.alloc.check()
    assert tight.alloc.n_free == tight.alloc.n_pages


# ---- scheduler edge cases (pure unit tests, injected clock) --------------


def _ticking_scheduler():
    """Scheduler on a deterministic clock: each read advances 1.0s."""
    counter = {"t": 0.0}

    def clock():
        counter["t"] += 1.0
        return counter["t"]

    return Scheduler(clock=clock), counter


def test_scheduler_finish_from_queue_never_touches_running():
    """Finishing a never-admitted (still-queued) request dequeues it
    cleanly; n_running belongs to admitted requests only."""
    sched, _ = _ticking_scheduler()
    rids = [sched.submit([2, 3], max_new=2) for _ in range(3)]
    sched.admit(2)
    assert sched.n_running == 2 and sched.n_queued == 1
    sched.finish(rids[2], "eos")  # queued rid: dequeue, don't decrement
    assert sched.n_running == 2 and sched.n_queued == 0
    assert sched.n_finished == 1
    with pytest.raises(RuntimeError, match="finished twice"):
        sched.finish(rids[2], "eos")
    # the two admitted requests finish through the normal path
    for rid in rids[:2]:
        sched.finish(rid, "length")
    assert sched.n_running == 0 and sched.n_finished == 3


def test_scheduler_cancel_queued_vs_running():
    sched, _ = _ticking_scheduler()
    r0 = sched.submit([2, 3], max_new=2)
    r1 = sched.submit([4, 5], max_new=2)
    sched.admit(1)  # r0 running, r1 queued
    assert sched.cancel(r1) is True  # queued: no device state to release
    assert sched.cancel(r0) is False  # running: engine must free the lane
    assert sched.n_running == 0 and sched.n_queued == 0
    assert sched.n_cancelled == 2 and sched.n_shed == 0
    assert sched.n_finished == 0  # cancellations are not completions
    with pytest.raises(RuntimeError, match="cannot cancel"):
        sched.cancel(r0)
    with pytest.raises(ValueError, match="not in"):
        sched.cancel(sched.submit([6], max_new=1), reason="boredom")


def test_scheduler_deadline_validation_and_expiry_scan():
    sched, counter = _ticking_scheduler()
    with pytest.raises(ValueError, match="deadline_s"):
        sched.submit([2], max_new=1, deadline_s=0.0)
    tight = sched.submit([2, 3], max_new=2, deadline_s=10.0)
    loose = sched.submit([4, 5], max_new=2, deadline_s=500.0)
    none = sched.submit([6, 7], max_new=2)
    assert none not in sched._deadlined
    assert sched.expired() == []  # nothing past deadline yet
    counter["t"] += 100.0
    assert sched.expired() == [tight]  # only the tight one, loose survives
    sched.cancel(tight, reason="deadline")
    assert sched.expired() == []  # shed rids leave the deadline index
    assert sched.n_shed == 1 == sched.n_cancelled


def test_scheduler_stats_exclude_cancelled_from_latency():
    """A shed request has no honest latency — stats() must keep cancelled
    requests out of every percentile while still counting them."""
    sched, _ = _ticking_scheduler()
    done_rid = sched.submit([2, 3], max_new=2)
    shed_rid = sched.submit([4, 5], max_new=2, deadline_s=1e-3)
    sched.admit(2)
    req = sched.requests[done_rid]
    sched.note_prefill_done([req])
    sched.requests[done_rid].tokens = [7, 8]
    sched.finish(done_rid, "length")
    sched.cancel(shed_rid, reason="deadline")
    st = sched.stats()
    assert st["finished"] == 1 and st["cancelled"] == 1 and st["shed"] == 1
    completed_latency = req.finished_at - req.submitted_at
    assert st["p99_latency_s"] == st["p50_latency_s"] == completed_latency
    assert st["mean_latency_s"] == completed_latency
    assert st["p99_ttft_s"] == req.prefill_done_at - req.submitted_at


def test_shared_prefix_cow_matches_unshared():
    """Requests sharing a registered system prompt must emit exactly what
    they emit without sharing, while physically holding one prefix copy."""
    prefix = list(range(2, 14))  # 12 toks: 1 full + 1 partial page (ps=8)
    suffixes = [[20, 21], [22, 23, 24], [25]]
    prompts = [prefix + s for s in suffixes]
    want = _cengine(slots=3).generate([list(p) for p in prompts], max_new=5)
    eng = _cengine(slots=3, n_pages=16)
    eng.register_shared_prefix(prefix)
    got = eng.generate([list(p) for p in prompts], max_new=5)
    assert got == want
    eng.alloc.check()
    # the two prefix pages stay pinned for future adopters; all private
    # pages were freed on finish
    assert eng.alloc.n_free == eng.alloc.n_pages - 2


def test_capacity_boundary_exact():
    """A prompt of exactly max_len is admissible and yields exactly one
    token (reason 'length'); one more token of prompt is rejected."""
    full = list(range(2, 34))  # 32 == max_len
    for eng in (_engine(slots=1, chunk=5), _cengine(slots=1, chunk=5)):
        outs = eng.generate([list(full)], max_new=8)
        assert len(outs[0]) == 1
        assert eng.scheduler.requests[0].finish_reason == "length"
        with pytest.raises(ValueError):
            eng.submit(full + [2])
    # both engines emit the same single token
    a = _engine(slots=1).generate([list(full)], max_new=8)
    b = _cengine(slots=1).generate([list(full)], max_new=8)
    assert a == b


def test_streaming_tokens_match_outputs():
    for eng in (_engine(slots=2), _cengine(slots=2)):
        rids = [eng.submit(list(p), max_new=4, admit=False)
                for p in MIXED_PROMPTS]
        streamed = {r: [] for r in rids}
        while eng.active.any() or eng.scheduler.n_queued:
            eng.step()
            for rid, tok in eng.drain_stream():
                streamed[rid].append(tok)
        assert not eng.drain_stream()
        for rid in rids:
            assert streamed[rid] == list(eng.scheduler.requests[rid].tokens)


def test_ttft_stamped_per_request_not_per_batch():
    """In one admission batch, a 1-chunk prompt's TTFT stamp must precede
    a 4-chunk prompt's — the old code stamped the whole batch once, after
    the longest prompt finished."""
    for eng in (_engine(slots=2, chunk=4), _cengine(slots=2, chunk=4)):
        _fake_clock(eng.scheduler)
        r_short = eng.submit([5, 9], max_new=2, admit=False)
        r_long = eng.submit([13, 4, 8, 6, 1, 12, 10, 2, 4, 9, 3, 7, 11],
                            max_new=2, admit=False)
        while eng.active.any() or eng.scheduler.n_queued:
            eng.step()
        reqs = eng.scheduler.requests
        assert reqs[r_short].prefill_done_at < reqs[r_long].prefill_done_at


def test_stats_zero_phase_rates_are_zero():
    eng = _engine()
    st = eng.stats()
    assert st["prefill_tok_s"] == 0.0 and st["decode_tok_s"] == 0.0
    assert st["p50_ttft_s"] == 0.0 and st["p99_ttft_s"] == 0.0
    assert st["running"] == 0
    # decode-free serving (max_new=1) must still report 0.0, not ~1e9
    eng.generate([[2, 3, 4]], max_new=1)
    st = eng.stats()
    assert st["decode_tokens"] == 0 and st["decode_tok_s"] == 0.0
    assert st["prefill_tok_s"] > 0


def test_finish_twice_raises():
    eng = _engine()
    eng.generate([[2, 3, 4]], max_new=2)
    with pytest.raises(RuntimeError):
        eng.scheduler.finish(0, "eos")


def test_percentile_interpolation():
    from repro.serving import percentile

    assert percentile([], 99.0) == 0.0
    assert percentile([5.0], 50.0) == 5.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50.0) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 100.0) == 4.0
    assert percentile([4.0, 1.0, 3.0, 2.0], 0.0) == 1.0  # sorts internally


def test_continuous_stats_surface_page_state():
    eng = _cengine(slots=2, n_pages=8)
    eng.generate([[2, 3, 4]], max_new=3)
    st = eng.stats()
    assert st["n_pages"] == 8 and st["page_size"] == 8
    assert st["free_pages"] == 8  # everything released after finish
    assert st["preempted"] == 0
    assert "p99_ttft_s" in st and "p99_tpot_s" in st


def test_continuous_serves_recurrent_families():
    """The old construction-time family rejection is gone: recurrent
    configs build and serve (the full conformance matrix, the chunking
    invariant, and the shared-prefix guard messages that replaced the
    rejection live in tests/test_family_serving.py)."""
    cfg = dataclasses.replace(get_config("xlstm-1.3b", smoke=True),
                              dtype="float32")
    params = T.init_params(KEY, cfg)
    eng = ContinuousEngine(cfg, params, ServeConfig(
        n_slots=2, max_len=32, prefill_chunk=8, page_size=8
    ))
    out = eng.generate([[5, 6, 7, 8, 9]], max_new=3)[0]
    assert len(out) == 3
