"""Serving engine: slot lifecycle, batched decode, packed-weight serving."""

import dataclasses

import jax
import numpy as np

from repro.core.packed_linear import LinearSpec
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving.engine import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)


def _engine(quant="native", slots=3):
    cfg = get_config("qwen1.5-110b", smoke=True)
    cfg = dataclasses.replace(cfg, quant=LinearSpec(mode=quant))
    params = T.init_params(KEY, cfg)
    return Engine(cfg, params, ServeConfig(n_slots=slots, max_len=32))


def test_submit_and_step():
    eng = _engine()
    rid = eng.submit([5, 6, 7])
    assert rid == 0 and eng.active[0]
    eng.step()
    assert len(eng.outputs[rid]) == 2  # prefill token + one decode


def test_slot_exhaustion_and_reuse():
    eng = _engine(slots=2)
    assert eng.submit([1, 2]) is not None
    assert eng.submit([3, 4]) is not None
    assert eng.submit([5, 6]) is None  # no free slot
    eng.active[:] = False  # finish everything
    assert eng.submit([5, 6]) is not None  # slot reused


def test_generate_batch():
    eng = _engine()
    outs = eng.generate([[2, 3], [4, 5, 6], [7]], max_new=6)
    assert len(outs) == 3
    for toks in outs.values():
        assert 1 <= len(toks) <= 6


def test_greedy_decode_is_deterministic():
    out1 = _engine().generate([[2, 3, 4]], max_new=5)
    out2 = _engine().generate([[2, 3, 4]], max_new=5)
    assert list(out1.values()) == list(out2.values())


def test_packed_int4_serving_runs():
    eng = _engine(quant="int4_packed")
    outs = eng.generate([[2, 3, 4]], max_new=4)
    assert all(np.isfinite(t).all() for t in outs.values())


def test_engine_decode_consistent_with_uncached_forward():
    """The engine's cached greedy decode must equal argmax over an
    uncached full forward at every step (float32 smoke model)."""
    cfg = dataclasses.replace(
        get_config("qwen1.5-110b", smoke=True), dtype="float32"
    )
    params = T.init_params(KEY, cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=1, max_len=32))
    prompt = [3, 7, 11, 2]
    rid = eng.submit(list(prompt))
    for _ in range(5):
        eng.step()
    got = eng.outputs[rid][:6]

    # reference: greedy re-decode with full forwards
    import jax.numpy as jnp
    import numpy as np

    seq = list(prompt)
    want = []
    for _ in range(6):
        logits, _, _ = T.forward(params, cfg, jnp.asarray([seq], jnp.int32))
        nxt = int(np.argmax(np.asarray(logits[0, -1])))
        want.append(nxt)
        seq.append(nxt)
    assert got == want[: len(got)]
