"""Cross-validation of the static packing verifier against the repo's
measurement machinery — proof vs experiment on the same arithmetic:

* ``TestIntervalDomain`` — soundness of the abstract domain's transfer
  functions (every concrete result of an operation on members lands in
  the abstract result) plus the endpoint-exactness ``ashr`` relies on.
* ``TestSpecCertificateDominance`` — for EVERY plan the enumerator emits
  across the six width pairs (~520 specs), the certified per-extraction
  WCE dominates the error the independent int64 DSP simulator
  (``tests/dsp_sim.py``) measures on seeded full-range operands, and
  certified-exact plans measure exactly zero.  The fuzz corpus here is
  the measurement; the certificate is the claim under test.
* ``TestWitnessTightness`` — the bound is not just sound but TIGHT: the
  certificate's witness operands drive the simulator to the certified
  WCE exactly, per extraction, in every output cell (checked for the
  named presets and a deterministic sweep of bounded plans).
* ``TestConfigCertificates`` — the DSP48 outer-product certificates'
  analytic MAE/EP reproduce the exhaustive ``scheme_stats`` numbers
  EXACTLY for the paper's Table I/II configurations (both derive from
  complete operand enumeration, so equality is bit-for-bit), and the
  full ``enumerate_packing_configs × SCHEMES`` family stays clause-
  coherent (legal pairings pass, unrestored overpacking is flagged).
* ``TestAddpackCertificates`` — carry certificates vs measured packed-
  adder behavior: guard-0 lanes err by the certified congruence WCE,
  guarded layouts accumulate exactly in the certified chunk.
* ``TestConstructorCitesClauses`` — illegal specs are rejected at
  construction with the clause id the certificate would flag.
* ``TestCertifiedPlans`` — the ``certified_plans`` stamping contract.
* ``TestLint`` — each dtype-hazard rule fires on a minimal synthetic
  snippet, justified waivers suppress with an audit count, unjustified
  waivers are themselves findings, and the real tree is clean with ZERO
  waivers.
"""

from __future__ import annotations

from pathlib import Path

import numpy as np
import pytest

from dsp_sim import simulate_packed_matmul

from repro.analysis import clauses as C
from repro.analysis.domain import Interval
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.verify import (
    certify_addpack,
    certify_config,
    certify_spec,
    witness_operands,
)
from repro.core.addpack import (
    AddPackConfig,
    accumulate,
    lane_add_expected,
    packed_lane_add,
)
from repro.core.correction import SCHEMES, scheme_stats
from repro.core.packing import intn_packing
from repro.kernels import ref
from repro.tuning.plans import (
    certified_plans,
    enumerate_packing_configs,
    enumerate_specs,
)

REPO = Path(__file__).resolve().parent.parent

WIDTH_PAIRS = ((2, 2), (4, 4), (4, 8), (6, 6), (8, 4), (8, 8))
POOL = [s for a, w in WIDTH_PAIRS for s in enumerate_specs(a, w)]

# full-pool sweeps: a deterministic thinning runs in the fast CI lane,
# the long tail carries the `slow` marker (the nightly lane runs all)
_POOL_PARAMS = [
    pytest.param(spec, marks=() if i % 4 == 0 else pytest.mark.slow,
                 id=spec.name())
    for i, spec in enumerate(POOL)
]


# ---------------------------------------------------------------------------
# abstract domain
# ---------------------------------------------------------------------------


class TestIntervalDomain:
    @pytest.mark.parametrize("case", range(40))
    def test_transfer_functions_sound(self, case):
        """Concrete results of members stay inside the abstract result."""
        rng = np.random.default_rng((0xCE21, case))

        def rand_iv():
            lo, hi = sorted(int(v) for v in rng.integers(-2000, 2000, 2))
            return Interval(lo, hi)

        A, B = rand_iv(), rand_iv()
        k = case % 5 + 1
        n = case % 7 + 1
        xs = [int(v) for v in rng.integers(A.lo, A.hi + 1, 16)]
        ys = [int(v) for v in rng.integers(B.lo, B.hi + 1, 16)]
        for x, y in zip(xs, ys):
            assert (A + B).contains(x + y)
            assert (A - B).contains(x - y)
            assert (A * B).contains(x * y)
            assert (-A).contains(-x)
            assert A.shl(k).contains(x << k)
            assert A.ashr(k).contains(x >> k)
            assert A.round_half_up(k).contains(((x >> (k - 1)) + 1) >> 1)
        assert A.sum_n(n).contains(sum(xs[:n]))

    @pytest.mark.parametrize("case", range(20))
    def test_ashr_endpoint_exact(self, case):
        """``ashr`` is endpoint-exact (floor shift is monotone), which is
        what makes the low-field residue bound tight rather than merely
        sound."""
        rng = np.random.default_rng((0xCE22, case))
        lo, hi = sorted(int(v) for v in rng.integers(-(1 << 20), 1 << 20, 2))
        k = case % 8 + 1
        assert Interval(lo, hi).ashr(k) == Interval(lo >> k, hi >> k)

    def test_range_constructors(self):
        assert Interval.signed(4) == Interval(-8, 7)
        assert Interval.unsigned(4) == Interval(0, 15)
        assert Interval.point(3) == Interval(3, 3)
        assert Interval(-8, 7).fits_signed(4)
        assert not Interval(-9, 7).fits_signed(4)


# ---------------------------------------------------------------------------
# spec certificates vs the independent int64 simulator
# ---------------------------------------------------------------------------


def _exact_matmul(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """The mathematically exact integer matmul, in numpy — this file runs
    hundreds of shapes, so avoiding one XLA compile per shape matters."""
    return x.astype(np.int64) @ w.astype(np.int64)


def _measured_max_error(spec, draws: int = 2) -> tuple[int, int]:
    """(max |sim − exact| over seeded full-range draws, n_extractions)."""
    worst = 0
    k = 2 * spec.chunk + (spec.chunk > 1)  # ragged when chunks allow
    n_extr = -(-k // spec.chunk)
    for draw in range(draws):
        rng = np.random.default_rng((0xCE23, spec.p, spec.n_pairs, draw))
        x = rng.integers(0, 1 << spec.bits_a, (3, k)).astype(np.int32)
        w = rng.integers(
            -(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, 5)
        ).astype(np.int32)
        sim = simulate_packed_matmul(spec, x, w).astype(np.int64)
        worst = max(worst, int(np.abs(sim - _exact_matmul(x, w)).max()))
    return worst, n_extr


class TestSpecCertificateDominance:
    @pytest.mark.parametrize("spec", _POOL_PARAMS)
    def test_certified_wce_dominates_simulator(self, spec):
        cert = certify_spec(spec)
        assert cert.ok, cert.failed_clauses
        measured, n_extr = _measured_max_error(spec)
        assert measured <= n_extr * cert.wce_per_extraction, cert.summary()
        if cert.exact:
            # acceptance bar: NO certified-exact plan may show any error
            assert measured == 0, cert.summary()

    def test_every_provably_exact_plan_certifies_exact(self):
        """The constructor's algebraic predicate is subsumed by the
        verifier — including the acceptance examples a8w8-p11-n1-full-c4
        and the a4w4 n=16 accumulation chains."""
        names = {s.name(): certify_spec(s) for s in POOL}
        assert "a8w8-p11-n1-full-c4" in names
        assert names["a8w8-p11-n1-full-c4"].exact
        a4w4_n16 = [s for s in POOL
                    if (s.bits_a, s.bits_w, s.n_pairs) == (4, 4, 16)
                    and s.provably_exact]
        assert a4w4_n16, "enumerator lost the a4w4 n=16 chains"
        for spec in POOL:
            if spec.provably_exact:
                assert names[spec.name()].exact, spec.name()


# ---------------------------------------------------------------------------
# witness tightness
# ---------------------------------------------------------------------------

_BOUNDED = [s for s in POOL if not certify_spec(s).exact]
_TIGHTNESS_SPECS = [
    pytest.param(ref.INT4_NAIVE, id="INT4_NAIVE"),
    pytest.param(ref.INT4_MR_OVERPACKED, id="INT4_MR_OVERPACKED"),
] + [
    pytest.param(spec, marks=() if i % 9 == 0 else pytest.mark.slow,
                 id=spec.name())
    for i, spec in enumerate(_BOUNDED)
]


class TestWitnessTightness:
    @pytest.mark.parametrize("spec", _TIGHTNESS_SPECS)
    def test_witness_achieves_certified_wce(self, spec):
        """The witness drives the SIMULATOR (not the jnp ref the verifier
        CLI uses — an independent engine) to the certified endpoint in
        every cell of every extraction."""
        cert = certify_spec(spec)
        assert not cert.exact and cert.witness is not None
        n_extr = 3
        x, w = witness_operands(spec, n_extractions=n_extr, rows=2, cols=2)
        sim = simulate_packed_matmul(spec, x, w).astype(np.int64)
        err = sim - _exact_matmul(x, w)
        assert np.all(err == n_extr * cert.witness.per_extraction_error)
        assert np.abs(err).max() == n_extr * cert.wce_per_extraction

    def test_exact_plans_have_no_witness(self):
        with pytest.raises(ValueError, match="certified exact"):
            witness_operands(ref.INT4_EXACT)


# ---------------------------------------------------------------------------
# DSP48 outer-product configs: analytic MAE == exhaustive measurement
# ---------------------------------------------------------------------------

# the paper's 4-bit Table I/II operating points with their exact error
# expectations (complete 2^16-operand enumeration on both sides, so the
# comparison is literal float equality, not approximate)
_PAPER_POINTS = [
    pytest.param(3, "naive", 0.37353515625, id="d3-naive"),
    pytest.param(3, "full", 0.0, id="d3-full"),
    pytest.param(3, "approx", 0.023529052734375, id="d3-approx"),
    pytest.param(-2, "mr", 0.47823333740234375, id="d-2-mr"),
    pytest.param(-2, "mr+full", 0.30533599853515625, id="d-2-mr+full"),
]

_CFG_PARAMS = [
    pytest.param(cfg, scheme,
                 marks=() if i % 5 == 0 else pytest.mark.slow,
                 id=f"{'x'.join(map(str, cfg.a_widths))}-d{cfg.delta}-{scheme}")
    for i, (cfg, scheme) in enumerate(
        (cfg, scheme)
        for a_bits, w_bits in ((4, 4), (8, 8))
        for cfg in enumerate_packing_configs(a_bits, w_bits)
        for scheme in SCHEMES
    )
]


class TestConfigCertificates:
    @pytest.mark.parametrize("delta, scheme, mae", _PAPER_POINTS)
    def test_paper_mae_reproduced_exactly(self, delta, scheme, mae):
        cfg = intn_packing((4, 4), (4, 4), delta)
        cert = certify_config(cfg, scheme)
        stats = scheme_stats(cfg, scheme)
        assert cert.mae_per_extraction == stats.mae_bar == mae
        if mae == 0.0:
            assert cert.exact
        else:
            assert cert.verdict == "bounded"
            assert cert.mae_kind == "exact"  # enumeration, not a bound
            assert cert.ep_per_extraction == stats.ep_bar / 100.0
            assert cert.wce_per_extraction == stats.wce_bar

    @pytest.mark.parametrize("cfg, scheme", _CFG_PARAMS)
    def test_enumerated_family_clause_coherent(self, cfg, scheme):
        """certify_config itself raises on unsoundness (enumerated WCE
        beyond the interval bound); here we additionally pin the clause
        contract: δ >= 0 or an MR scheme must pass every clause, and
        overpacked overlap WITHOUT the restore must be flagged as a
        field-wrap hazard — the paper's core legality boundary."""
        cert = certify_config(cfg, scheme)
        legal_pairing = cfg.delta >= 0 or scheme in ("mr", "mr+full")
        if legal_pairing:
            assert cert.ok, cert.summary()
        else:
            assert C.CLAUSE_FIELD_WRAP in cert.failed_clauses, cert.summary()


# ---------------------------------------------------------------------------
# addition packing
# ---------------------------------------------------------------------------


class TestAddpackCertificates:
    def test_guard0_congruence_wce_measured(self):
        """Five 9-bit lanes, no guards (Table III): certified bounded with
        congruence WCE 1; random packed adds never err by more than the
        certified carry modulo the lane width, and a saturated draw
        realizes it."""
        cfg = AddPackConfig((9,) * 5)
        cert = certify_addpack(cfg)
        assert not cert.exact and cert.wce_per_extraction == 1
        assert set(cert.failed_clauses) == {
            C.CLAUSE_GUARD_CARRY, C.CLAUSE_FIELD_WRAP,
        }
        rng = np.random.default_rng(0xCE24)
        lo, hi = -(1 << 8), 1 << 8
        x = rng.integers(lo, hi, (64, cfg.n_lanes))
        y = rng.integers(lo, hi, (64, cfg.n_lanes))
        got = packed_lane_add(cfg, x, y)
        want = lane_add_expected(cfg, x, y)
        for i, width in enumerate(cfg.lane_widths):
            diff = (got[..., i] - want[..., i]) % (1 << width)
            assert int(diff.max()) <= cert.wce_per_extraction
        # all-(-1) lanes saturate every field: the carry chain realizes
        # the certified WCE in every victim lane
        ones = np.full((1, cfg.n_lanes), -1)
        got = packed_lane_add(cfg, ones, ones)
        want = lane_add_expected(cfg, ones, ones)
        assert int(np.abs(got - want).max()) == cert.wce_per_extraction

    @pytest.mark.parametrize(
        "cfg, chunk",
        [
            pytest.param(AddPackConfig((8, 8), guard_bits=1), 2, id="8x8-g1"),
            pytest.param(AddPackConfig((10,) * 4, guard_bits=2), 4,
                         id="10x4-g2"),
        ],
    )
    def test_guarded_lanes_accumulate_exactly(self, cfg, chunk):
        cert = certify_addpack(cfg)
        assert cert.exact and cert.ok
        assert f"max exact accumulation chunk {chunk}" in next(
            c.detail for c in cert.clauses
            if c.clause == C.CLAUSE_GUARD_CARRY
        )
        rng = np.random.default_rng(0xCE25)
        # guard bits absorb CROSS-lane carries; the lane's own payload
        # must still fit its width per chunk partial sum, so draw terms
        # at 1/chunk of the lane range
        w = min(cfg.lane_widths)
        lim = (1 << (w - 1)) // chunk
        terms = rng.integers(-lim, lim, (5, 4 * chunk, cfg.n_lanes))
        got = accumulate(cfg, terms)
        np.testing.assert_array_equal(got, terms.sum(axis=-2))


# ---------------------------------------------------------------------------
# constructor ↔ clause cross-references
# ---------------------------------------------------------------------------


class TestConstructorCitesClauses:
    def test_alias_hazard_rejected_with_clause_id(self):
        """The extraction-aliasing hazard the verifier uncovered: at
        n_pairs=73 the rounding residue pushes M + g past the signed
        extract width, so sign-extension wraps.  The constructor must
        reject it citing the certificate clause."""
        with pytest.raises(ValueError, match=C.CLAUSE_EXTRACTION_ALIAS):
            ref.PackedDotSpec(3, 2, 7, 73, "mr", 5)

    def test_accumulator_overflow_cites_clause(self):
        with pytest.raises(ValueError, match=C.CLAUSE_INT32_ACCUMULATOR):
            ref.PackedDotSpec(8, 8, 16, 8, "full")

    def test_enumerated_specs_all_construct_clause_clean(self):
        """The new constructor check must not reject anything the
        enumerator emits (every emitted plan passes all clauses)."""
        for spec in POOL:
            assert certify_spec(spec).ok, spec.name()


# ---------------------------------------------------------------------------
# certified_plans stamping
# ---------------------------------------------------------------------------


class TestCertifiedPlans:
    def test_pairs_cover_enumeration_with_matching_names(self):
        pairs = certified_plans(4, 4)
        specs = enumerate_specs(4, 4)
        assert len(pairs) == len(specs)
        for (spec, cert), expected in zip(pairs, specs):
            assert spec == expected
            assert cert.plan == spec.name()
            assert cert.verdict in ("exact", "bounded")
            if spec.provably_exact:
                assert cert.exact


# ---------------------------------------------------------------------------
# dtype-hazard lint
# ---------------------------------------------------------------------------


class TestLint:
    def _rules(self, source: str) -> list[str]:
        return [f.rule for f in lint_source(source)]

    def test_dth001_integer_dot_missing_preferred_type(self):
        src = (
            "import jax.numpy as jnp\n"
            "def f(a, b):\n"
            "    a8 = a.astype(jnp.int8)\n"
            "    return jnp.dot(a8, b)\n"
        )
        assert self._rules(src) == ["DTH001"]

    def test_dth001_silent_with_preferred_type(self):
        src = (
            "import jax.numpy as jnp\n"
            "def f(a, b):\n"
            "    a8 = a.astype(jnp.int8)\n"
            "    return jnp.dot(a8, b, preferred_element_type=jnp.int32)\n"
        )
        assert self._rules(src) == []

    def test_dth002_constant_overflows_dtype(self):
        assert self._rules(
            "import numpy as np\nx = np.int8(77 * 3)\n"
        ) == ["DTH002"]
        assert self._rules(
            "import numpy as np\nx = np.array(1 << 15, dtype=np.int16)\n"
        ) == ["DTH002"]
        assert self._rules(
            "import numpy as np\nx = np.int8(-128)\n"
        ) == []

    def test_dth003_narrowing_astype_before_multiply(self):
        src = "def f(x, y):\n    return x.astype('int16') * y\n"
        assert self._rules(src) == ["DTH003"]
        wide = "def f(x, y):\n    return x.astype('int64') * y\n"
        assert self._rules(wide) == []

    def test_dth004_int32_shift_overflow(self):
        src = (
            "import numpy as np\n"
            "def f(v):\n"
            "    v32 = v.astype(np.int32)\n"
            "    return v32 << 31\n"
        )
        assert self._rules(src) == ["DTH004"]
        safe = (
            "import numpy as np\n"
            "def f(v):\n"
            "    v64 = v.astype(np.int64)\n"
            "    return v64 << 31\n"
        )
        assert self._rules(safe) == []

    def test_justified_waiver_suppresses_and_counts(self):
        src = (
            "import numpy as np\n"
            "def f(v):\n"
            "    v32 = v.astype(np.int32)\n"
            "    # packlint: ok[DTH004] -- feeds a 64-bit accumulator\n"
            "    return v32 << 31\n"
        )
        assert lint_source(src) == []

    def test_unjustified_waiver_is_a_finding(self):
        src = (
            "import numpy as np\n"
            "def f(v):\n"
            "    v32 = v.astype(np.int32)\n"
            "    return v32 << 31  # packlint: ok[DTH004]\n"
        )
        assert self._rules(src) == ["PRAGMA000"]

    def test_tree_clean_with_zero_waivers(self):
        """The acceptance bar: the kernel stack lints clean with no
        unexplained waivers — in fact with NO waivers at all."""
        findings, n_files, n_waived = lint_paths(
            [str(REPO / d) for d in ("src", "tests", "benchmarks")]
        )
        assert findings == [], [str(f) for f in findings]
        assert n_waived == 0
        assert n_files > 50  # the walk actually visited the tree
