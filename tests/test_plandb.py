"""Persisted plan database: key fingerprinting, loss-free round-trips,
schema invalidation, crash consistency through the Checkpointer's atomic
publish, and the headline warm-build contract — an engine built against a
warm DB runs ZERO measurement (the ``tuning.mixed.PROBES`` counter stays
at zero) and serves tokens identical to the cold build that populated
it."""

import dataclasses
import json
import os

import jax
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import ContinuousEngine, Engine, ServeConfig
from repro.tuning import (
    PROBES,
    PlanDB,
    SCHEMA_VERSION,
    plan_key,
    report_from_json,
    report_to_json,
    select_plan,
)
from repro.tuning.plans import spec_from_json, spec_to_json

KEY = jax.random.PRNGKey(0)
CFG = dataclasses.replace(get_config("qwen1.5-110b", smoke=True),
                          dtype="float32")
PARAMS = T.init_params(KEY, CFG)


def _scfg(**kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 4)
    return ServeConfig(**kw)


# ---- serialization -------------------------------------------------------


def test_report_json_roundtrip_is_lossless():
    for budget, exact_first in ((0.0, True), (0.5, False)):
        report = select_plan(4, 4, error_budget=budget,
                             exact_first=exact_first)
        blob = json.dumps(report_to_json(report))  # genuinely JSON-able
        assert report_from_json(json.loads(blob)) == report


def test_spec_json_rejects_unknown_fields():
    d = spec_to_json(select_plan(4, 4).spec)
    assert spec_from_json(dict(d)) == select_plan(4, 4).spec
    d["mystery_knob"] = 7
    with pytest.raises(ValueError, match="mystery_knob"):
        spec_from_json(d)


# ---- keying --------------------------------------------------------------


def test_plan_key_stable_and_search_sensitive():
    scfg = _scfg(quant_mode="dsp_tuned")
    k = plan_key(CFG, scfg, PARAMS)
    assert k == plan_key(CFG, scfg, PARAMS)  # deterministic
    # knobs the search reads change the key...
    assert k != plan_key(CFG, dataclasses.replace(scfg, plan_bits=(8, 8)),
                         PARAMS)
    assert k != plan_key(CFG, dataclasses.replace(scfg, error_budget=0.9),
                         PARAMS)
    assert k != plan_key(CFG, dataclasses.replace(scfg,
                                                  quant_mode="dsp_mixed"),
                         PARAMS)
    # ...a changed model config too...
    other_cfg = dataclasses.replace(CFG, name="other")
    assert k != plan_key(other_cfg, scfg, PARAMS)
    # ...but serving-only knobs (slots, sampling, pages) never do
    assert k == plan_key(CFG, dataclasses.replace(scfg, n_slots=7), PARAMS)
    assert k == plan_key(CFG, dataclasses.replace(scfg, temperature=0.8),
                         PARAMS)


# ---- the database --------------------------------------------------------


def test_plandb_put_get_persists_across_instances(tmp_path):
    db = PlanDB(str(tmp_path / "db"))
    assert db.get("k") is None and db.n_misses == 1
    entry = {"kind": "tuned", "plans": {"x": report_to_json(select_plan())}}
    db.put("k", entry)
    got = db.get("k")
    assert got == entry and db.n_hits == 1
    # a fresh instance (a restarted engine) reads the same entry
    db2 = PlanDB(str(tmp_path / "db"))
    assert db2.get("k") == entry
    assert len(db2) == 1 and db2.keys() == ["k"]


def test_plandb_invalidate(tmp_path):
    db = PlanDB(str(tmp_path / "db"))
    db.put("a", {"kind": "tuned"})
    db.put("b", {"kind": "tuned"})
    assert db.invalidate("missing") == 0
    assert db.invalidate("a") == 1
    assert db.keys() == ["b"]
    assert db.invalidate() == 1 and len(db) == 0
    # the drop persists like any put
    assert PlanDB(str(tmp_path / "db")).get("b") is None


def test_schema_mismatch_reads_as_empty(tmp_path):
    db = PlanDB(str(tmp_path / "db"))
    db.put("k", {"kind": "tuned"})
    # a future writer bumps the schema: this reader must not deserialize
    db._ckpt.save(99, {}, extra={"schema": SCHEMA_VERSION + 1,
                                 "entries": {"k": {"kind": "garbled"}}})
    assert db.get("k") is None
    assert db.n_stale == 1
    # ...and a put from this reader rebuilds a valid envelope on top
    db.put("k", {"kind": "tuned"})
    assert db.get("k") == {"kind": "tuned"}


def test_torn_write_is_invisible(tmp_path):
    """A writer killed mid-put leaves only a ``.tmp`` directory — the
    Checkpointer's ``all_steps`` never offers it, so readers keep seeing
    the previous complete database."""
    db = PlanDB(str(tmp_path / "db"))
    db.put("k", {"kind": "tuned"})
    step = db._ckpt.latest_step()
    torn = os.path.join(db.directory, f"step_{step + 1:08d}.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "extra.json"), "w") as f:
        f.write("{\"schema\": 1, \"entries\"")  # half-written JSON
    assert db._ckpt.latest_step() == step
    assert db.get("k") == {"kind": "tuned"}
    # the next put publishes past the torn dir without tripping on it
    db.put("k2", {"kind": "tuned"})
    assert sorted(db.keys()) == ["k", "k2"]


def test_keep_gc_never_drops_live_entries(tmp_path):
    """Whole-DB-per-step: every put rewrites ALL entries, so however many
    old steps the keep-GC deletes, the newest step still carries every
    key any live engine was built from."""
    db = PlanDB(str(tmp_path / "db"), keep=2)
    for i in range(6):
        db.put(f"k{i}", {"kind": "tuned", "i": i})
    assert len(db._ckpt.all_steps()) == 2  # GC ran
    assert db.keys() == sorted(f"k{i}" for i in range(6))
    for i in range(6):
        assert db.get(f"k{i}") == {"kind": "tuned", "i": i}


# ---- warm-build contract -------------------------------------------------


def test_dsp_tuned_warm_build_serves_identical_tokens(tmp_path):
    dbdir = str(tmp_path / "db")
    prompts = [[2, 3, 4, 5], [7, 8, 9]]

    cold = Engine(CFG, PARAMS, _scfg(quant_mode="dsp_tuned", plan_db=dbdir))
    assert cold.plan_db_stats["misses"] == 1
    assert cold.plan_db_stats["hits"] == 0
    cold_out = cold.generate(prompts, max_new=6)

    warm = Engine(CFG, PARAMS, _scfg(quant_mode="dsp_tuned", plan_db=dbdir))
    assert warm.plan_db_stats["hits"] == 1
    assert warm.plan_db_stats["misses"] == 0
    assert warm.generate(prompts, max_new=6) == cold_out
    # the warm table IS the cold table, measured floats included
    assert warm.plan_table == cold.plan_table


@pytest.mark.slow
def test_dsp_mixed_warm_build_runs_zero_probes(tmp_path):
    """The expensive path: a cold dsp_mixed build runs the sensitivity
    probe forwards; the warm build against the same DB runs NONE (the
    module-level probe counter stays at zero) and exposes the identical
    allocation and token stream."""
    dbdir = str(tmp_path / "db")
    prompts = [[2, 3, 4, 5], [7, 8, 9]]
    scfg = dict(quant_mode="dsp_mixed", plan_bits="auto", plan_db=dbdir,
                calib_tokens=8, width_candidates=((4, 4), (8, 8)))

    PROBES.reset()
    cold = ContinuousEngine(CFG, PARAMS, _scfg(page_size=8, **scfg))
    assert PROBES.count > 0  # the cold build really measured
    cold_out = cold.generate(prompts, max_new=6)

    PROBES.reset()
    warm = ContinuousEngine(CFG, PARAMS, _scfg(page_size=8, **scfg))
    assert PROBES.count == 0, "warm build re-ran sensitivity probes"
    assert warm.plan_db_stats["hits"] == 1
    assert warm.mixed_allocation == cold.mixed_allocation
    assert warm.generate(prompts, max_new=6) == cold_out


def test_governed_warm_build_runs_zero_tier_searches(tmp_path):
    """A governed engine builds a tier ladder (narrow fallback table on
    top of the primary); the ladder's plan searches are persisted under
    the same plan_key entry, so a warm governed build runs ZERO tier
    searches (``governor.TIER_SEARCHES`` stays flat — the tier analogue
    of the PROBES contract) yet exposes the identical ladder and
    tokens."""
    from repro.serving.governor import TIER_SEARCHES

    dbdir = str(tmp_path / "db")
    prompts = [[2, 3, 4, 5], [7, 8, 9]]
    scfg = dict(quant_mode="dsp_tuned", plan_db=dbdir, governor=True)

    TIER_SEARCHES.reset()
    cold = Engine(CFG, PARAMS, _scfg(**scfg))
    assert TIER_SEARCHES.count > 0  # the cold build really searched
    cold_out = cold.generate(prompts, max_new=6)
    cold_ladder = [(t.name, t.max_certified_mae) for t in cold.tiers]

    TIER_SEARCHES.reset()
    warm = Engine(CFG, PARAMS, _scfg(**scfg))
    assert TIER_SEARCHES.count == 0, "warm governed build re-ran tier search"
    assert warm.plan_db_stats["hits"] == 1
    assert [(t.name, t.max_certified_mae) for t in warm.tiers] == cold_ladder
    assert warm.generate(prompts, max_new=6) == cold_out
