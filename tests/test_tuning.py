"""Packing-plan subsystem: enumeration legality, error scoring, budgeted
selection, block autotuning and the serving-side plan routing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed_linear import LinearSpec, apply_linear
from repro.core.packed_params import (
    DspTunedLeaf,
    is_dsp_tuned_leaf,
    iter_packable_weights,
    quantize_for_serving,
)
from repro.kernels.ref import INT2_EXACT, INT4_EXACT, INT4_MR_OVERPACKED
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import Engine, ServeConfig
from repro.tuning import (
    autotune_block,
    candidate_blocks,
    enumerate_specs,
    min_exact_p,
    plan_linear_layers,
    rank_plans,
    select_plan,
    spec_error_stats,
)


class TestEnumeration:
    def test_presets_are_rediscovered(self):
        """The hand-derived presets are points in the searched space."""
        assert INT4_EXACT in enumerate_specs(4, 4)
        assert INT4_MR_OVERPACKED in enumerate_specs(4, 4)
        assert INT2_EXACT in enumerate_specs(2, 2, n_pairs_choices=(32,))

    def test_min_exact_p_is_minimal(self):
        from repro.kernels.ref import PackedDotSpec

        p = min_exact_p(4, 4, 4)
        assert p == 11
        PackedDotSpec(4, 4, p, 4, "full")  # constructs
        with pytest.raises(ValueError):
            PackedDotSpec(4, 4, p - 1, 4, "full")  # one bit tighter fails

    def test_exact_schemes_carry_no_mr_bits(self):
        for spec in enumerate_specs(4, 4):
            p_min = min_exact_p(4, 4, spec.n_pairs, spec.n_columns)
            if spec.correction in ("naive", "full"):
                assert spec.mr_bits == 0 and spec.p == p_min
            else:
                assert spec.mr_bits == p_min - spec.p

    def test_six_bit_single_column_only_overpacked(self):
        """Without columns 6-bit operands only fit squeezed (mr) plans;
        the column axis unlocks exact-spacing 6-bit plans."""
        single = enumerate_specs(6, 6, n_columns_choices=(1,))
        assert single and all(s.uses_mr for s in single)
        multi = enumerate_specs(6, 6)
        assert any(s.correction == "full" and s.n_columns > 1 for s in multi)

    def test_column_counts_skip_duplicate_slice_widths(self):
        """n_columns beyond bits_a, or repeating a slice width, would emit
        the identical plan twice — the enumerator skips them."""
        specs = enumerate_specs(2, 2, n_columns_choices=(1, 2, 4))
        assert {s.n_columns for s in specs} == {1, 2}  # 4 > bits_a
        names = [s.name() for s in specs]
        assert len(names) == len(set(names))

    def test_a8w8_plans_exist_and_are_column_packed(self):
        specs = enumerate_specs(8, 8)
        assert specs and all(s.n_columns > 1 for s in specs)
        assert any(s.provably_exact for s in specs)

    def test_cost_proxy_charges_columns(self):
        from repro.tuning import plan_cost_proxy
        from repro.kernels.ref import PackedDotSpec

        c1 = PackedDotSpec(4, 4, 11, 4, "full")
        c2 = PackedDotSpec(4, 4, 11, 4, "full", n_columns=2)
        assert plan_cost_proxy(c2) == 2 * plan_cost_proxy(c1)


class TestScoring:
    def test_full_plans_score_zero_error(self):
        for spec in enumerate_specs(4, 4, corrections=("full",)):
            assert spec_error_stats(spec).mae == 0.0

    def test_naive_plans_score_the_bias(self):
        score = spec_error_stats(INT4_EXACT.__class__(4, 4, 11, 4, "naive"))
        assert 0 < score.mae_per_extraction <= 1.0

    def test_exhaustive_grid_used_when_small(self):
        assert spec_error_stats(INT2_EXACT.__class__(2, 2, 5, 1, "full")).exhaustive
        assert not spec_error_stats(INT4_MR_OVERPACKED).exhaustive

    def test_rounding_never_hurts_mr(self):
        from repro.kernels.ref import PackedDotSpec

        mr = spec_error_stats(PackedDotSpec(4, 4, 10, 16, "mr", 3))
        mrf = spec_error_stats(PackedDotSpec(4, 4, 10, 16, "mr+full", 3))
        assert mrf.mae <= mr.mae


class TestSelection:
    def test_budget_filters(self):
        """Budget 0 admits only PROVEN exact plans — by static certificate
        (``analysis.verify.certify_spec``) or by exhaustive enumeration of
        the extraction's full operand space.  A sampled grid that happened
        to observe zero error is neither: its reported MAE falls back to
        the certificate's analytic bound, which is provably positive for
        every non-exact plan."""
        exact_only = rank_plans(4, 4, error_budget=0.0)
        assert exact_only and all(r.mae_per_extraction == 0 for r in exact_only)
        assert all(
            r.certificate.exact or (r.exhaustive and r.mae == 0)
            for r in exact_only
        )
        sampled_zero = [
            r for r in rank_plans(4, 4, error_budget=0.5)
            if r.mae == 0 and not r.certificate.exact and not r.exhaustive
        ]
        for r in sampled_zero:  # certificate-backed, so budget 0 excludes
            assert r.mae_per_extraction > 0

    def test_default_budget_prefers_longer_chains(self):
        best = select_plan(4, 4)
        assert best.spec.chunk > INT4_EXACT.chunk  # non-default plan wins
        assert best.mae_per_extraction <= 0.5

    def test_every_ranked_plan_respects_budget(self):
        for budget in (0.0, 0.1, 0.5):
            for r in rank_plans(4, 4, error_budget=budget):
                assert r.mae_per_extraction <= budget

    def test_unsatisfiable_budget_raises_with_guidance(self):
        # restricted to single-column plans, 6-bit operands only have
        # squeezed (inexact) plans, so a zero budget is unsatisfiable
        single = enumerate_specs(6, 6, n_columns_choices=(1,))
        with pytest.raises(ValueError, match="error budget"):
            select_plan(6, 6, error_budget=0.0, specs=single)

    def test_budget_zero_a8w8_selects_exact_column_plan(self):
        """The headline: 8-bit operands are exactly servable via columns."""
        best = select_plan(8, 8, error_budget=0.0)
        assert best.spec.n_columns > 1 and best.spec.provably_exact
        assert best.mae_per_extraction == 0.0

    def test_report_json_roundtrips(self):
        import json

        r = select_plan(4, 4)
        blob = json.loads(json.dumps(r.to_json()))
        assert blob["plan"] == r.name and blob["correction"] == r.spec.correction


class TestAutotune:
    def test_blocks_filtered_to_spec_chunk(self):
        for b in candidate_blocks(INT4_MR_OVERPACKED, 64, 256, 64):
            assert b[2] % INT4_MR_OVERPACKED.chunk == 0

    def test_sweep_times_and_sorts(self):
        timings = autotune_block(
            INT4_EXACT, (16, 64, 16),
            blocks=[(16, 16, 32), (16, 16, 64)],
            interpret=True, warmup=0, iters=1,
        )
        assert len(timings) == 2
        assert timings[0].us_per_call <= timings[1].us_per_call

    def test_rank_with_autotune_attaches_blocks(self):
        specs = enumerate_specs(4, 4, corrections=("full",),
                                n_pairs_choices=(2, 4))
        ranked = rank_plans(4, 4, specs=specs, autotune=True,
                            shape=(16, 64, 16), interpret=True)
        assert all(r.block is not None and r.us_per_call is not None
                   for r in ranked)


CFG = dataclasses.replace(get_config("qwen1.5-110b", smoke=True),
                          dtype="float32")
PARAMS = T.init_params(jax.random.PRNGKey(0), CFG)


class TestServingIntegration:
    def test_plan_table_covers_exactly_the_packable_weights(self):
        table = plan_linear_layers(PARAMS)
        assert set(table) == {p for p, _ in iter_packable_weights(PARAMS)}
        assert table  # smoke config has packable layers

    def test_quantize_for_serving_routes_plans(self):
        table = plan_linear_layers(PARAMS)
        tuned = quantize_for_serving(PARAMS, "dsp_tuned", plans=table)
        leaves = [
            (p, leaf) for p, leaf in _walk(tuned)
            if is_dsp_tuned_leaf(leaf)
        ]
        assert {p for p, _ in leaves} == set(table)
        for p, leaf in leaves:
            assert leaf.spec == table[p].spec
            assert leaf.values.dtype == jnp.int8

    def test_tuned_leaf_is_jit_transparent(self):
        leaf = DspTunedLeaf(
            values=jnp.ones((32, 8), jnp.int8),
            scale=jnp.ones((1, 8), jnp.float32),
            spec=INT4_EXACT,
        )
        y = jax.jit(lambda p, x: apply_linear(p, x, LinearSpec("dsp_tuned")))(
            {"w": leaf}, jnp.ones((4, 32), jnp.float32)
        )
        assert y.shape == (4, 8)

    def test_tuned_apply_matches_per_call_dsp_packed(self):
        from repro.core.quantize import quantize_signed

        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.standard_normal((64, 48)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal((5, 64)).astype(np.float32))
        spec = INT4_MR_OVERPACKED
        wq = quantize_signed(w, bits=4, axis=0)
        leaf = DspTunedLeaf(wq.values.astype(jnp.int8), wq.scale, spec)
        tuned = apply_linear({"w": leaf}, x, LinearSpec("dsp_tuned"))
        percall = apply_linear(
            {"w": w}, x, LinearSpec("dsp_packed", dsp_spec=spec)
        )
        np.testing.assert_allclose(
            np.asarray(tuned), np.asarray(percall), atol=1e-4
        )

    def test_engine_runs_tuned_plans_end_to_end(self):
        eng = Engine(CFG, PARAMS, ServeConfig(
            n_slots=2, max_len=32, prefill_chunk=4, quant_mode="dsp_tuned",
        ))
        assert eng.plan_table
        assert any(r.spec != INT4_EXACT for r in eng.plan_table.values())
        out = eng.generate([[5, 6, 7], [8, 9]], max_new=4)
        assert all(len(t) == 4 for t in out.values())

    def test_engine_serves_a8w8_column_plans_end_to_end(self):
        """plan_bits=(8, 8): every selected plan is column-packed (no
        single-word a8w8 plan exists) and decode runs it end to end."""
        eng = Engine(CFG, PARAMS, ServeConfig(
            n_slots=2, max_len=32, prefill_chunk=4, quant_mode="dsp_tuned",
            plan_bits=(8, 8), error_budget=0.0,
        ))
        assert eng.plan_table
        assert all(r.spec.n_columns > 1 and r.spec.provably_exact
                   for r in eng.plan_table.values())
        out = eng.generate([[5, 6, 7], [8, 9]], max_new=4)
        assert all(len(t) == 4 for t in out.values())

    def test_engine_budget_zero_serves_exact_plans(self):
        eng = Engine(CFG, PARAMS, ServeConfig(
            n_slots=2, max_len=32, prefill_chunk=4, quant_mode="dsp_tuned",
            error_budget=0.0,
        ))
        assert all(r.mae_per_extraction == 0 for r in eng.plan_table.values())
        # exact packed arithmetic == the plain quantized path: greedy tokens
        # match the dsp_packed engine with the exact preset
        ref_eng = Engine(CFG, PARAMS, ServeConfig(
            n_slots=2, max_len=32, prefill_chunk=4, quant_mode="dsp_packed",
        ))
        prompts = [[5, 6, 7], [8, 9]]
        assert eng.generate(prompts, max_new=4) == ref_eng.generate(
            prompts, max_new=4
        )


def _walk(tree, path=""):
    if isinstance(tree, dict) and not is_dsp_tuned_leaf(tree):
        for k, v in tree.items():
            yield from _walk(v, f"{path}/{k}")
    else:
        yield path, tree
