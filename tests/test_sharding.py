"""Sharding policy rules + an 8-device subprocess dry-run smoke + elastic
resharding restore (different device count than saved)."""

import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import (
    batch_pspec,
    cache_pspec,
    fsdp_axes,
    linear_partition,
    param_pspec,
)


class FakeMesh:
    """Duck-typed mesh for rule tests (shape dict + axis names)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_column_parallel_qkv():
    spec = param_pspec("groups/attn/wq/w", (80, 8192, 8192), MESH1)
    assert spec == P(None, "data", "model")


def test_row_parallel_out():
    spec = param_pspec("groups/attn/wo/w", (80, 8192, 8192), MESH2)
    assert spec == P(None, "model", ("pod", "data"))


def test_embed_vocab_parallel():
    assert param_pspec("embed/w", (152064, 8192), MESH1) == P("model", "data")


def test_norms_replicated():
    assert param_pspec("groups/ln1/scale", (80, 8192), MESH1) == P(None, None)


def test_expert_parallel_moe():
    spec = param_pspec("groups/moe/up", (40, 16, 6144, 10752), MESH1)
    assert spec[1] == "model"  # expert axis on model (EP)


def test_indivisible_dims_replicate():
    # whisper vocab 51866 is not divisible by 16: must not shard on model
    spec = param_pspec("lm_head/w", (1280, 51866), MESH1)
    assert "model" not in spec


def test_cache_seq_parallel_for_batch1():
    # long_500k: batch=1 -> shard the sequence axis (SP)
    spec = cache_pspec(MESH1, (4, 1, 524288, 8, 128), batch=1)
    assert spec[2] == "data"
    assert spec[1] is None


def test_cache_batch_parallel():
    spec = cache_pspec(MESH1, (40, 128, 32768, 8, 128), batch=128)
    assert spec[1] == "data"


def test_fsdp_axes_with_and_without_pod():
    assert fsdp_axes(MESH1) == ("data",)
    assert fsdp_axes(MESH2) == ("pod", "data")


def test_linear_partition_exact_token_match():
    # Megatron conventions shared with the serving TP wrapper
    assert linear_partition("groups/attn/wq/w") == "col"
    assert linear_partition("groups/mlp/up/w") == "col"
    assert linear_partition("lm_head/w") == "col"
    assert linear_partition("groups/attn/wo/w") == "row"
    assert linear_partition("groups/mlp/down/w") == "row"
    # unnamed roles replicate
    assert linear_partition("groups/ln1/scale") is None
    assert linear_partition("groups/moe/router/w") is None
    # exact token matching, never substring: 'groups' must not match
    # 'up' ("§Perf iteration 7" — the bug col-sharded every stacked
    # weight), nor 'wo_gated' match 'wo'
    assert linear_partition("groups/groupnorm/w") is None
    assert linear_partition("upstream/w") is None


def test_batch_pspec_divisibility():
    assert batch_pspec(MESH1, 128) == P("data", None)
    assert batch_pspec(MESH2, 64) == P(("pod", "data"), None)
    # indivisible batch replicates rather than padding implicitly
    assert batch_pspec(MESH1, 7) == P(None, None)
    assert batch_pspec(MESH2, 16) == P(None, None)  # 16 % 32 != 0


def test_cache_pspec_both_meshes():
    # attn KV (G, B, S, kv, hd): batch over the composite fsdp axis
    spec = cache_pspec(MESH2, (40, 64, 32768, 8, 128), batch=64)
    assert spec[1] == ("pod", "data")
    # batch=1 falls back to sequence parallelism on the same mesh
    spec = cache_pspec(MESH2, (4, 1, 524288, 8, 128), batch=1)
    assert spec[1] is None and spec[2] == ("pod", "data")
    # state caches (G, B, feat): batch on fsdp, biggest feature on model
    spec = cache_pspec(MESH1, (4, 128, 4096), batch=128, path="mamba")
    assert spec[1] == "data" and spec[2] == "model"


@pytest.mark.slow
def test_subprocess_8dev_dryrun_smoke(tmp_path):
    """End-to-end pjit on 8 fake devices in a subprocess (smoke config)."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import transformer as T
        from repro.models.registry import get_config
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.runtime.sharding import param_shardings
        from repro.launch.steps import make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("qwen1.5-110b", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        shard = param_shardings(params, mesh)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, shard)
        state = {"params": params, "opt": adamw_init(params)}
        step = jax.jit(make_train_step(cfg, AdamWConfig(), mesh))
        batch = {
            "tokens": jnp.zeros((8, 16), jnp.int32),
            "labels": jnp.zeros((8, 16), jnp.int32),
        }
        with mesh:
            state, metrics = step(state, batch)
        assert jnp.isfinite(metrics["loss"]), metrics
        print("SUBPROCESS_OK", float(metrics["loss"]))
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=480,
    )
    assert "SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_elastic_reshard_restore(tmp_path):
    """Checkpoint saved from 1-device state restores onto an 8-device mesh
    (elastic restart), bit-exactly."""
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.models import transformer as T
        from repro.models.registry import get_config
        from repro.runtime.sharding import param_shardings

        cfg = get_config("qwen1.5-110b", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        ck = Checkpointer(r"{tmp_path}")
        ck.save(1, params)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shard = param_shardings(params, mesh)
        restored, _ = ck.restore(1, params, shardings=shard)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ndev = {{len(s.device_set) for s in jax.tree.leaves(jax.tree.map(lambda x: x.sharding, restored))}}
        assert max(ndev) == 8, ndev
        print("RESHARD_OK")
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=480,
    )
    assert "RESHARD_OK" in out.stdout, out.stderr[-2000:]
