"""Third compute model for the differential suites: a numpy int64 DSP
simulation of the pair-packed dot path, built on the ``core.packing``
primitives (``PackingConfig`` offsets, ``pack_activations``/``pack_weights``,
``mul_lsbs``, ``sign_extend``) with the int32 accumulator modeled
EXPLICITLY — every packed partial sum is wrapped to 32 bits before
extraction, exactly like the jnp/Pallas int32 lanes wrap.

This is deliberately an independent implementation: it shares no packing or
extraction code with ``kernels/ref.py`` (which the Pallas kernel reuses), so
"simulator == ref == kernel" in the fuzz/parity suites is a real three-way
cross-check, not one code path asserted against itself.  The packing layout
is expressed through a :class:`PackingConfig` (the paper's Eqn. 4 notation):
one pair-packed word is the outer product of the operand vectors
``(a_even, a_odd)`` × ``(w_odd, w_even)`` at offsets ``(0, p)`` each, whose
shared middle field at offset ``p`` accumulates the pair's dot-product
contribution.  Multi-DSP column plans run one such word stream per
activation bit-slice and recombine extracted fields at the slice offsets.
"""

from __future__ import annotations

import numpy as np

from repro.core.packing import (
    PackingConfig,
    mul_lsbs,
    pack_activations,
    pack_weights,
    sign_extend,
)
from repro.kernels.ref import PackedDotSpec

__all__ = ["pair_packing_config", "simulate_packed_matmul"]


def pair_packing_config(spec: PackedDotSpec) -> PackingConfig:
    """The :class:`PackingConfig` of ONE column's pair-packed word.

    ``a_offsets = w_offsets = (0, p)`` puts the outer product's two middle
    results on the same offset ``p`` — the dot-product trick — with the
    cross terms at 0 and 2p.  Activation widths are the per-column slice
    width, weights the full signed width.
    """
    ca = spec.col_bits_a
    return PackingConfig(
        a_widths=(ca, ca),
        w_widths=(spec.bits_w, spec.bits_w),
        a_offsets=(0, spec.p),
        w_offsets=(0, spec.p),
        delta=spec.delta,
    )


def _wrap32(v: np.ndarray) -> np.ndarray:
    """Model the int32 accumulator: keep 32 bits, two's complement."""
    return sign_extend(v, 32)


def _extract(spec: PackedDotSpec, partial32: np.ndarray,
             contam: np.ndarray | None) -> np.ndarray:
    """Middle-field extraction per the spec's correction scheme (int64
    mirror of the semantics, written independently of ``ref``)."""
    we = spec.extract_width
    if spec.rounds_half_up:
        t = ((partial32 >> np.int64(spec.p - 1)) + np.int64(1)) >> np.int64(1)
    else:  # naive floor extraction
        t = partial32 >> np.int64(spec.p)
    e = sign_extend(t, we)
    if spec.uses_mr:
        e = sign_extend(e - (contam << np.int64(we - spec.mr_bits)), we)
    return e


def simulate_packed_matmul(spec: PackedDotSpec, x_u: np.ndarray,
                           w_s: np.ndarray) -> np.ndarray:
    """(M, K) unsigned × (K, N) signed → (M, N) int32, the DSP-sim way.

    Ragged K is zero-padded to ``spec.chunk`` like the other two models.
    """
    x = np.asarray(x_u, dtype=np.int64)
    w = np.asarray(w_s, dtype=np.int64)
    m, k = x.shape
    n = w.shape[1]
    pad = (-k) % spec.chunk
    if pad:
        x = np.pad(x, ((0, 0), (0, pad)))
        w = np.pad(w, ((0, pad), (0, 0)))
        k += pad
    cfg = pair_packing_config(spec)
    mr_mask = np.int64((1 << spec.mr_bits) - 1)

    # Packed weight words are shared by every column: W = w_odd + w_even<<p.
    ws = w.reshape(k // 2, 2, n)
    w_words = pack_weights(cfg, np.stack([ws[:, 1, :], ws[:, 0, :]], axis=-1))

    acc = np.zeros((m, n), dtype=np.int64)
    ca = spec.col_bits_a
    col_mask = np.int64((1 << ca) - 1)
    for j in range(spec.n_columns):
        xj = (x >> np.int64(j * ca)) & col_mask
        xa = xj.reshape(m, k // 2, 2)
        a_words = pack_activations(cfg, xa)  # A = a_even + a_odd<<p
        for c in range(k // spec.chunk):
            sl = slice(c * spec.n_pairs, (c + 1) * spec.n_pairs)
            # n_pairs wide multiply-accumulates into ONE int32 word:
            partial = np.einsum(
                "mp,pn->mn", a_words[:, sl], w_words[sl, :], dtype=np.int64
            )
            partial32 = _wrap32(partial)
            contam = None
            if spec.uses_mr:
                # Σ a_odd·w_even mod 2**mr_bits — the high field's LSBs
                # (paper Eqns. 8/9), recomputed exactly via mul_lsbs.
                contam = np.zeros((m, n), dtype=np.int64)
                for pair in range(sl.start, sl.stop):
                    contam = contam + mul_lsbs(
                        xa[:, pair, 1][:, None], ws[pair, 0, :][None, :],
                        spec.mr_bits,
                    )
                contam &= mr_mask
            acc = acc + (_extract(spec, partial32, contam) << np.int64(j * ca))
    return _wrap32(acc).astype(np.int32)
