import sys
import os

sys.path.insert(0, os.path.dirname(__file__))  # proptest shim importable


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end tests")


# XLA:CPU's JIT crashes (SIGSEGV inside backend_compile) once a single
# process accumulates ~1300 tests' worth of compiled executables — the
# crash lands in whatever innocent test compiles next.  Dropping the jit
# caches every few hundred tests keeps the full suite inside one process.
_CLEAR_CACHES_EVERY = 200
_test_counter = {"n": 0}


def pytest_runtest_teardown(item):
    _test_counter["n"] += 1
    if _test_counter["n"] % _CLEAR_CACHES_EVERY == 0:
        import jax

        jax.clear_caches()
