import sys
import os

sys.path.insert(0, os.path.dirname(__file__))  # proptest shim importable


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end tests")
