"""Addition packing (paper §VII, Table III)."""

import numpy as np
import pytest

from repro.core.addpack import (
    AddPackConfig,
    accumulate,
    extract_lanes,
    five_by_nine,
    lane_add_expected,
    pack_lanes,
    packed_lane_add,
)


def test_lane_layout():
    cfg = five_by_nine()
    assert cfg.offsets == (0, 9, 18, 27, 36)
    assert cfg.bits_used() == 45
    with pytest.raises(ValueError):
        AddPackConfig((9,) * 6)  # 54 bits > 48


def test_pack_extract_roundtrip():
    cfg = five_by_nine()
    rng = np.random.default_rng(0)
    x = rng.integers(-256, 256, (64, 5))
    np.testing.assert_array_equal(extract_lanes(cfg, pack_lanes(cfg, x)), x)


def test_table3_statistics():
    """Paper Table III: MAE 0.51 / EP 51.83% / WCE 1 for a 9-bit lane packed
    with four others, no guards.  Exhaustive over one lane pair + carry-in:
    our measured EP is ~49.9% (uniform operands); MAE == EP/100 and WCE == 1
    in modular lane arithmetic — structure matches, level within 2pp
    (operand distribution in the paper's HW run is unspecified; recorded in
    EXPERIMENTS.md §Paper-deltas)."""
    cfg = AddPackConfig((9, 9), guard_bits=0, total_bits=48)
    a0 = np.arange(512)
    # exhaustive lower-lane pairs; upper lane fixed operands sweep a sample
    lo_x, lo_y = np.meshgrid(a0, a0, indexing="ij")
    rng = np.random.default_rng(0)
    hi_x = rng.integers(-256, 256, lo_x.shape)
    hi_y = rng.integers(-256, 256, lo_x.shape)
    x = np.stack([lo_x.ravel() - 256, hi_x.ravel()], -1)
    y = np.stack([lo_y.ravel() - 256, hi_y.ravel()], -1)
    got = packed_lane_add(cfg, x, y)
    want = lane_add_expected(cfg, x, y)
    diff = np.abs(got[:, 1] - want[:, 1])
    mod = np.minimum(diff, 512 - diff)
    ep = (mod > 0).mean() * 100
    assert mod.max() == 1  # WCE = 1 (Table III)
    assert abs(ep - 51.83) < 2.5  # level close to the paper's 51.83%
    assert (got[:, 0] == want[:, 0]).all()  # lowest lane exact (paper claim a)


def test_guard_bit_blocks_carry():
    cfg = AddPackConfig((8, 8), guard_bits=1)
    x = np.array([[255 - 256, 3]])  # lower lane at max field pattern
    y = np.array([[1, 4]])
    np.testing.assert_array_equal(
        packed_lane_add(cfg, x, y), lane_add_expected(cfg, x, y)
    )


def test_snn_accumulate_exact_with_chunking():
    cfg = AddPackConfig((10,) * 4, guard_bits=2)
    rng = np.random.default_rng(1)
    terms = rng.integers(-4, 5, (8, 64, 4))
    got = accumulate(cfg, terms)
    np.testing.assert_array_equal(got, terms.sum(-2))


def test_packing_density():
    assert five_by_nine().packing_density() == 45 / 48
