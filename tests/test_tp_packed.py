"""Tensor-parallel packed arithmetic, in-process (no mesh needed).

The word-space reduction invariant (DESIGN.md §4) at the math level:
summing per-shard packed partial words and extracting ONCE must be
bit-identical to a single device running the widened spec
(``kernels.ref.widen_for_shards``).  Mesh/engine-level bit-identity
lives in ``tests/test_tp_serving.py`` (subprocess host meshes); this
file pins the algebra and the build-time legality surface.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.runtime.tp_packed import TpLinear, _widened_grouping
from repro.tuning import enumerate_specs, rank_plans, select_plan

jax.config.update("jax_platform_name", "cpu")


# ---- widen_for_shards ------------------------------------------------------


def test_widen_identity_at_one_shard():
    assert ref.widen_for_shards(ref.INT4_EXACT, 1) is ref.INT4_EXACT
    with pytest.raises(ValueError, match="n_shards"):
        ref.widen_for_shards(ref.INT4_EXACT, 0)


def test_widen_multiplies_pairs_only():
    spec = select_plan(4, 4, shard_groups=2).spec
    wide = ref.widen_for_shards(spec, 2)
    assert wide.n_pairs == 2 * spec.n_pairs
    # extraction parameters are untouched: extracting the psum'd word
    # with the original spec is the same operation
    assert (wide.p, wide.correction, wide.n_columns) == (
        spec.p, spec.correction, spec.n_columns)


def test_widen_rejection_cites_certificate_clause():
    """The presets sit at the single-word accumulation ceiling, so ANY
    row sharding of them must be rejected — with the violated clause
    named, like an illegal n_pairs."""
    for spec in (ref.INT4_EXACT, ref.INT4_MR_OVERPACKED, ref.INT2_EXACT):
        with pytest.raises(ValueError) as e:
            ref.widen_for_shards(spec, 2)
        msg = str(e.value)
        assert "cannot be row-sharded 2 ways" in msg
        assert "certificate clause" in msg


# ---- word-path algebra: shard-sum == widened single device -----------------


def _sharded_word_matmul(x_u, w_s, spec, S):
    """Mirror ``tp_packed._tuned_row``'s word path with the psums replaced
    by explicit per-shard sums (pure math, no shard_map)."""
    pw = ref.pack_weight_words(w_s, spec)
    words = _widened_grouping(pw.words, S, 0, 1)
    wsc = None if pw.wsc is None else _widened_grouping(pw.wsc, S, 0, 1)
    m, k = x_u.shape
    c, merged, n = words.shape
    npair = spec.n_pairs
    acc = jnp.zeros((m, n), jnp.int32)
    for j in range(spec.n_columns):
        xa = ref.slice_column(x_u, spec, j).reshape(m, k // 2, 2)
        a_words = (xa[:, :, 0] + (xa[:, :, 1] << spec.p)).reshape(m, c, merged)
        xa4 = xa.reshape(m, c, merged, 2)
        partial = jnp.zeros((c, m, n), jnp.int32)
        contam = jnp.zeros((c, m, n), jnp.int32) if spec.uses_mr else None
        for d in range(S):  # one iteration per "device"
            sl = slice(d * npair, (d + 1) * npair)
            partial = partial + jax.lax.dot_general(
                a_words[:, :, sl], words[:, sl, :],
                (((2,), (1,)), ((1,), (0,))),
                preferred_element_type=jnp.int32,
            )
            if spec.uses_mr:
                contam = contam + ref.contamination_terms(
                    xa4[:, :, sl, :], wsc[:, sl], spec
                )
        if spec.uses_mr:
            # residues mod 2**mr_bits compose across shards
            contam = contam & jnp.int32(ref.contamination_mask(spec))
        field = ref.extract_accumulated_field(partial, spec, contam)
        col = jnp.sum(field, axis=0)
        shift = spec.column_shift(j)
        acc = acc + (col << shift if shift else col)
    return acc


@pytest.mark.parametrize("shards", [2, 4])
def test_shard_sum_matches_widened_spec_bitwise(shards):
    """Per-shard word accumulation + one extraction == the widened plan on
    one device, bit-for-bit — including mr contamination composition
    (the planner's shard-aware pick at 4,4 is an mr multi-column plan,
    so the hard case is exercised)."""
    spec = select_plan(4, 4, shard_groups=shards).spec
    wide = ref.widen_for_shards(spec, shards)
    rng = np.random.default_rng(0)
    k = shards * spec.chunk * 2
    x_u = jnp.asarray(rng.integers(0, 2 ** spec.bits_a, (5, k)), jnp.int32)
    w_s = jnp.asarray(
        rng.integers(-(2 ** (spec.bits_w - 1)), 2 ** (spec.bits_w - 1),
                     (k, 7)), jnp.int32)
    got = _sharded_word_matmul(x_u, w_s, spec, shards)
    want = ref.ref_packed_matmul(x_u, w_s, wide)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert spec.uses_mr  # the planner pick really exercises mr psum


def test_shard_sum_matches_widened_spec_non_mr():
    """Same identity on an exact-spacing (non-mr) plan.

    Enumerated plans sit at the single-word accumulation ceiling, so the
    per-device spec is the enumerated one NARROWED (n_pairs / S) — which
    is exactly how the shard-aware planner serves them: widening the
    narrowed spec recovers the enumerated plan."""
    wide_src = next(
        s for s in enumerate_specs(4, 4)
        if not s.uses_mr and s.n_pairs % 2 == 0
    )
    spec = dataclasses.replace(wide_src, n_pairs=wide_src.n_pairs // 2)
    wide = ref.widen_for_shards(spec, 2)
    assert wide == wide_src
    rng = np.random.default_rng(1)
    k = 2 * spec.chunk * 3
    x_u = jnp.asarray(rng.integers(0, 16, (4, k)), jnp.int32)
    w_s = jnp.asarray(rng.integers(-8, 8, (k, 6)), jnp.int32)
    got = _sharded_word_matmul(x_u, w_s, spec, 2)
    want = ref.ref_packed_matmul(x_u, w_s, wide)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _widens_ok(spec, s):
    try:
        ref.widen_for_shards(spec, s)
        return True
    except ValueError:
        return False


# ---- shard-aware planner ---------------------------------------------------


def test_rank_plans_shard_groups_only_emits_shardable_plans():
    for s in (2, 8):
        ranked = rank_plans(4, 4, shard_groups=s)
        assert ranked, f"no shardable a4w4 plans at shard_groups={s}"
        for r in ranked:
            assert _widens_ok(r.spec, s), r.spec.name()


def test_select_plan_no_int4_fallback_under_sharding():
    """The INT4_EXACT preset is un-shardable, so the shard-aware search
    must never fall back to it."""
    r = select_plan(4, 4, shard_groups=8)
    assert r.spec.name() != ref.INT4_EXACT.name()
    assert _widens_ok(r.spec, 8)


def test_select_plan_reports_unshardable_width_family():
    """a8w8 has no plan whose widened spec fits one word 8 ways — the
    search fails loudly, naming the sharding, instead of silently
    narrowing the served widths."""
    with pytest.raises(ValueError, match="sharded 8 ways"):
        select_plan(8, 8, error_budget=0.0, shard_groups=8)


# ---- TpLinear pytree -------------------------------------------------------


def test_tp_linear_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        TpLinear({}, kind="diag", mesh=None, n_shards=2)


def test_tp_linear_pytree_roundtrip_keeps_aux_static():
    inner = {"w_f32": jnp.ones((4, 4)), "scale": jnp.ones((1, 4)),
             "packed": jnp.zeros((2, 4), jnp.uint8)}
    w = TpLinear(inner, kind="row", mesh=None, n_shards=2)
    leaves, treedef = jax.tree_util.tree_flatten(w)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (back.kind, back.n_shards, back.axis) == ("row", 2, "model")
    # mapping over the tree touches the inner arrays, not the aux
    doubled = jax.tree.map(lambda a: a * 2, w)
    np.testing.assert_array_equal(
        np.asarray(doubled.inner["w_f32"]), 2 * np.ones((4, 4)))
