"""Differential fuzz suite: the three compute models of the packed dot path
cross-checked on matched specs (paper §V/§VI arithmetic, all correction
schemes, single- and multi-DSP-column plans):

* the ``core.packing``-primitive DSP simulator (``tests/dsp_sim.py``) —
  numpy int64 with an explicitly wrapped int32 accumulator;
* the jnp reference ``kernels.ref.ref_packed_matmul``;
* the Pallas kernel ``kernels.packed_matmul.packed_matmul``.

Structure:

* ``TestSimulatorVsReference`` — ``DIFF_FUZZ_CASES`` (default 200) seeded
  random cases: random spec from the enumerator's full emission over six
  width pairs (including asymmetric a8w4/a4w8 and the column-packed a8w8
  family), random ragged shape, full-range operands; asserts BIT parity
  between simulator and reference, plus the statically certified
  worst-case error bound (``analysis.verify``) vs the exact integer matmul.  The first ``SMOKE_CASES`` run in the fast
  lane; the long tail carries the ``slow`` marker (CI runs it in the
  scheduled/labelled slow lane).
* ``TestKernelInTheLoop`` — a deterministic spec subset (every scheme ×
  column count) through the actual Pallas kernel: kernel == ref == sim,
  bit-for-bit.  Kept small because each (spec, shape) pair is a separate
  interpret-mode compile.
* ``TestMeasuredErrorVsScorePrediction`` — seeded fuzz measurements of MAE
  per extraction vs ``tuning.score``'s prediction for the same plan.  For
  plans the scorer PROVES exact (algebraically or by exhaustive
  enumeration) the assertion is strict: measured error must be zero.  For
  sampled predictions the measurement must agree within a documented
  sampling margin — both the prediction and the fuzz measurement are
  finite-sample estimates of the same mean, so exact dominance is not a
  meaningful invariant, but large excursions would flag a real model
  mismatch.

Every case is seeded through ``np.random.default_rng((tag, case))`` so CI
failures reproduce locally by case id.
"""

from __future__ import annotations

import os

import numpy as np
import pytest

from dsp_sim import simulate_packed_matmul

from repro.analysis.verify import certify_spec
from repro.kernels import ref
from repro.kernels.packed_matmul import packed_matmul
from repro.tuning import enumerate_specs
from repro.tuning.score import spec_error_stats

N_CASES = int(os.environ.get("DIFF_FUZZ_CASES", "200"))
SMOKE_CASES = 12  # unmarked prefix: always runs, even in the fast CI lane

WIDTH_PAIRS = ((2, 2), (4, 4), (4, 8), (6, 6), (8, 4), (8, 8))
POOL = [s for a, w in WIDTH_PAIRS for s in enumerate_specs(a, w)]
COLUMN_POOL = [s for s in POOL if s.n_columns > 1]


def _draw_case(case: int):
    """Seeded (spec, x, w) draw; every other case forces a column plan so
    the new axis gets half the fuzz volume."""
    rng = np.random.default_rng((0xD5B, case))
    pool = COLUMN_POOL if case % 2 else POOL
    spec = pool[int(rng.integers(0, len(pool)))]
    m = int(rng.integers(1, 9))
    n = int(rng.integers(1, 17))
    k = int(rng.integers(1, 3 * spec.chunk + 2))  # ragged K, crosses chunks
    x = rng.integers(0, 1 << spec.bits_a, (m, k)).astype(np.int32)
    w = rng.integers(
        -(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, n)
    ).astype(np.int32)
    return spec, x, w


def _certified_error_bound(spec, k: int) -> int:
    """Certified worst-case |packed − exact| for a (M, k)·(k, N) matmul
    under ``spec``: the static verifier's per-extraction WCE scales
    linearly with the number of chunk extractions (each extraction's
    low-field residue is independent).  Strictly tighter than the old
    hand-derived ``2**mr_bits · Σ 2**column_shift`` envelope — the
    certificate's interval endpoints are realizable (the verifier carries
    a witness), so this bound has no slack to hide drift in."""
    n_extractions = -(-k // spec.chunk)
    return n_extractions * certify_spec(spec).wce_per_extraction


_CASE_PARAMS = [
    pytest.param(i, marks=() if i < SMOKE_CASES else pytest.mark.slow)
    for i in range(N_CASES)
]


class TestSimulatorVsReference:
    @pytest.mark.parametrize("case", _CASE_PARAMS)
    def test_sim_bit_equals_ref(self, case):
        spec, x, w = _draw_case(case)
        sim = simulate_packed_matmul(spec, x, w)
        got = np.asarray(ref.ref_packed_matmul(x, w, spec))
        np.testing.assert_array_equal(
            sim, got, err_msg=f"case {case}: {spec.name()}"
        )
        # and neither model drifts past the certified worst case
        exact = np.asarray(ref.ref_quantized_matmul(x, w))
        bound = _certified_error_bound(spec, x.shape[1])
        assert np.abs(got - exact).max() <= bound, (case, spec.name())
        if certify_spec(spec).exact:
            # the certificate's exact verdict covers strictly more plans
            # than the constructor's algebraic provably_exact predicate
            np.testing.assert_array_equal(got, exact)


def _kernel_representatives():
    """One plan per (scheme, n_columns) combination the enumerator emits —
    kept small because every (spec, shape) is a separate kernel compile."""
    seen, reps = set(), []
    for spec in POOL:
        key = (spec.correction, spec.n_columns)
        if key not in seen:
            seen.add(key)
            reps.append(spec)
    return reps


class TestKernelInTheLoop:
    """The Pallas kernel joins the differential: one representative plan
    per (scheme, n_columns) combination the enumerator emits."""

    @pytest.mark.parametrize(
        "spec", _kernel_representatives(), ids=lambda s: s.name()
    )
    def test_three_way_parity(self, spec):
        rng = np.random.default_rng((0xD5C, spec.p, spec.n_pairs))
        m, n = 5, 9
        k = 2 * spec.chunk + 1  # ragged
        x = rng.integers(0, 1 << spec.bits_a, (m, k)).astype(np.int32)
        w = rng.integers(
            -(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, n)
        ).astype(np.int32)
        kern = np.asarray(
            packed_matmul(x, w, spec=spec, block=(8, 16, spec.chunk),
                          interpret=True)
        )
        got = np.asarray(ref.ref_packed_matmul(x, w, spec))
        sim = simulate_packed_matmul(spec, x, w)
        np.testing.assert_array_equal(kern, got, err_msg=spec.name())
        np.testing.assert_array_equal(sim, got, err_msg=spec.name())


class TestMeasuredErrorVsScorePrediction:
    """Fuzz-measured MAE per extraction vs the scorer's prediction.

    ``REPRESENTATIVES`` spans every scheme at both column regimes.  For
    each, ``_measure`` aggregates error over several seeded matmuls
    (hundreds-to-thousands of output samples), normalized per extraction
    exactly like ``SpecScore.mae_per_extraction``."""

    REPRESENTATIVES = [
        spec for spec in POOL
        if (spec.bits_a, spec.bits_w) in ((4, 4), (8, 8))
    ][::7]  # deterministic thinning: every 7th plan of the a4w4/a8w8 family

    @staticmethod
    def _measure(spec, n_draws: int = 8):
        abs_err_sum, n_outputs, n_extr = 0.0, 0, 0
        for draw in range(n_draws):
            rng = np.random.default_rng((0xD5D, spec.p, spec.n_pairs, draw))
            m, n = 6, 12
            k = 2 * spec.chunk
            x = rng.integers(0, 1 << spec.bits_a, (m, k)).astype(np.int32)
            w = rng.integers(
                -(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, n)
            ).astype(np.int32)
            got = np.asarray(ref.ref_packed_matmul(x, w, spec))
            exact = np.asarray(ref.ref_quantized_matmul(x, w))
            abs_err_sum += float(np.abs(got - exact).sum())
            n_outputs += got.size
            n_extr = k // spec.chunk
        return abs_err_sum / n_outputs / n_extr

    @pytest.mark.parametrize(
        "spec", REPRESENTATIVES, ids=lambda s: s.name()
    )
    def test_measured_mae_within_prediction(self, spec):
        score = spec_error_stats(spec)
        measured = self._measure(spec)
        proven_exact = spec.provably_exact or (
            score.exhaustive and score.mae == 0.0
        )
        if proven_exact:
            # a proof is a proof: one wrong bit anywhere fails the fuzz
            assert measured == 0.0, spec.name()
        else:
            # both numbers estimate the same per-extraction mean; 1.5x plus
            # a small absolute term covers the finite-sample noise of the
            # fuzz draw (seeded: deterministic, so no flakes)
            predicted = score.mae_per_extraction
            assert measured <= 1.5 * predicted + 0.05, (
                f"{spec.name()}: measured {measured:.4f} vs "
                f"predicted {predicted:.4f}"
            )
