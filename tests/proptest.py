"""Tiny property-testing shim (hypothesis is unavailable offline).

Provides ``@given(...)`` decorators with seeded strategies.  Each strategy
is a callable ``rng -> value``; the decorated test runs ``N_CASES`` times
with derandomized seeds so failures are reproducible.  Shrinking is not
implemented; the failing seed is reported instead.
"""

from __future__ import annotations

import os

import numpy as np

N_CASES = int(os.environ.get("PROPTEST_CASES", "25"))


def integers(lo: int, hi: int):
    return lambda rng: int(rng.integers(lo, hi + 1))


def sampled_from(options):
    return lambda rng: options[int(rng.integers(0, len(options)))]


def booleans():
    return lambda rng: bool(rng.integers(0, 2))


def tuples(*elems):
    """Draw one value from each strategy: ``tuples(integers(0,3), booleans())``."""
    return lambda rng: tuple(e(rng) for e in elems)


def lists(elem, min_size: int, max_size: int):
    def strat(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elem(rng) for _ in range(n)]

    return strat


def arrays(shape_strat, lo, hi, dtype=np.int64):
    def strat(rng):
        shape = shape_strat(rng) if callable(shape_strat) else shape_strat
        return rng.integers(lo, hi + 1, size=shape).astype(dtype)

    return strat


def floats_array(shape, scale=1.0):
    return lambda rng: (rng.standard_normal(shape) * scale).astype(np.float32)


def given(**strategies):
    def deco(fn):
        # zero-arg wrapper (no functools.wraps: pytest must NOT see the
        # strategy parameters, or it would try to inject them as fixtures)
        def wrapper(*args):  # *args carries `self` for methods only
            for case in range(N_CASES):
                rng = np.random.default_rng((hash(fn.__name__) & 0xFFFF, case))
                drawn = {k: s(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn)
                except Exception:
                    print(f"[proptest] {fn.__name__} failed on case {case}: {drawn}")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
