"""Quantizer unit tests: ranges, roundtrip error bounds, STE gradients,
zero-point folding identity."""

import jax
import jax.numpy as jnp
import numpy as np

from proptest import given, integers

from repro.core.quantize import (
    fake_quant_signed,
    fake_quant_unsigned,
    quantize_signed,
    quantize_unsigned,
    zero_point_correction,
)


def test_unsigned_8bit_uses_the_upper_half_of_the_range():
    """Regression: an int8-stored offset-binary payload saturated every
    8-bit value above the zero point at 127 (float->int8 conversion clamps)
    — the whole upper half of the a8 grid collapsed.  The uint8 store must
    reach it."""
    x = jnp.asarray([[-1.0, -0.5, 0.25, 0.5, 1.0]], jnp.float32)
    q = quantize_unsigned(x, bits=8, axis=-1)
    v = np.asarray(q.values).astype(np.int32)
    assert q.values.dtype == jnp.uint8
    assert v.max() == 255 and v.min() == 1  # full offset-binary swing
    # and the dequantized extremes come back (zp folding intact)
    np.testing.assert_allclose(
        np.asarray(q.dequantize()), np.asarray(x), atol=float(q.scale.max())
    )


@given(bits=integers(2, 8), seed=integers(0, 2**31))
def test_signed_range_and_roundtrip(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32))
    q = quantize_signed(x, bits=bits, axis=-1)
    v = np.asarray(q.values)
    assert v.min() >= -(1 << (bits - 1)) and v.max() <= (1 << (bits - 1)) - 1
    err = np.abs(np.asarray(q.dequantize()) - np.asarray(x))
    assert err.max() <= np.asarray(q.scale).max() * 0.5 + 1e-6


@given(bits=integers(2, 8), seed=integers(0, 2**31))
def test_unsigned_range(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))
    q = quantize_unsigned(x, bits=bits, axis=-1)
    v = np.asarray(q.values)
    assert v.min() >= 0 and v.max() <= (1 << bits) - 1
    assert q.zero_point == 1 << (bits - 1)


def test_zero_point_folding_identity():
    """a·w == a_u·w − zp·Σw — the algebra the packed path relies on."""
    rng = np.random.default_rng(0)
    a = rng.integers(-8, 8, (5, 16)).astype(np.int32)
    w = rng.integers(-8, 8, (16, 7)).astype(np.int32)
    zp = 8
    a_u = a + zp
    direct = a @ w
    folded = a_u @ w - np.asarray(zero_point_correction(jnp.asarray(w), zp))
    np.testing.assert_array_equal(direct, folded)


def test_ste_gradient_identity_inside_range():
    x = jnp.linspace(-0.5, 0.5, 32)
    g = jax.grad(lambda v: jnp.sum(fake_quant_signed(v, 4, -1)))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)


def test_ste_gradient_masked_for_clipped():
    x = jnp.asarray([0.01, 0.02, 10.0])  # 10.0 saturates the absmax scale? no
    # construct explicit saturation: one huge outlier sets the scale; then
    # values beyond qmax*scale would clip. With absmax scaling nothing
    # clips, so gradients stay 1 — assert exactly that invariant instead.
    g = jax.grad(lambda v: jnp.sum(fake_quant_signed(v, 4, -1)))(x)
    assert np.isfinite(np.asarray(g)).all()


def test_fake_quant_unsigned_forward_matches_quantizer():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(64).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(fake_quant_unsigned(x, 4, -1)),
        np.asarray(quantize_unsigned(x, 4, -1).dequantize()),
        atol=1e-6,
    )
