"""Pallas kernel validation: bit-exact vs ref.py oracles across shape/dtype
sweeps, all in interpret mode (CPU container; TPU is the lowering target)."""

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, integers

from repro.kernels import ref
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.ops import int4_matmul_f32, packed_matmul_f32, quantized_matmul_ref
from repro.kernels.packed_matmul import packed_matmul
from repro.kernels.ref import (
    INT2_EXACT,
    INT4_EXACT,
    INT4_MR_OVERPACKED,
    INT4_NAIVE,
    PackedDotSpec,
)

RNG = np.random.default_rng(7)


def _operands(m, k, n, bits=4):
    hi_a = (1 << bits) - 1
    hi_w = 1 << (bits - 1)
    x = RNG.integers(0, hi_a + 1, (m, k)).astype(np.int8)
    w = RNG.integers(-hi_w, hi_w, (k, n)).astype(np.int8)
    return jnp.asarray(x), jnp.asarray(w)


class TestPackedMatmulKernel:
    """Large-shape semantic checks.  Kernel-vs-ref bit parity across every
    enumerated plan / scheme / block shape lives in
    ``test_kernel_parity_matrix.py`` (it replaced the single-spec spot
    checks that used to sit here)."""

    def test_full_correction_kernel_is_exact(self):
        x, w = _operands(128, 256, 128)
        got = packed_matmul(x, w, spec=INT4_EXACT, interpret=True)
        want = ref.ref_quantized_matmul(x, w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_int2_exact(self):
        x, w = _operands(128, 128, 128, bits=2)
        got = packed_matmul(x, w, spec=INT2_EXACT, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.ref_quantized_matmul(x, w))
        )

    def test_naive_reproduces_bias_at_matmul_scale(self):
        """The paper's -1-per-extraction bias accumulates over K chunks."""
        x, w = _operands(128, 512, 128)
        naive = np.asarray(packed_matmul(x, w, spec=INT4_NAIVE, interpret=True))
        exact = np.asarray(ref.ref_quantized_matmul(x, w))
        err = naive - exact
        assert (err <= 0).all()  # bias toward -inf, never positive
        n_extractions = 512 // INT4_NAIVE.chunk
        assert err.min() >= -n_extractions

    def test_mr_overpacked_error_small(self):
        x, w = _operands(256, 512, 128)
        got = np.asarray(packed_matmul(x, w, spec=INT4_MR_OVERPACKED, interpret=True))
        exact = np.asarray(ref.ref_quantized_matmul(x, w))
        err = np.abs(got - exact)
        assert err.mean() < 0.2
        rel = err.mean() / max(np.abs(exact).mean(), 1)
        assert rel < 1e-3

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            PackedDotSpec(bits_a=4, bits_w=4, p=12, n_pairs=8)  # overflows
        with pytest.raises(ValueError):
            PackedDotSpec(bits_a=4, bits_w=4, p=9, n_pairs=4, correction="full")


class TestInt4Kernel:
    @pytest.mark.parametrize("shape", [(128, 128, 128), (128, 256, 384)])
    def test_kernel_vs_oracle(self, shape):
        m, k, n = shape
        x = jnp.asarray(RNG.integers(-128, 128, (m, k)).astype(np.int8))
        w = jnp.asarray(RNG.integers(-8, 8, (k, n)).astype(np.int8))
        packed = ref.pack_int4_weights(w)
        got = int4_matmul(x, packed, interpret=True)
        want = ref.ref_int4_matmul(x, packed)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_pack_unpack_roundtrip(self):
        w = jnp.asarray(RNG.integers(-8, 8, (64, 32)).astype(np.int8))
        np.testing.assert_array_equal(
            np.asarray(ref.unpack_int4_weights(ref.pack_int4_weights(w))),
            np.asarray(w),
        )

    def test_packed_storage_is_half(self):
        w = jnp.zeros((128, 64), jnp.int8)
        assert ref.pack_int4_weights(w).size * 2 == w.size


class TestFloatWrappers:
    @given(m=integers(8, 100), k=integers(16, 200), n=integers(8, 100),
           seed=integers(0, 2**31))
    def test_packed_f32_equals_quant_oracle(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        got = packed_matmul_f32(x, w, use_kernel=False)
        want = quantized_matmul_ref(x, w, bits=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_int4_f32_close_to_dense(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((128, 96)).astype(np.float32))
        from repro.core.quantize import quantize_signed

        wq = quantize_signed(w, bits=4, axis=0)
        packed = ref.pack_int4_weights(wq.values)
        got = np.asarray(int4_matmul_f32(x, packed, wq.scale, use_kernel=True, interpret=True))
        dense = np.asarray(x @ w)
        rel = np.abs(got - dense).mean() / np.abs(dense).mean()
        assert rel < 0.25  # int4-weight quantization noise only


class TestAddpackKernel:
    def test_exact_vs_oracle(self):
        from repro.kernels.addpack_acc import (
            addpack_accumulate,
            ref_addpack_accumulate,
        )

        rng = np.random.default_rng(11)
        terms = jnp.asarray(rng.integers(-2000, 2000, (64, 2, 256)).astype(np.int32))
        got = addpack_accumulate(terms, interpret=True)
        want = ref_addpack_accumulate(terms)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(t=integers(1, 48), seed=integers(0, 2**31))
    def test_random_lengths(self, t, seed):
        from repro.kernels.addpack_acc import (
            addpack_accumulate,
            ref_addpack_accumulate,
        )

        rng = np.random.default_rng(seed)
        terms = jnp.asarray(rng.integers(-4096, 4096, (t, 2, 256)).astype(np.int32))
        got = addpack_accumulate(terms, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref_addpack_accumulate(terms))
        )


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "shape", [(1, 2, 512, 64, 256, 128), (2, 1, 256, 128, 128, 128)]
    )
    def test_matches_oracle(self, shape):
        from repro.kernels.flash_attention import flash_attention, ref_attention

        b, h, s, hd, bq, bk = shape
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.standard_normal((b, h, s, hd)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, h, s, hd)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, h, s, hd)).astype(np.float32))
        got = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref_attention(q, k, v)), atol=5e-6
        )

    def test_causality(self):
        from repro.kernels.flash_attention import flash_attention

        rng = np.random.default_rng(6)
        q = jnp.asarray(rng.standard_normal((1, 1, 256, 64)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 1, 256, 64)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 1, 256, 64)).astype(np.float32))
        base = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
        k2 = k.at[:, :, -1].set(50.0)
        v2 = v.at[:, :, -1].set(50.0)
        pert = flash_attention(q, k2, v2, bq=128, bk=128, interpret=True)
        np.testing.assert_allclose(
            np.asarray(base[:, :, :-1]), np.asarray(pert[:, :, :-1]), atol=1e-6
        )
