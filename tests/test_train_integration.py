"""Integration: loss decreases, checkpoint/restart is bit-exact, data
pipeline is deterministic and restorable, gradient compression converges."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.compression import init_error_feedback

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen1.5-110b", compress=False):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(KEY, cfg)
    state = {"params": params, "opt": adamw_init(params)}
    if compress:
        state["error_buf"] = init_error_feedback(params)
    step = jax.jit(
        make_train_step(cfg, AdamWConfig(lr=1e-3), compress_grads=compress)
    )
    data = SyntheticStream(DataConfig(cfg.vocab_size, 33, 8, seed=1))
    return cfg, state, step, data


def _run(state, step, data, n):
    losses = []
    for i in range(n):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_decreases():
    _, state, step, data = _setup()
    _, losses = _run(state, step, data, 30)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_compressed_grads_still_converge():
    _, state, step, data = _setup(compress=True)
    _, losses = _run(state, step, data, 30)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25


def test_checkpoint_resume_bit_exact(tmp_path):
    _, state, step, data = _setup()
    ck = Checkpointer(str(tmp_path))

    state5, _ = _run(state, step, data, 5)
    ck.save(5, state5, extra={"data": {"step": 5, "seed": 1}})

    # continue 5 more steps directly
    state10, _ = _run(state5, step, data_from(data, 5), 5)

    # restart from checkpoint and replay
    restored, extra = ck.restore(5, state5)
    assert extra["data"]["step"] == 5
    state10b, _ = _run(restored, step, data_from(data, 5), 5)
    for a, b in zip(jax.tree.leaves(state10), jax.tree.leaves(state10b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def data_from(data, start):
    class _Shim:
        def batch_at(self, i):
            return data.batch_at(start + i)

    return _Shim()


def test_checkpointer_atomicity_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3):
        ck.save(s, tree)
    assert ck.all_steps() == [2, 3]  # keep=2 garbage-collected step 1
    assert ck.latest_step() == 3
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(7, {"w": jnp.ones(4)})
    ck.wait()
    assert ck.latest_step() == 7


def test_torn_async_save_invisible_then_recoverable(tmp_path, monkeypatch):
    """A background writer that dies mid-write (disk full before the atomic
    rename) must re-raise at wait(), leave every read path pointing at the
    last COMPLETE step, and not poison the next save of the same step."""
    from repro.checkpoint import checkpointer as C

    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(6.0)}
    ck.save(1, tree, extra={"tag": "live"})

    real_savez, torn = np.savez, {"fail": True}

    def flaky_savez(*args, **kwargs):
        if torn["fail"]:
            raise OSError("No space left on device")
        return real_savez(*args, **kwargs)

    monkeypatch.setattr(C.np, "savez", flaky_savez)
    ck.save_async(2, tree)
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        ck.wait()
    ck.wait()  # the error is surfaced once, not re-raised forever

    # the torn step is invisible to every read path...
    assert any(f.endswith(".tmp") for f in os.listdir(tmp_path))
    assert ck.all_steps() == [1]
    assert ck.latest_step() == 1
    restored, extra = ck.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(6.0))
    assert extra["tag"] == "live"

    # ...and a retry of the SAME step clears the stale tmp and publishes
    torn["fail"] = False
    ck.save(2, tree)
    assert ck.all_steps() == [1, 2]
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))


def test_torn_sync_save_keeps_previous_step_restorable(tmp_path, monkeypatch):
    """Synchronous-path variant: the exception propagates to the caller and
    the previous checkpoint restores bit-exact afterwards."""
    from repro.checkpoint import checkpointer as C

    ck = Checkpointer(str(tmp_path))
    ck.save(3, {"w": jnp.full(4, 2.0)})

    def boom(*args, **kwargs):
        raise OSError("No space left on device")

    monkeypatch.setattr(C.np, "savez", boom)
    with pytest.raises(OSError):
        ck.save(4, {"w": jnp.full(4, 9.0)})
    monkeypatch.undo()

    assert ck.latest_step() == 3
    restored, _ = ck.restore(3, {"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full(4, 2.0))


def test_checkpointer_keep_validation(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        Checkpointer(str(tmp_path), keep=0)


def test_data_pipeline_determinism_and_hosts():
    cfg = DataConfig(vocab_size=97, seq_len=17, global_batch=8, seed=3, n_hosts=2, host_id=0)
    s1 = SyntheticStream(cfg)
    s2 = SyntheticStream(cfg)
    np.testing.assert_array_equal(s1.batch_at(4)["tokens"], s2.batch_at(4)["tokens"])
    other = SyntheticStream(
        DataConfig(vocab_size=97, seq_len=17, global_batch=8, seed=3, n_hosts=2, host_id=1)
    )
    assert (s1.batch_at(4)["tokens"] != other.batch_at(4)["tokens"]).any()
    assert s1.batch_at(0)["tokens"].shape == (4, 16)  # host shard of global 8


def test_data_pipeline_prefetch_and_state():
    cfg = DataConfig(vocab_size=97, seq_len=9, global_batch=4, seed=5)
    s = SyntheticStream(cfg, prefetch=2).start()
    b0 = next(s)
    b1 = next(s)
    s.stop()
    fresh = SyntheticStream(cfg)
    np.testing.assert_array_equal(b0["tokens"], fresh.batch_at(0)["tokens"])
    np.testing.assert_array_equal(b1["tokens"], fresh.batch_at(1)["tokens"])
    fresh.load_state_dict({"step": 11, "seed": 5})
    assert fresh.state_dict()["step"] == 11
