"""Collection sanity: the suite's helper modules must contribute ZERO
collected tests, and every ``test_*.py`` file must contribute at least one —
the failure mode this guards is a helper rename (or a ``@given`` wrapper
regression) silently deregistering a whole file's tests, which pytest
reports as success.

Also pins the proptest-shim contract that makes its tests collectable in
the first place: the ``@given`` wrapper must expose a zero-argument
function (pytest would otherwise try to inject the strategy parameters as
fixtures and error every test out) with the ``test_``-prefixed name
preserved.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

import numpy as np

import proptest

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(TESTS_DIR)
HELPER_MODULES = ("proptest.py", "dsp_sim.py", "conftest.py",
                  "faultinject.py")


def _collect_counts() -> dict[str, int]:
    """Per-file collected-test counts for the whole tests/ tree."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         "-p", "no:cacheprovider", TESTS_DIR],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT, check=True,
    ).stdout
    counts: dict[str, int] = {}
    for line in out.splitlines():
        m = re.match(r"(?:tests[/\\])?(test_\w+\.py)::", line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts


def test_every_test_file_collects_and_helpers_collect_nothing():
    counts = _collect_counts()
    test_files = sorted(
        f for f in os.listdir(TESTS_DIR)
        if f.startswith("test_") and f.endswith(".py")
    )
    empty = [f for f in test_files if counts.get(f, 0) == 0]
    assert not empty, f"test files collecting ZERO tests: {empty}"
    for helper in HELPER_MODULES:
        assert helper not in counts, f"helper {helper} leaked into collection"
    # and the helpers really exist where this test thinks they do
    for helper in HELPER_MODULES:
        assert os.path.exists(os.path.join(TESTS_DIR, helper))


def test_given_wrapper_is_pytest_collectable():
    """The shim's decorated tests must look like plain zero-arg test
    functions to pytest: name preserved, no leftover strategy params."""
    import inspect

    calls = []

    @proptest.given(x=proptest.integers(0, 3), flag=proptest.booleans())
    def test_dummy_property(x, flag):
        assert 0 <= x <= 3 and isinstance(flag, bool)
        calls.append((x, flag))

    assert test_dummy_property.__name__ == "test_dummy_property"
    params = inspect.signature(test_dummy_property).parameters
    assert all(
        p.kind is inspect.Parameter.VAR_POSITIONAL for p in params.values()
    ), "wrapper must not expose strategy params for fixture injection"
    test_dummy_property()  # runs N_CASES seeded cases
    assert len(calls) == proptest.N_CASES


def test_strategies_are_seed_deterministic():
    strat = proptest.tuples(
        proptest.integers(0, 100), proptest.sampled_from(["a", "b"])
    )
    a = strat(np.random.default_rng(7))
    b = strat(np.random.default_rng(7))
    assert a == b
