"""Exhaustive kernel-parity matrix: the Pallas kernel is bit-exact vs the
``core.packing``/``core.correction``-validated ground truth for EVERY plan
the enumerator emits — all schemes (naive/full/mr/mr+full), all operand
widths (2/4/6/8 bit), all multi-DSP column counts, non-default and ragged
block/problem shapes.

Three layers of assurance, replacing the old single-spec spot checks:

1. every emitted plan: kernel == jnp ref == ``core.packing``-based DSP
   simulator (``tests/dsp_sim.py``), bit-for-bit, on a ragged shape — a
   genuine three-way cross-check since the simulator shares no packing or
   extraction code with the kernel/ref pair;
2. exactness where the plan algebra promises it: every ``full`` plan equals
   the mathematically exact integer matmul (including the column-packed
   a8w8 plans that lift the int32 ceiling); every ``naive`` plan is biased
   by at most −1 per extraction per column (scaled by the column's
   recombination shift); every mr plan's error is bounded;
3. block-shape sweep: representative plans per scheme — and column-packed
   representatives — across non-default and ragged (M, K, N) grids,
   including blocks larger than the problem.

Plus the plan-construction failure surface: requesting an (n_pairs, δ,
n_columns) combination that overflows the int32 accumulator (or a field)
fails AT CONSTRUCTION with an error naming the violated budget — never
deep in the kernel.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from dsp_sim import simulate_packed_matmul

from repro.core.quantize import quantize_unsigned
from repro.kernels import ref
from repro.kernels.packed_matmul import (
    default_block_for,
    packed_matmul,
    packed_matmul_prepacked,
)
from repro.kernels.ref import CORRECTIONS, PackedDotSpec
from repro.tuning import enumerate_specs

RNG = np.random.default_rng(20)

WIDTH_PAIRS = ((2, 2), (4, 4), (6, 6), (8, 8))
ALL_SPECS = [s for a, w in WIDTH_PAIRS for s in enumerate_specs(a, w)]


def _operands(m, k, n, spec):
    x = RNG.integers(0, 1 << spec.bits_a, (m, k)).astype(np.int32)
    w = RNG.integers(
        -(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, n)
    ).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(w)


def _column_scale(spec):
    """Worst-case recombination multiplier of one unit of per-column
    extraction error: Σ_j 2^(j·col_bits_a)."""
    return sum(1 << spec.column_shift(j) for j in range(spec.n_columns))


def _assert_parity(spec, shape, block, simulator=True):
    m, k, n = shape
    x, w = _operands(m, k, n, spec)
    got = packed_matmul(x, w, spec=spec, block=block, interpret=True)
    want = ref.ref_packed_matmul(x, w, spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if simulator:
        sim = simulate_packed_matmul(spec, np.asarray(x), np.asarray(w))
        np.testing.assert_array_equal(sim, np.asarray(got))
    return np.asarray(got), x, w


class TestEveryEmittedPlan:
    """Acceptance gate: parity holds for every plan the enumerator emits."""

    def test_enumerator_emits_plans_for_every_width(self):
        for a_bits, w_bits in WIDTH_PAIRS:
            assert enumerate_specs(a_bits, w_bits), (a_bits, w_bits)

    def test_a8w8_needs_columns_and_has_provably_exact_plans(self):
        # single-word packing still admits NO 8-bit plan inside int32 …
        assert enumerate_specs(8, 8, n_columns_choices=(1,)) == ()
        # … and the column axis is exactly what lifts that ceiling
        a8 = enumerate_specs(8, 8)
        assert a8 and all(s.n_columns > 1 for s in a8)
        exact = [s for s in a8 if s.provably_exact]
        assert exact, "a8w8 must have at least one provably exact column plan"

    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name())
    def test_kernel_bit_equals_ground_truth(self, spec):
        # ragged K exercises the zero-pad path; block bk = one chunk group
        shape = (8, 2 * spec.chunk + 3, 16)
        got, x, w = _assert_parity(spec, shape, (8, 16, spec.chunk))
        exact = np.asarray(ref.ref_quantized_matmul(x, w))
        err = got - exact
        n_extractions = -(-shape[1] // spec.chunk)
        scale = _column_scale(spec)
        if spec.correction == "full":
            np.testing.assert_array_equal(got, exact)
        elif spec.correction == "naive":
            # the white-paper bias: at most -1 per extraction per column
            # (column j's bias recombines scaled by 2^(j·col_bits_a)),
            # never positive
            assert err.max() <= 0 and err.min() >= -n_extractions * scale
        else:  # mr corrections: restored error is bounded per extraction by
            # the low-field spill into the squeezed middle field, again
            # scaled by the column recombination
            bound = n_extractions * (1 << spec.mr_bits) * scale
            assert np.abs(err).max() <= bound, spec.name()


class TestBlockShapeMatrix:
    """Scheme × block × ragged-problem grid for representative plans."""

    REPRESENTATIVE = {
        "naive": PackedDotSpec(4, 4, 11, 4, "naive"),
        "full": PackedDotSpec(4, 4, 11, 4, "full"),
        "mr": PackedDotSpec(4, 4, 10, 16, "mr", 3),
        "mr+full": PackedDotSpec(4, 4, 10, 16, "mr+full", 3),
    }
    # Column-packed representatives: the high-n_pairs exact a4w4 plan and
    # the a8w8 plan that exists ONLY thanks to columns.
    COLUMN_REPRESENTATIVE = [
        PackedDotSpec(4, 4, 11, 16, "full", n_columns=2),
        PackedDotSpec(8, 8, 11, 1, "full", n_columns=4),
        PackedDotSpec(8, 8, 10, 1, "mr+full", 1, n_columns=4),
    ]

    @pytest.mark.parametrize("scheme", CORRECTIONS)
    @pytest.mark.parametrize(
        "block", [(128, 128, 128), (32, 64, 128), (16, 16, 64)]
    )
    @pytest.mark.parametrize(
        "shape", [(128, 128, 128), (96, 200, 72), (33, 130, 17)]
    )
    def test_parity_across_blocks_and_ragged_shapes(self, scheme, block, shape):
        _assert_parity(self.REPRESENTATIVE[scheme], shape, block)

    @pytest.mark.parametrize(
        "spec", COLUMN_REPRESENTATIVE, ids=lambda s: s.name()
    )
    @pytest.mark.parametrize("block", [(32, 64, 128), (16, 16, 64)])
    @pytest.mark.parametrize("shape", [(96, 200, 72), (33, 130, 17)])
    def test_column_parity_across_blocks_and_ragged_shapes(
        self, spec, block, shape
    ):
        """Three-way parity for column-packed plans on ragged grids."""
        _assert_parity(spec, shape, block)

    def test_block_larger_than_problem(self):
        _assert_parity(self.REPRESENTATIVE["full"], (8, 24, 8), (128, 128, 128))

    def test_bk_not_multiple_of_chunk_rejected(self):
        spec = self.REPRESENTATIVE["mr"]  # chunk 32
        x, w = _operands(8, 64, 8, spec)
        with pytest.raises(ValueError, match="multiple of spec.chunk"):
            packed_matmul(x, w, spec=spec, block=(8, 8, 48), interpret=True)


class TestPrepackedParity:
    """The prepacked fast path is bit-identical to the per-call kernel for
    EVERY emitted plan: ``packed_matmul_prepacked(pack_weight_words(w)) ==
    packed_matmul(w) == ref == simulator`` — packing weights once at engine
    build must never change a single output bit."""

    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name())
    def test_prepacked_bit_equals_per_call(self, spec):
        shape = (8, 2 * spec.chunk + 3, 16)
        m, k, n = shape
        x, w = _operands(m, k, n, spec)
        want = np.asarray(ref.ref_packed_matmul(x, w, spec))
        packed = ref.pack_weight_words(w, spec)
        # the wsc contamination stream is materialized ONLY for mr plans
        assert (packed.wsc is None) == (not spec.uses_mr)
        got_ref = np.asarray(ref.ref_packed_matmul_prepacked(x, packed, spec))
        got_kernel = np.asarray(packed_matmul_prepacked(
            x, packed.words, packed.wsc, spec=spec,
            block=(8, 16, spec.chunk), interpret=True,
        ))
        np.testing.assert_array_equal(got_ref, want)
        np.testing.assert_array_equal(got_kernel, want)

    @pytest.mark.parametrize(
        "spec",
        [
            PackedDotSpec(4, 4, 11, 4, "full"),
            PackedDotSpec(4, 4, 10, 16, "mr+full", 3),
            PackedDotSpec(8, 8, 11, 1, "full", n_columns=4),
        ],
        ids=lambda s: s.name(),
    )
    def test_prepacked_three_way_with_simulator(self, spec):
        m, k, n = 5, 3 * spec.chunk, 12
        x, w = _operands(m, k, n, spec)
        packed = ref.pack_weight_words(w, spec)
        got = np.asarray(packed_matmul_prepacked(
            x, packed.words, packed.wsc, spec=spec, interpret=True,
        ))
        sim = simulate_packed_matmul(spec, np.asarray(x), np.asarray(w))
        np.testing.assert_array_equal(got, sim)

    @pytest.mark.parametrize(
        "spec",
        [
            PackedDotSpec(4, 4, 11, 4, "full"),
            PackedDotSpec(4, 4, 10, 16, "mr+full", 3),
            PackedDotSpec(8, 8, 11, 1, "full", n_columns=4),
        ],
        ids=lambda s: s.name(),
    )
    def test_fused_quantize_prologue_matches_staged(self, spec):
        """The in-kernel activation quantize (f32 tile + row scale) equals
        quantize-then-call bit for bit — no HBM staging round-trip."""
        rng = np.random.default_rng(7)
        m, k, n = 5, 2 * spec.chunk + 3, 12
        w = jnp.asarray(rng.integers(
            -(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, n)
        ), jnp.int32)
        xf = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        xq = quantize_unsigned(xf, bits=spec.bits_a, axis=-1)
        packed = ref.pack_weight_words(w, spec)
        staged = np.asarray(packed_matmul_prepacked(
            jnp.asarray(xq.values, jnp.int32), packed.words, packed.wsc,
            spec=spec, interpret=True,
        ))
        fused = np.asarray(packed_matmul_prepacked(
            xf, packed.words, packed.wsc, spec=spec, interpret=True,
            x_scale=xq.scale, x_zp=xq.zero_point,
        ))
        np.testing.assert_array_equal(fused, staged)

    def test_decode_default_block_is_small_m(self):
        spec = PackedDotSpec(4, 4, 11, 4, "full")
        assert default_block_for(2, spec)[0] == 8
        assert default_block_for(128, spec)[0] == 128
        # chunk-aligned bk even for long-chunk plans
        mr = PackedDotSpec(4, 4, 10, 16, "mr+full", 3)  # chunk 32
        assert default_block_for(2, mr)[2] % mr.chunk == 0

    def test_activation_shorter_than_packed_weights(self):
        """An x truncated well below the words' K — with a bk that does
        not divide the words' grid — must still cover every weight chunk
        (regression: the K grid used to truncate tail chunks here)."""
        spec = PackedDotSpec(4, 4, 11, 4, "full")  # chunk 8
        rng = np.random.default_rng(11)
        k_w, k_x, n = 40, 19, 12
        w = jnp.asarray(rng.integers(-8, 8, (k_w, n)), jnp.int32)
        x = jnp.asarray(rng.integers(0, 16, (3, k_x)), jnp.int32)
        packed = ref.pack_weight_words(w, spec)
        want = np.asarray(ref.ref_packed_matmul(
            jnp.pad(x, ((0, 0), (0, k_w - k_x))), w, spec
        ))
        for block in ((8, 16, 16), (8, 16, 8), (8, 16, 48)):
            got = np.asarray(packed_matmul_prepacked(
                x, packed.words, packed.wsc, spec=spec, block=block,
                interpret=True,
            ))
            np.testing.assert_array_equal(got, want)

    def test_mr_plan_requires_contamination_operands(self):
        spec = PackedDotSpec(4, 4, 10, 16, "mr+full", 3)
        x, w = _operands(4, spec.chunk, 8, spec)
        packed = ref.pack_weight_words(w, spec)
        with pytest.raises(ValueError, match="contamination"):
            packed_matmul_prepacked(
                x, packed.words, None, spec=spec, interpret=True
            )


class TestConstructionTimeBudgets:
    """Satellite: overflowing (n_pairs, δ) combinations fail at plan
    construction with errors naming the violated budget."""

    def test_int32_accumulator_budget_named(self):
        with pytest.raises(ValueError, match="int32 accumulator budget"):
            PackedDotSpec(bits_a=4, bits_w=4, p=12, n_pairs=8)

    def test_int32_budget_message_names_the_knobs(self):
        with pytest.raises(ValueError, match=r"n_pairs \(=8\).*p \(=12\)"):
            PackedDotSpec(bits_a=4, bits_w=4, p=12, n_pairs=8)

    def test_middle_field_budget_named(self):
        with pytest.raises(
            ValueError, match="middle field.*p = 9.*mr correction"
        ):
            PackedDotSpec(bits_a=4, bits_w=4, p=9, n_pairs=4, correction="full")

    def test_restored_middle_field_budget_named(self):
        # even with the mr widening, n_pairs=64 at p=5 cannot hold the sum
        with pytest.raises(ValueError, match="restored middle field"):
            PackedDotSpec(4, 4, p=5, n_pairs=64, correction="mr", mr_bits=1)

    def test_int8_has_no_legal_single_column_plan_and_says_why(self):
        with pytest.raises(ValueError, match="raise n_columns"):
            PackedDotSpec(bits_a=8, bits_w=8, p=17, n_pairs=1, correction="full")
        # the very combination the error suggests is legal — and exact
        spec = PackedDotSpec(8, 8, p=11, n_pairs=1, correction="full",
                             n_columns=4)
        assert spec.provably_exact

    def test_n_columns_validated_at_construction(self):
        with pytest.raises(ValueError, match="n_columns=0"):
            PackedDotSpec(4, 4, 11, 4, n_columns=0)
        with pytest.raises(ValueError, match="at least one activation bit"):
            PackedDotSpec(4, 4, 11, 4, n_columns=5)

    def test_per_column_budget_named_in_error(self):
        # 2 columns of 4-bit slices are NOT enough for a8w8 at n_pairs=8
        with pytest.raises(ValueError, match="per column"):
            PackedDotSpec(8, 8, p=17, n_pairs=8, correction="full",
                          n_columns=2)

    def test_mr_bits_consistency_enforced(self):
        with pytest.raises(ValueError, match="mr_bits >= 1"):
            PackedDotSpec(4, 4, 10, 4, correction="mr", mr_bits=0)
        with pytest.raises(ValueError, match="only meaningful"):
            PackedDotSpec(4, 4, 11, 4, correction="full", mr_bits=2)

    def test_every_emitted_plan_constructs_and_names_itself(self):
        names = [s.name() for s in ALL_SPECS]
        assert len(set(names)) == len(names)  # enumeration has no duplicates
        for spec, name in zip(ALL_SPECS, names):
            assert f"n{spec.n_pairs}" in name and spec.correction in name
