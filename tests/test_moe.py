"""MoE routing: capacity accounting, combine correctness, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_ffn

CFG = ModelConfig(
    name="t", family="moe", n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
    d_ff=32, vocab_size=64, n_experts=4, experts_per_token=2,
    capacity_factor=2.0, dtype="float32",
)


def test_moe_output_shape_and_finite():
    key = jax.random.PRNGKey(0)
    p = init_moe(key, CFG)
    x = jax.random.normal(key, (2, 8, 16))
    out, aux = moe_ffn(p, x, CFG)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-3  # aux >= 1 at balance (e * k/e * 1/k)


def test_moe_matches_dense_reference():
    """With capacity for every token, sorted dispatch must equal the
    direct per-token expert evaluation."""
    key = jax.random.PRNGKey(1)
    p = init_moe(key, CFG)
    x = jax.random.normal(key, (1, 6, 16))
    out, _ = moe_ffn(p, x, CFG)

    xt = x.reshape(-1, 16)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for c in range(2):
            e = int(idx[t, c])
            h = np.asarray(xt[t]) @ np.asarray(p["up"][e])
            g = np.asarray(xt[t]) @ np.asarray(p["gate"][e])
            act = (g / (1 + np.exp(-g))) * h
            want[t] += float(gates[t, c]) * (act @ np.asarray(p["down"][e]))
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, 16), want, atol=2e-3
    )


def test_moe_capacity_drops_tokens():
    import dataclasses

    tight = dataclasses.replace(CFG, capacity_factor=0.25)
    key = jax.random.PRNGKey(2)
    p = init_moe(key, tight)
    x = jax.random.normal(key, (2, 16, 16))
    out, _ = moe_ffn(p, x, tight)
    # with capacity 0.25 some tokens must be dropped (zero output rows)
    norms = np.linalg.norm(np.asarray(out).reshape(-1, 16), axis=-1)
    assert (norms < 1e-6).any()
    assert np.isfinite(np.asarray(out)).all()


def test_moe_grad_flows():
    key = jax.random.PRNGKey(3)
    p = init_moe(key, CFG)
    x = jax.random.normal(key, (1, 8, 16))

    def loss(pp):
        out, aux = moe_ffn(pp, x, CFG)
        return jnp.sum(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


# ---- per-expert packed serving (split expert stacks) ---------------------


def _registry_moe_cfgs():
    import dataclasses

    from repro.models.registry import get_config, list_archs

    out = []
    for arch in list_archs():
        cfg = dataclasses.replace(get_config(arch, smoke=True),
                                  dtype="float32")
        if cfg.n_experts:
            out.append(cfg)
    return out


def test_iter_packable_weights_discovers_every_moe_expert_stack():
    """``split_expert_stacks`` + ``iter_packable_weights`` must surface a
    2-D per-expert leaf for every expert of every up/gate/down stack in
    every MoE-bearing registry config (MoE and hybrid families)."""
    import re

    from repro.core.packed_params import (
        iter_packable_weights,
        split_expert_stacks,
    )
    from repro.models import transformer as T

    cfgs = _registry_moe_cfgs()
    assert len(cfgs) >= 3  # dbrx, moonshot, jamba at minimum
    for cfg in cfgs:
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        split = split_expert_stacks(params)
        # idempotent: a second split is a no-op
        assert jax.tree.structure(split_expert_stacks(split)) == \
            jax.tree.structure(split)
        expert_leaves = {}
        for path, leaf in iter_packable_weights(split):
            m = re.search(r"/(up|gate|down)/e(\d+)$", path)
            if m:
                # per-expert matmul dims, under any leading stack axes
                # (group scan and hybrid per-group layer stacks slice
                # those off at runtime)
                d, f = cfg.d_model, cfg.d_ff
                want = (f, d) if m.group(1) == "down" else (d, f)
                assert leaf.shape[-2:] == want, (path, leaf.shape)
                expert_leaves.setdefault(m.group(1), set()).add(
                    int(m.group(2)))
        assert set(expert_leaves) == {"up", "gate", "down"}, cfg.name
        for proj, ids in expert_leaves.items():
            assert ids == set(range(cfg.n_experts)), (cfg.name, proj, ids)


def test_per_expert_packed_decode_matches_float_within_bound():
    """Every expert served through its own int4 packed plan: the forward
    must stay within calibrated int4 quantization noise of float (and the
    packed tree must actually carry per-expert packed leaves — before the
    split, expert stacks silently served in float)."""
    import dataclasses

    from repro.core.packed_params import quantize_for_serving
    from repro.models import transformer as T
    from repro.models.registry import get_config

    cfg = dataclasses.replace(get_config("moonshot-v1-16b-a3b", smoke=True),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 2,
                              cfg.vocab_size, jnp.int32)
    ref, _, _ = T.forward(params, cfg, toks)
    q = quantize_for_serving(params, "int4_packed")
    leaves = jax.tree_util.tree_flatten_with_path(q)[0]
    assert any("'e0'" in str(p) and "'packed'" in str(p) for p, _ in leaves)
    got, _, _ = T.forward(q, cfg, toks)
    ref_l = np.asarray(ref[:, -1]).reshape(-1)
    got_l = np.asarray(got[:, -1]).reshape(-1)
    assert np.isfinite(got_l).all()
    rel = float(np.abs(got_l - ref_l).mean() / np.abs(ref_l).mean())
    cos = float(np.dot(got_l, ref_l)
                / (np.linalg.norm(got_l) * np.linalg.norm(ref_l)))
    # same calibrated smoke-net bounds as the serving packed-decode test
    assert rel < 1.0, rel
    assert cos > 0.6, cos


def test_sort_dispatch_determinism_and_padding_independence():
    """Same tokens => same routing => bitwise-identical outputs across
    calls; and a real token's output must not depend on junk padding rows
    sharing the batch (dropless serving dispatch parks invalid tokens in
    the overflow bin behind every real assignment)."""
    key = jax.random.PRNGKey(10)
    p = init_moe(key, CFG)
    x = jax.random.normal(key, (2, 6, 16))
    valid = jnp.ones((2, 6), bool)
    a, _ = moe_ffn(p, x, CFG, valid=valid)
    b, _ = moe_ffn(p, x, CFG, valid=valid)
    assert bool(jnp.all(a == b))

    # junk third row, masked invalid: real rows' outputs are unperturbed
    junk = jnp.concatenate([x, 100.0 * jnp.ones((1, 6, 16))], axis=0)
    vj = jnp.concatenate([valid, jnp.zeros((1, 6), bool)], axis=0)
    c, _ = moe_ffn(p, junk, CFG, valid=vj)
    np.testing.assert_allclose(np.asarray(c[:2]), np.asarray(a),
                               rtol=0, atol=2e-6)
    # the invalid row contributes nothing and receives zeros
    assert float(jnp.abs(c[2]).max()) == 0.0


def test_dropless_serving_vs_capacity_training_paths():
    """valid=None keeps the training capacity-drop semantics; the serving
    path (valid given) must be dropless — no zero output rows even at a
    capacity factor that drops tokens in training."""
    import dataclasses

    tight = dataclasses.replace(CFG, capacity_factor=0.25)
    key = jax.random.PRNGKey(11)
    p = init_moe(key, tight)
    x = jax.random.normal(key, (2, 16, 16))
    train_out, _ = moe_ffn(p, x, tight)
    train_norms = np.linalg.norm(np.asarray(train_out).reshape(-1, 16),
                                 axis=-1)
    assert (train_norms < 1e-6).any()  # capacity drops in training
    serve_out, _ = moe_ffn(p, x, tight, valid=jnp.ones((2, 16), bool))
    serve_norms = np.linalg.norm(np.asarray(serve_out).reshape(-1, 16),
                                 axis=-1)
    assert (serve_norms > 1e-6).all()  # dropless in serving
