"""MoE routing: capacity accounting, combine correctness, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_ffn

CFG = ModelConfig(
    name="t", family="moe", n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
    d_ff=32, vocab_size=64, n_experts=4, experts_per_token=2,
    capacity_factor=2.0, dtype="float32",
)


def test_moe_output_shape_and_finite():
    key = jax.random.PRNGKey(0)
    p = init_moe(key, CFG)
    x = jax.random.normal(key, (2, 8, 16))
    out, aux = moe_ffn(p, x, CFG)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-3  # aux >= 1 at balance (e * k/e * 1/k)


def test_moe_matches_dense_reference():
    """With capacity for every token, sorted dispatch must equal the
    direct per-token expert evaluation."""
    key = jax.random.PRNGKey(1)
    p = init_moe(key, CFG)
    x = jax.random.normal(key, (1, 6, 16))
    out, _ = moe_ffn(p, x, CFG)

    xt = x.reshape(-1, 16)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for c in range(2):
            e = int(idx[t, c])
            h = np.asarray(xt[t]) @ np.asarray(p["up"][e])
            g = np.asarray(xt[t]) @ np.asarray(p["gate"][e])
            act = (g / (1 + np.exp(-g))) * h
            want[t] += float(gates[t, c]) * (act @ np.asarray(p["down"][e]))
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, 16), want, atol=2e-3
    )


def test_moe_capacity_drops_tokens():
    import dataclasses

    tight = dataclasses.replace(CFG, capacity_factor=0.25)
    key = jax.random.PRNGKey(2)
    p = init_moe(key, tight)
    x = jax.random.normal(key, (2, 16, 16))
    out, _ = moe_ffn(p, x, tight)
    # with capacity 0.25 some tokens must be dropped (zero output rows)
    norms = np.linalg.norm(np.asarray(out).reshape(-1, 16), axis=-1)
    assert (norms < 1e-6).any()
    assert np.isfinite(np.asarray(out)).all()


def test_moe_grad_flows():
    key = jax.random.PRNGKey(3)
    p = init_moe(key, CFG)
    x = jax.random.normal(key, (1, 8, 16))

    def loss(pp):
        out, aux = moe_ffn(pp, x, CFG)
        return jnp.sum(out**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
