"""Fault-injection scenarios: deadline shedding under bursts and page
exhaustion, shed requests freeing lanes/slots/pages, and the CI smoke for
the degradation story — burst → governor degrades → queue drains →
governor recovers → a fresh request is token-identical to a never-
degraded engine.  Scenarios are driven through ``tests/faultinject.py``
(no wall-clock sleeps: expiry is injected by backdating ``deadline_at``
so the production shedding path fires deterministically)."""

import dataclasses

import jax
import pytest

import faultinject as fi
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import (
    ContinuousEngine,
    Engine,
    GovernorConfig,
    ServeConfig,
)

KEY = jax.random.PRNGKey(0)
CFG = dataclasses.replace(get_config("qwen1.5-110b", smoke=True),
                          dtype="float32")
PARAMS = T.init_params(KEY, CFG)


def _engine(quant="native", slots=3, chunk=4, **kw):
    return Engine(CFG, PARAMS, ServeConfig(
        n_slots=slots, max_len=32, prefill_chunk=chunk, quant_mode=quant, **kw
    ))


def _cengine(quant="native", slots=3, chunk=4, **kw):
    kw.setdefault("page_size", 8)
    return ContinuousEngine(CFG, PARAMS, ServeConfig(
        n_slots=slots, max_len=32, prefill_chunk=chunk, quant_mode=quant, **kw
    ))


# ---- deadline shedding ---------------------------------------------------


def test_queued_burst_sheds_on_deadline_continuous():
    """A burst beyond capacity: the queued tail expires and is shed
    without ever touching a lane; survivors finish normally and the page
    pool comes back whole."""
    eng = _cengine(slots=2)
    rids = fi.burst(eng, 6, max_new=4)
    eng.step()  # admits what fits; the rest wait in the queue
    queued = list(eng.scheduler._queue)
    assert queued, "burst was supposed to outrun capacity"
    fi.force_expire(eng, queued)
    eng.step()
    for rid in queued:
        assert eng.scheduler.requests[rid].finish_reason == "deadline"
    fi.drain(eng)
    st = eng.stats()
    assert st["shed"] == len(queued) == st["cancelled"]
    assert st["finished"] == len(rids) - len(queued)
    assert st["free_pages"] == st["n_pages"]
    for rid in set(rids) - set(queued):
        assert eng.scheduler.requests[rid].finish_reason == "length"


def test_shed_running_request_frees_lane_continuous():
    """Expiring a *running* request mid-decode frees its lane and pages
    at the next step boundary; the queued request behind it gets the
    capacity and completes."""
    eng = _cengine(slots=2)
    rids = fi.burst(eng, 3, max_new=8)
    eng.step()
    victim = next(r for r in rids if r not in eng.scheduler._queue)
    # run the victim past its chunked prefill so it is genuinely decoding
    fi.step_until(eng, lambda e: e.scheduler.requests[victim].tokens)
    fi.force_expire(eng, [victim])
    eng.step()
    assert eng.scheduler.requests[victim].finish_reason == "deadline"
    fi.drain(eng)
    st = eng.stats()
    assert st["shed"] == 1
    assert st["free_pages"] == st["n_pages"]
    assert eng.scheduler.requests[rids[2]].finish_reason == "length"
    assert len(eng.scheduler.requests[rids[2]].tokens) == 8


def test_shed_running_request_frees_slot_fixed():
    eng = _engine(slots=2)
    rids = fi.burst(eng, 3, max_new=8)
    eng.step()
    victim = next(r for r in rids if r not in eng.scheduler._queue)
    fi.force_expire(eng, [victim])
    eng.step()
    assert eng.scheduler.requests[victim].finish_reason == "deadline"
    fi.drain(eng)
    assert (eng._slot_rid == -1).all() and not eng.active.any()
    assert eng.stats()["shed"] == 1
    assert eng.scheduler.requests[rids[2]].finish_reason == "length"


def test_page_exhaustion_with_deadlines_drains_clean():
    """A page pool too small for the burst: requests queue on pages, the
    whole backlog is expired, and the engine still drains to an empty,
    fully-freed state — no stuck lanes, no leaked pages."""
    eng = _cengine(slots=4, n_pages=8)  # 64 pooled tokens for the burst
    rids = fi.burst(eng, 8, max_new=8, prompt_len=(6, 10))
    fi.run_steps(eng, 3)
    unfinished = [r for r in rids if not eng.scheduler.requests[r].done]
    assert unfinished
    fi.force_expire(eng, unfinished)
    fi.drain(eng)
    st = eng.stats()
    assert st["free_pages"] == st["n_pages"]
    assert not eng.active.any() and st["running"] == 0
    assert st["shed"] == len(unfinished)
    for rid in rids:
        assert eng.scheduler.requests[rid].done


def test_deadline_ms_engine_default_applies_to_every_submit():
    """ServeConfig.deadline_ms stamps a deadline on requests that don't
    pass their own — the serve-wide SLO knob."""
    eng = _cengine(deadline_ms=60_000.0)
    rid = eng.submit([2, 3, 4], max_new=2)
    req = eng.scheduler.requests[rid]
    assert req.deadline_at is not None
    assert rid in eng.scheduler._deadlined
    # and a per-request override beats the engine default
    rid2 = eng.submit([2, 3], max_new=2, deadline_ms=1e6)
    assert eng.scheduler.requests[rid2].deadline_at > req.deadline_at
    fi.drain(eng)
    assert eng.stats()["shed"] == 0  # generous deadlines: nothing shed


def test_decode_wall_time_feeds_straggler_signal():
    """Every decode step's wall time lands in the StragglerDetector, so
    the governor's slow-step signal (and the operator's
    ``decode_median_step_s``) is live after any decoding at all."""
    eng = _cengine(slots=2)
    eng.submit([2, 3, 4], max_new=6)
    fi.drain(eng)
    assert eng.straggler.n_recorded() > 0
    assert eng.straggler.n_recorded() <= eng.straggler.window
    assert eng.stats()["decode_median_step_s"] > 0.0


# ---- the degradation story (CI fast-lane smoke) --------------------------


def test_burst_degrade_recover_token_identity():
    """Burst → the governor swaps to the narrow tier after ``hold_steps``
    deep-queue observations → the queue drains and it recovers one rung
    back to primary → a request served *after* recovery is token-for-
    token identical to a never-degraded engine."""
    gcfg = GovernorConfig(queue_high=3, queue_low=1, hold_steps=2)
    gov = _cengine(quant="dsp_tuned", plan_bits=(8, 8), slots=2,
                   governor=gcfg)
    assert [t.name for t in gov.tiers] == ["primary", "narrow"]

    rids = fi.burst(gov, 8, max_new=3)
    fi.step_until(gov, lambda e: e.active_tier == 1, max_steps=50)
    assert gov.governor.n_swaps == 1
    assert gov.governor.history[-1][1:] == (0, 1)

    fi.drain(gov)
    fi.step_until(gov, lambda e: e.active_tier == 0, max_steps=50)
    assert gov.governor.n_swaps == 2
    assert gov.governor.history[-1][1:] == (1, 0)
    for rid in rids:
        req = gov.scheduler.requests[rid]
        assert req.finish_reason in ("length", "eos")
        assert 1 <= len(req.tokens) <= 3

    prompt = [5, 6, 7, 8]
    rid = gov.submit(prompt, max_new=6)
    fi.drain(gov)
    got = list(gov.scheduler.requests[rid].tokens)

    ref = _cengine(quant="dsp_tuned", plan_bits=(8, 8), slots=2)
    want = ref.generate([prompt], max_new=6)[0]
    assert got == want, "post-recovery serving diverged from primary tier"


@pytest.mark.slow
@pytest.mark.parametrize("make", [_engine, _cengine], ids=["slot", "cont"])
def test_midflight_swap_storm_keeps_serving(make):
    """Repeated manual tier swaps while requests are in flight: every
    request still runs to its full budget and the engine drains clean —
    tier swaps change arithmetic, never request lifecycle."""
    eng = make(quant="dsp_tuned", plan_bits=(8, 8), slots=2,
               governor=GovernorConfig(queue_high=50, emergency_queue_high=99,
                                       hold_steps=2))
    rids = fi.burst(eng, 4, max_new=6)
    for step in range(40):
        if not (eng.active.any() or eng.scheduler.n_queued):
            break
        eng.set_tier(step % 2)
        eng.step()
    assert not (eng.active.any() or eng.scheduler.n_queued)
    for rid in rids:
        req = eng.scheduler.requests[rid]
        assert req.finish_reason in ("length", "eos")
        assert 1 <= len(req.tokens) <= 6
        assert all(0 <= t < CFG.vocab_size for t in req.tokens)
