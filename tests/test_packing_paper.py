"""Exhaustive reproduction of the paper's Tables I and II.

Every assertion below checks OUR bit-exact DSP48E2 simulation against the
NUMBERS PRINTED IN THE PAPER, over all 65 536 input combinations — this is
the ground-truth layer of the whole framework.
"""

import pytest

from repro.core.correction import scheme_stats
from repro.core.packing import (
    int4_packing,
    int8_packing,
    intn_packing,
)


class TestConfigAlgebra:
    def test_int4_matches_paper_fig2(self):
        cfg = int4_packing()
        assert cfg.a_offsets == (0, 11)
        assert cfg.w_offsets == (0, 22)
        assert cfg.r_offsets == (0, 11, 22, 33)
        assert cfg.r_widths == (8, 8, 8, 8)
        assert cfg.delta == 3
        assert cfg.fits_dsp48()

    def test_mr_overpacking_fig6_config(self):
        cfg = int4_packing(delta=-2)
        assert cfg.a_offsets == (0, 6)
        assert cfg.w_offsets == (0, 12)
        assert cfg.r_offsets == (0, 6, 12, 18)

    def test_intn_fig9_config(self):
        cfg = intn_packing((4, 4, 4), (3, 3), delta=0)
        assert cfg.a_offsets == (0, 7, 14)
        assert cfg.w_offsets == (0, 21)
        assert cfg.r_offsets == (0, 7, 14, 21, 28, 35)
        assert cfg.r_widths == (7,) * 6

    def test_overpacking_fig9_config(self):
        cfg = intn_packing((4, 4, 4), (5, 5), delta=-2)
        assert cfg.a_offsets == (0, 7, 14)
        assert cfg.w_offsets == (0, 21)
        assert cfg.r_widths == (9,) * 6

    def test_int8_fits(self):
        assert int8_packing().fits_dsp48()

    def test_accumulation_budget(self):
        assert int4_packing(delta=3).max_accumulations() == 8
        assert int4_packing(delta=0).max_accumulations() == 1


class TestTable1:
    """Paper Table I — MAE / EP / WCE per approach (4-bit, 4 multiplies)."""

    def test_xilinx_int4_naive(self):
        st = scheme_stats(int4_packing(), "naive")
        assert round(st.mae_bar, 2) == 0.37
        assert round(st.ep_bar, 2) == 37.35
        assert st.wce_bar == 1

    def test_full_correction_is_exact(self):
        st = scheme_stats(int4_packing(), "full")
        assert st.mae_bar == 0.0 and st.ep_bar == 0.0 and st.wce_bar == 0

    def test_approx_correction(self):
        st = scheme_stats(int4_packing(), "approx")
        assert round(st.mae_bar, 2) == 0.02  # paper: 0.02
        # paper reports EP=3.13%: that is the per-affected-result rate; our
        # all-results mean is 2.35% (r0 is always exact). Check both views.
        assert round(st.ep_bar, 2) == pytest.approx(2.35, abs=0.01)
        for ep in st.ep[1:]:
            assert ep == pytest.approx(3.13, abs=0.03)
        assert st.wce_bar == 1

    @pytest.mark.parametrize(
        "delta,mae,wce", [(-1, 24.27, 129), (-2, 37.95, 194), (-3, 45.53, 228)]
    )
    def test_naive_overpacking(self, delta, mae, wce):
        st = scheme_stats(int4_packing(delta=delta), "naive")
        assert st.mae_bar == pytest.approx(mae, abs=0.015)
        assert st.wce_bar == wce

    def test_naive_overpacking_ep_delta1_delta3(self):
        # EP matches the paper at δ=-1 (49.85) and δ=-3 (78.26); the paper's
        # δ=-2 EP (58.64%) disagrees with our exhaustive 64.90% even though
        # its MAE and WCE match exactly — recorded as a probable erratum
        # (EXPERIMENTS.md §Paper-deltas).
        assert scheme_stats(int4_packing(delta=-1), "naive").ep_bar == pytest.approx(49.85, abs=0.01)
        assert scheme_stats(int4_packing(delta=-3), "naive").ep_bar == pytest.approx(78.26, abs=0.01)

    @pytest.mark.parametrize(
        "delta,mae,ep,wce",
        [(-1, 0.37, 37.35, 1), (-2, 0.47, 41.48, 2), (-3, 0.78, 49.95, 4)],
    )
    def test_mr_overpacking(self, delta, mae, ep, wce):
        st = scheme_stats(int4_packing(delta=delta), "mr")
        assert st.mae_bar == pytest.approx(mae, abs=0.015)
        assert st.ep_bar == pytest.approx(ep, abs=0.02)
        assert st.wce_bar == wce


class TestTable2:
    """Paper Table II — per-result statistics."""

    def test_int4_per_result(self):
        st = scheme_stats(int4_packing(), "naive")
        assert [round(m, 2) for m in st.mae] == [0.0, 0.47, 0.50, 0.53]
        assert [round(e, 2) for e in st.ep] == [0.0, 46.88, 49.80, 52.73]
        assert list(st.wce) == [0, 1, 1, 1]

    def test_mr_delta2_per_result(self):
        st = scheme_stats(int4_packing(delta=-2), "mr")
        assert list(st.ep) == pytest.approx([0.0, 52.34, 55.41, 58.20], abs=0.02)
        assert list(st.wce) == [0, 2, 2, 2]
        assert list(st.mae)[1:] == pytest.approx([0.60, 0.64, 0.66], abs=0.01)


class TestBeyondPaper:
    def test_mr_plus_full_beats_paper(self):
        """Beyond-paper: MR restore + round-half-up cuts MAE 0.37 -> ~0.10."""
        base = scheme_stats(int4_packing(delta=-1), "mr")
        ours = scheme_stats(int4_packing(delta=-1), "mr+full")
        assert ours.mae_bar < base.mae_bar / 3

    def test_density_ordering_fig9(self):
        int4 = int4_packing()
        intn = intn_packing((4, 4, 4), (3, 3), delta=0)
        over = intn_packing((4, 4, 4), (5, 5), delta=-2)
        assert int4.packing_density() < intn.packing_density() < over.packing_density()
