"""Packed serving weights: structure, byte density, numeric drift, and
end-to-end forward equivalence within int4 quantization noise — plus the
prepacked decode operands (sub-byte storage round-trip, pack-once words,
projection fusion bit-identity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed_params import (
    DspTunedLeaf,
    dequantize_packed,
    fuse_projection_weights,
    is_packed_leaf,
    pack_signed_nibbles,
    quantize_for_serving,
    quantize_params_for_serving,
    unpack_signed_nibbles,
)
from repro.kernels.ref import INT4_EXACT, INT4_MR_OVERPACKED
from repro.models import transformer as T
from repro.models.registry import get_config

KEY = jax.random.PRNGKey(0)


def test_pack_dequant_roundtrip_bounds():
    w = jax.random.normal(KEY, (64, 48), jnp.float32)
    p = quantize_params_for_serving({"w": w}, min_dim=16)["w"]
    assert is_packed_leaf(p)
    assert p["packed"].dtype == jnp.uint8
    assert p["packed"].shape == (32, 48)
    deq = dequantize_packed(p, jnp.float32)
    err = jnp.abs(deq - w)
    # absmax int4: error bounded by scale/2 per channel
    assert bool((err <= p["scale"][0] * 0.5 + 1e-6).all())


def test_norms_and_embed_stay_dense():
    cfg = get_config("qwen1.5-110b", smoke=True)
    params = T.init_params(KEY, cfg, jnp.bfloat16)
    pq = quantize_params_for_serving(params, min_dim=16)
    assert not is_packed_leaf(pq["embed"]["w"])
    flat = jax.tree_util.tree_flatten_with_path(pq)[0]
    assert any("packed" in str(p) for p, _ in flat)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "dbrx-132b", "xlstm-1.3b"])
def test_forward_drift_small(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(KEY, cfg, jnp.float32)
    pq = quantize_params_for_serving(params, min_dim=16)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
    ref, _, _ = T.forward(params, cfg, toks)
    got, _, _ = T.forward(pq, cfg, toks)
    assert np.isfinite(np.asarray(got)).all()
    # int4 weights: logits drift bounded (smoke nets are tiny + random)
    rel = float(jnp.abs(got - ref).mean() / jnp.abs(ref).mean())
    # tiny random smoke nets amplify int4 noise (esp. xLSTM exp gating);
    # the calibrated bound is family-dependent
    assert rel < (1.5 if cfg.family == "ssm" else 0.5)


def test_byte_density():
    w = jnp.zeros((128, 128), jnp.bfloat16)
    p = quantize_params_for_serving({"up": w}, min_dim=16)["up"]
    raw = 128 * 128 * 2
    packed = p["packed"].size + p["scale"].size * 4
    assert packed < raw / 3.5  # ~4x minus scale overhead
    # storage-only conversion carries no decode-speed cache
    assert "w_f32" not in p


# ---- sub-byte storage & prepacked leaves ---------------------------------


def test_signed_nibble_roundtrip_exact():
    """Nibble-packed storage decodes to the EXACT signed grid — every
    int4 value, including the extremes, for 2-D and stacked shapes."""
    rng = np.random.default_rng(3)
    for shape in ((6, 5), (2, 8, 3)):
        v = rng.integers(-8, 8, shape).astype(np.int8)
        packed = pack_signed_nibbles(jnp.asarray(v))
        assert packed.dtype == jnp.uint8
        assert packed.shape == shape[:-2] + (shape[-2] // 2, shape[-1])
        np.testing.assert_array_equal(
            np.asarray(unpack_signed_nibbles(packed)), v
        )


def test_nibble_pack_rejects_odd_k():
    with pytest.raises(ValueError, match="even"):
        pack_signed_nibbles(jnp.zeros((3, 4), jnp.int8))


def test_dsp_tuned_leaf_nibble_storage_and_prepacked_operands():
    rng = np.random.default_rng(4)
    v = rng.integers(-8, 8, (64, 48)).astype(np.int8)
    leaf = DspTunedLeaf(
        values=jnp.asarray(v), scale=jnp.ones((1, 48), jnp.float32),
        spec=INT4_EXACT,
    )
    # bits_w <= 4 stores nibbles (half the bytes of the old int8 store)...
    assert leaf.nibble_packed and leaf.payload.shape == (32, 48)
    # ...and decodes to the exact signed grid
    np.testing.assert_array_equal(np.asarray(leaf.values), v)
    # prepacked compute operands built once at construction
    assert leaf.prepacked
    assert leaf.words.shape == (64 // INT4_EXACT.chunk, INT4_EXACT.n_pairs, 48)
    assert leaf.wsc is None  # full correction: no contamination stream
    assert leaf.zp_row.shape == (48,)
    assert leaf.w_f32 is not None  # INT4_EXACT is provably exact
    zp = 1 << (INT4_EXACT.bits_a - 1)
    np.testing.assert_array_equal(
        np.asarray(leaf.zp_row), zp * v.astype(np.int64).sum(0)
    )


def test_dsp_tuned_leaf_mr_plan_carries_contamination_operands():
    rng = np.random.default_rng(5)
    v = rng.integers(-8, 8, (64, 8)).astype(np.int8)
    leaf = DspTunedLeaf(
        values=jnp.asarray(v), scale=jnp.ones((1, 8), jnp.float32),
        spec=INT4_MR_OVERPACKED,
    )
    assert leaf.wsc is not None
    # mr+full at n_pairs=16 is not provably exact -> no f32 shortcut
    assert leaf.w_f32 is None


def test_dsp_tuned_leaf_roundtrips_through_pytree():
    leaf = DspTunedLeaf(
        values=jnp.ones((32, 8), jnp.int8),
        scale=jnp.ones((1, 8), jnp.float32), spec=INT4_EXACT,
    )
    flat, treedef = jax.tree_util.tree_flatten(leaf)
    back = jax.tree_util.tree_unflatten(treedef, flat)
    assert back.spec == leaf.spec and back.exact == leaf.exact
    np.testing.assert_array_equal(
        np.asarray(back.values), np.asarray(leaf.values)
    )


def test_quantize_for_serving_prepack_toggle():
    params = {"w": jax.random.normal(KEY, (64, 48), jnp.float32)}
    cold = quantize_for_serving(params, "dsp_tuned", min_dim=16,
                                prepack=False)["w"]
    hot = quantize_for_serving(params, "dsp_tuned", min_dim=16)["w"]
    assert not cold.prepacked and hot.prepacked
    np.testing.assert_array_equal(
        np.asarray(cold.values), np.asarray(hot.values)
    )
    p4 = quantize_for_serving(params, "int4_packed", min_dim=16,
                              prepack=True)["w"]
    assert "w_f32" in p4
    # the decode cache IS the decoded nibble grid
    np.testing.assert_array_equal(
        np.asarray(p4["w_f32"]),
        np.asarray(unpack_signed_nibbles(p4["packed"])).astype(np.float32),
    )


# ---- projection fusion ----------------------------------------------------


def _attn_mlp_params():
    k1, k2, k3, k4, k5, k6 = jax.random.split(KEY, 6)
    return {
        "attn": {
            "wq": {"w": jax.random.normal(k1, (64, 64), jnp.float32),
                   "b": jnp.ones((64,), jnp.float32)},
            "wk": {"w": jax.random.normal(k2, (64, 32), jnp.float32),
                   "b": jnp.zeros((32,), jnp.float32)},
            "wv": {"w": jax.random.normal(k3, (64, 32), jnp.float32),
                   "b": jnp.ones((32,), jnp.float32)},
            "wo": {"w": jax.random.normal(k4, (64, 64), jnp.float32)},
        },
        "mlp": {
            "up": {"w": jax.random.normal(k5, (64, 128), jnp.float32)},
            "gate": {"w": jax.random.normal(k6, (64, 128), jnp.float32)},
            "down": {"w": jax.random.normal(k4, (128, 64), jnp.float32)},
        },
    }


def test_fuse_projection_weights_structure():
    fused = fuse_projection_weights(_attn_mlp_params())
    assert set(fused["attn"]) == {"wqkv", "wo"}
    assert fused["attn"]["wqkv"]["w"].shape == (64, 128)
    assert fused["attn"]["wqkv"]["b"].shape == (128,)
    assert set(fused["mlp"]) == {"upgate", "down"}
    assert fused["mlp"]["upgate"]["w"].shape == (64, 256)


def test_fuse_projection_weights_granular_switches():
    p = _attn_mlp_params()
    attn_only = fuse_projection_weights(p, fuse_mlp=False)
    assert "wqkv" in attn_only["attn"] and "up" in attn_only["mlp"]
    mlp_only = fuse_projection_weights(p, fuse_attn=False)
    assert "wq" in mlp_only["attn"] and "upgate" in mlp_only["mlp"]


def test_fuse_skips_cross_attention():
    p = {"xattn": _attn_mlp_params()["attn"]}
    fused = fuse_projection_weights(p)
    assert "wq" in fused["xattn"] and "wqkv" not in fused["xattn"]


def test_fused_quantized_matmul_bit_identical_per_column():
    """Per-output-channel quantization makes the fused projection's columns
    bit-identical to the separately quantized ones — the invariant the
    engine-build fusion relies on."""
    from repro.core.packed_linear import LinearSpec, apply_linear

    p = _attn_mlp_params()
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 64), jnp.float32)
    spec = LinearSpec(mode="int4_packed")
    unf = quantize_for_serving(p, "int4_packed", min_dim=16)
    fus = quantize_for_serving(fuse_projection_weights(p), "int4_packed",
                               min_dim=16)
    fused_out = np.asarray(apply_linear(fus["attn"]["wqkv"], x, spec))
    for name, sl in (("wq", slice(0, 64)), ("wk", slice(64, 96)),
                     ("wv", slice(96, 128))):
        part = np.asarray(apply_linear(unf["attn"][name], x, spec))
        np.testing.assert_array_equal(fused_out[:, sl], part)
