"""Packed serving weights: structure, byte density, numeric drift, and
end-to-end forward equivalence within int4 quantization noise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed_params import (
    dequantize_packed,
    is_packed_leaf,
    quantize_params_for_serving,
)
from repro.models import transformer as T
from repro.models.registry import get_config

KEY = jax.random.PRNGKey(0)


def test_pack_dequant_roundtrip_bounds():
    w = jax.random.normal(KEY, (64, 48), jnp.float32)
    p = quantize_params_for_serving({"w": w}, min_dim=16)["w"]
    assert is_packed_leaf(p)
    assert p["packed"].dtype == jnp.uint8
    assert p["packed"].shape == (32, 48)
    deq = dequantize_packed(p, jnp.float32)
    err = jnp.abs(deq - w)
    # absmax int4: error bounded by scale/2 per channel
    assert bool((err <= p["scale"][0] * 0.5 + 1e-6).all())


def test_norms_and_embed_stay_dense():
    cfg = get_config("qwen1.5-110b", smoke=True)
    params = T.init_params(KEY, cfg, jnp.bfloat16)
    pq = quantize_params_for_serving(params, min_dim=16)
    assert not is_packed_leaf(pq["embed"]["w"])
    flat = jax.tree_util.tree_flatten_with_path(pq)[0]
    assert any("packed" in str(p) for p, _ in flat)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "dbrx-132b", "xlstm-1.3b"])
def test_forward_drift_small(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(KEY, cfg, jnp.float32)
    pq = quantize_params_for_serving(params, min_dim=16)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
    ref, _, _ = T.forward(params, cfg, toks)
    got, _, _ = T.forward(pq, cfg, toks)
    assert np.isfinite(np.asarray(got)).all()
    # int4 weights: logits drift bounded (smoke nets are tiny + random)
    rel = float(jnp.abs(got - ref).mean() / jnp.abs(ref).mean())
    # tiny random smoke nets amplify int4 noise (esp. xLSTM exp gating);
    # the calibrated bound is family-dependent
    assert rel < (1.5 if cfg.family == "ssm" else 0.5)


def test_byte_density():
    w = jnp.zeros((128, 128), jnp.bfloat16)
    p = quantize_params_for_serving({"up": w}, min_dim=16)["up"]
    raw = 128 * 128 * 2
    packed = p["packed"].size + p["scale"].size * 4
    assert packed < raw / 3.5  # ~4x minus scale overhead
