"""Tensor-parallel serving conformance + the replica front.

The mesh halves run in subprocesses (``XLA_FLAGS=--xla_force_host_
platform_device_count`` must be set before jax initializes, so the
parent process — which holds a 1-device jax — cannot host them): TP
decode must be **bit-identical** to the single-device engine in every
quant mode (DESIGN.md §4), and an illegal sharding must be rejected at
build with the violated certificate clause named.  The data-parallel
``ReplicaFront`` needs no mesh and is tested in-process.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import Engine, ReplicaFront, ServeConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# shared by the subprocess snippets: smoke config, f32 (bit-identity is
# asserted on tokens, but f32 keeps the reference arithmetic exact),
# tiny serving grid, greedy sampling
COMMON = """
import dataclasses, os
import jax
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import Engine, ServeConfig

CFG = dataclasses.replace(get_config("qwen1.5-110b", smoke=True),
                          dtype="float32")
PARAMS = T.init_params(jax.random.PRNGKey(0), CFG)
PROMPTS = [[3, 5, 7], [2, 4]]

def scfg(**kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 4)
    return ServeConfig(**kw)
"""


def _run(n_devices: int, body: str, timeout: int = 540) -> str:
    code = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={n_devices}"\n'
        + COMMON + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_tp2_int4_decode_bit_identity():
    """Fast-lane coverage: int4_packed decode on a 2-way mesh emits the
    single-device tokens exactly."""
    out = _run(2, """
    ref = Engine(CFG, PARAMS, scfg(quant_mode="int4_packed")).generate(
        PROMPTS, max_new=6)
    tp = Engine(CFG, PARAMS, scfg(quant_mode="int4_packed", tp=2)).generate(
        PROMPTS, max_new=6)
    assert tp == ref, (tp, ref)
    print("TP2_OK")
    """)
    assert "TP2_OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("tp", [2, 8])
def test_tp_decode_bit_identity_all_modes(tp):
    """The acceptance matrix: every quant mode, tokens bit-identical to
    tp=1 on 2- and 8-way host meshes.  dsp_mixed pins its candidate set
    to (4,4): the 8-bit width families have no plan whose widened spec
    fits one int32 word under this sharding (see test_tp_packed), and
    the allocator is not allowed to silently change widths per tp."""
    out = _run(tp, f"""
    MODES = {{
        "native": {{}},
        "int4_packed": {{}},
        "dsp_tuned": {{}},
        "dsp_mixed": dict(plan_bits="auto", width_candidates=((4, 4),),
                          calib_tokens=8),
    }}
    for mode, kw in MODES.items():
        ref = Engine(CFG, PARAMS, scfg(quant_mode=mode, **kw)).generate(
            PROMPTS, max_new=6)
        got = Engine(CFG, PARAMS,
                     scfg(quant_mode=mode, tp={tp}, **kw)).generate(
            PROMPTS, max_new=6)
        assert got == ref, (mode, got, ref)
        print("MODE_OK", mode)
    print("ALL_MODES_OK")
    """)
    assert "ALL_MODES_OK" in out
    for mode in ("native", "int4_packed", "dsp_tuned", "dsp_mixed"):
        assert f"MODE_OK {mode}" in out


def test_illegal_sharding_rejected_with_clause():
    """A plan table selected for one device (the INT4_EXACT preset sits
    at the int32 accumulation ceiling) cannot be row-sharded: the build
    must fail citing the violated certificate clause, naming the leaf."""
    out = _run(2, """
    from repro.core.packed_params import quantize_for_serving
    from repro.launch.mesh import make_serving_mesh
    from repro.runtime.tp_packed import shard_params_tp
    from repro.tuning import plan_linear_layers

    table = plan_linear_layers(PARAMS, a_bits=4, w_bits=4,
                               error_budget=0.0, shard_groups=1)
    q = quantize_for_serving(PARAMS, "dsp_tuned", plans=table)
    mesh = make_serving_mesh(2)
    try:
        shard_params_tp(q, mesh)
        raise SystemExit("sharding was not rejected")
    except ValueError as e:
        msg = str(e)
    assert "illegal row sharding" in msg, msg
    assert "certificate clause" in msg, msg
    assert "int32-accumulator" in msg, msg
    print("REJECT_OK")

    # and use_kernel has no cross-device reduction stage: rejected too
    try:
        shard_params_tp(q, mesh, use_kernel=True)
        raise SystemExit("use_kernel was not rejected")
    except ValueError as e:
        assert "use_kernel" in str(e)
    print("KERNEL_REJECT_OK")
    """)
    assert "REJECT_OK" in out and "KERNEL_REJECT_OK" in out


# ---- replica front (in-process: no mesh required) --------------------------

CFG = dataclasses.replace(get_config("qwen1.5-110b", smoke=True),
                          dtype="float32")
PARAMS = T.init_params(jax.random.PRNGKey(0), CFG)
PROMPTS = [[3, 5, 7], [2, 4], [9, 11, 13, 15], [6, 8]]


def _scfg(**kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 4)
    return ServeConfig(**kw)


def test_replica_front_routes_jsq_deterministically():
    front = ReplicaFront(CFG, PARAMS, _scfg(), n_replicas=2)
    grids = [front.submit(p, max_new=4) for p in PROMPTS]
    assert grids == [0, 1, 2, 3]  # the front owns a global rid namespace
    # equal-load ties break to the lowest index, so submissions alternate
    assert [front.replica_of(g) for g in grids] == [0, 1, 0, 1]


def test_replica_front_tokens_match_single_engine():
    """Routing affects latency, never content: every replica quantizes
    identical weights, so the front's outputs equal one engine's."""
    solo = Engine(CFG, PARAMS, _scfg()).generate(PROMPTS, max_new=4)
    front = ReplicaFront(CFG, PARAMS, _scfg(), n_replicas=2)
    outputs = front.generate(PROMPTS, max_new=4)
    assert sorted(outputs) == [0, 1, 2, 3]
    for grid in outputs:
        assert outputs[grid] == solo[grid], grid
    stats = front.stats()
    assert stats["n_replicas"] == 2
    assert stats["finished"] == len(PROMPTS)
    assert len(stats["replicas"]) == 2


def test_replica_front_validates_n_replicas():
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicaFront(CFG, PARAMS, _scfg(), n_replicas=0)
