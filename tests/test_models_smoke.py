"""Per-arch smoke tests (deliverable f): reduced same-family configs run a
forward + train step + decode step on CPU; output shapes + no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.steps import loss_fn, make_serve_step, make_train_step
from repro.models import transformer as T
from repro.models.registry import SMOKE_CONFIGS, get_config, list_archs
from repro.optim.adamw import AdamWConfig, adamw_init

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.encoder_len, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(KEY, cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    kw = {}
    if cfg.family == "encdec":
        kw["encoder_out"] = T.encode(params, cfg, batch["frames"].astype(jnp.bfloat16))
    if cfg.family == "vlm":
        kw["patch_embeds"] = batch["patches"]
    logits, _, aux = T.forward(params, cfg, batch["tokens"], **kw)
    exp_s = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(KEY, cfg)
    state = {"params": params, "opt": adamw_init(params)}
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", list_archs())
def test_serve_step_decodes(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(KEY, cfg, jnp.bfloat16)
    cache = T.init_cache(cfg, batch=2, max_len=32)
    step = jax.jit(make_serve_step(cfg))
    batch = {
        "tokens": jnp.zeros((2, 1), jnp.int32),
        "positions": jnp.zeros((2, 1), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["encoder_out"] = jnp.zeros(
            (2, cfg.encoder_len, cfg.d_model), jnp.bfloat16
        )
    tok, new_cache = step(params, cache, batch)
    assert tok.shape == (2,)
    assert tok.dtype == jnp.int32
    changed = any(
        bool((a != b).any())
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache))
    )
    assert changed, "decode must write the cache"


def test_all_ten_archs_registered():
    assert len(list_archs()) == 10
    assert len(SMOKE_CONFIGS) == 10


@pytest.mark.parametrize("mode", ["qat4", "qat8", "int8", "dsp_packed", "int4_packed"])
def test_quant_modes_forward(mode):
    from repro.core.packed_linear import LinearSpec

    cfg = dataclasses.replace(
        get_config("qwen1.5-110b", smoke=True), quant=LinearSpec(mode=mode),
        dtype="float32",
    )
    params = T.init_params(KEY, cfg)
    logits, _, _ = T.forward(params, cfg, jnp.zeros((1, 8), jnp.int32))
    assert np.isfinite(np.asarray(logits)).all()


def test_qat_mode_is_differentiable():
    from repro.core.packed_linear import LinearSpec

    cfg = dataclasses.replace(
        get_config("qwen1.5-110b", smoke=True), quant=LinearSpec(mode="qat4"),
        dtype="float32",
    )
    params = T.init_params(KEY, cfg)
    batch = _batch(cfg)

    g = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
