"""Layer-level unit tests: rmsnorm, rope, GQA attention, KV caches, SWA."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L

CFG = ModelConfig(
    name="t", family="dense", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab_size=64, dtype="float32",
)


def test_rmsnorm_unit_scale():
    p = L.init_rmsnorm(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 5
    y = L.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative_positions():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # dot(q_i, k_j) depends only on i - j
    q = jnp.ones((1, 8, 1, 16))
    k = jnp.ones((1, 8, 1, 16))
    qr, kr = L.rope(q, pos, 1e4), L.rope(k, pos, 1e4)
    d1 = jnp.einsum("bshd,bthd->st", qr, kr)
    assert abs(d1[3, 1] - d1[5, 3]) < 1e-4


def test_attention_causality():
    key = jax.random.PRNGKey(0)
    p = L.init_attention(key, CFG)
    x = jax.random.normal(key, (1, 8, 32))
    pos = jnp.arange(8)[None]
    out1, _ = L.attention(p, x, CFG, pos)
    x2 = x.at[:, -1].set(99.0)  # future token change must not leak backward
    out2, _ = L.attention(p, x2, CFG, pos)
    np.testing.assert_allclose(
        np.asarray(out1[:, :-1]), np.asarray(out2[:, :-1]), atol=1e-5
    )


def test_decode_matches_full_forward():
    key = jax.random.PRNGKey(2)
    p = L.init_attention(key, CFG)
    x = jax.random.normal(key, (2, 6, 32))
    pos = jnp.arange(6)[None]
    full, _ = L.attention(p, x, CFG, pos)
    cache = L.init_kv_cache(CFG, 2, 8, dtype=jnp.float32)
    outs = []
    for t in range(6):
        o, cache = L.attention(
            p, x[:, t : t + 1], CFG, jnp.full((2, 1), t), cache=cache
        )
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=1e-4)


def test_swa_ring_buffer_decode():
    cfg = dataclasses.replace(CFG, sliding_window=4)
    key = jax.random.PRNGKey(3)
    p = L.init_attention(key, cfg)
    cache = L.init_kv_cache(cfg, 1, 16, dtype=jnp.float32)
    assert cache["k"].shape[1] == 4  # ring buffer is window-sized
    x = jax.random.normal(key, (1, 10, 32))
    out = None
    for t in range(10):
        out, cache = L.attention(
            p, x[:, t : t + 1], cfg, jnp.full((1, 1), t), cache=cache
        )
    assert np.isfinite(np.asarray(out)).all()

    # reference: full attention with window mask over the last 4 tokens
    full, _ = L.attention(p, x, cfg, jnp.arange(10)[None])
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(full[:, -1]), atol=1e-4
    )


def test_gqa_head_broadcast():
    x = jnp.ones((1, 2, 2, 4))
    out = L._repeat_kv(x, 3)
    assert out.shape == (1, 2, 6, 4)
    np.testing.assert_array_equal(np.asarray(out[:, :, 0]), np.asarray(out[:, :, 2]))


def test_cached_prefill_multitoken():
    """Full-attention cached prefill (s>1) matches uncached forward."""
    key = jax.random.PRNGKey(4)
    p = L.init_attention(key, CFG)
    x = jax.random.normal(key, (1, 6, 32))
    pos = jnp.arange(6)[None]
    full, _ = L.attention(p, x, CFG, pos)
    cache = L.init_kv_cache(CFG, 1, 8, dtype=jnp.float32)
    got, cache2 = L.attention(p, x, CFG, pos, cache=cache)
    np.testing.assert_allclose(np.asarray(full), np.asarray(got), atol=1e-4)
    assert cache2 is not None


def test_chunked_attention_matches_naive():
    cfg = dataclasses.replace(CFG, attention_chunk=16)
    key = jax.random.PRNGKey(7)
    p = L.init_attention(key, CFG)
    x = jax.random.normal(key, (2, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    ref, _ = L.attention(p, x, CFG, pos)
    got, _ = L.attention(p, x, cfg, pos)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=2e-5)


def test_chunked_attention_swa_matches_naive():
    base = dataclasses.replace(CFG, sliding_window=24)
    cfg = dataclasses.replace(base, attention_chunk=16)
    key = jax.random.PRNGKey(8)
    p = L.init_attention(key, base)
    x = jax.random.normal(key, (1, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    ref, _ = L.attention(p, x, base, pos)
    got, _ = L.attention(p, x, cfg, pos)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=2e-5)
