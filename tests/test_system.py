"""End-to-end behaviour tests for the paper's system."""

import subprocess
import sys
import os

import pytest


@pytest.mark.slow
def test_quickstart_example_runs():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "examples/quickstart.py"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=420,
    )
    assert out.returncode == 0, out.stderr[-1500:]
    assert "packed matmul == exact int matmul: True" in out.stdout
    assert "MAE=0.37 EP=37.35% WCE=1" in out.stdout  # paper Table I headline


@pytest.mark.slow
def test_snn_example_runs():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "examples/snn_addpack.py"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=420,
    )
    assert out.returncode == 0, out.stderr[-1500:]
    assert "exact with 2 guard bits" in out.stdout


@pytest.mark.slow
def test_serve_driver_cli():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen1.5-110b",
         "--smoke", "--requests", "3", "--max-new", "4", "--slots", "2",
         "--max-len", "32", "--quant", "int4_packed", "--temperature", "0.8",
         "--top-k", "20"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=560,
    )
    assert out.returncode == 0, out.stderr[-1500:]
    assert "request 2" in out.stdout
    assert "decode" in out.stdout


@pytest.mark.slow
def test_train_driver_cli():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "xlstm-1.3b",
         "--smoke", "--steps", "3", "--global-batch", "2", "--seq-len", "32"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=560,
    )
    assert out.returncode == 0, out.stderr[-1500:]
    assert "[train] done" in out.stdout
