"""PageAllocator unit tests: free-list accounting, CoW, prefix sharing,
watermark admission, and the leak/double-free invariants the continuous
engine leans on."""

import numpy as np
import pytest

from repro.serving import OutOfPages, PageAllocator


def test_constructor_validation():
    with pytest.raises(ValueError):
        PageAllocator(0, 8)
    with pytest.raises(ValueError):
        PageAllocator(8, 0)
    with pytest.raises(ValueError):
        PageAllocator(8, 8, watermark=8)
    with pytest.raises(ValueError):
        PageAllocator(8, 8, watermark=-1)


def test_blocks_for_rounds_up():
    a = PageAllocator(8, page_size=8)
    assert a.blocks_for(1) == 1
    assert a.blocks_for(8) == 1
    assert a.blocks_for(9) == 2
    assert a.blocks_for(0) == 0


def test_grow_is_atomic_and_low_pages_first():
    a = PageAllocator(4, 8)
    a.open_table(0)
    assert a.grow(0, 2) == [0, 1]
    assert a.n_free == 2
    # asking beyond the free list raises WITHOUT mutating
    with pytest.raises(OutOfPages):
        a.grow(0, 5)
    assert a.n_blocks(0) == 2
    assert a.n_free == 2
    # growing to the current size is a no-op
    assert a.grow(0, 2) == []
    a.check()


def test_double_free_and_double_open_raise():
    a = PageAllocator(4, 8)
    a.open_table(7)
    a.grow(7, 2)
    with pytest.raises(ValueError):
        a.open_table(7)
    a.free(7)
    assert a.n_free == 4
    with pytest.raises(KeyError):
        a.free(7)
    a.check()


def test_no_leak_across_many_requests():
    a = PageAllocator(6, 8)
    for rid in range(50):
        a.open_table(rid)
        a.grow(rid, 1 + rid % 3)
        a.check()
        a.free(rid)
        a.check()
    assert a.n_free == 6


def test_watermark_admission():
    a = PageAllocator(10, 8, watermark=3)
    assert a.can_admit(7)
    assert not a.can_admit(8)
    a.open_table(0)
    a.grow(0, 5)
    assert a.can_admit(2)
    assert not a.can_admit(3)
    # grow itself ignores the watermark — it is an ADMISSION throttle,
    # running requests may consume the reserve
    a.grow(0, 10)
    assert a.n_free == 0


def test_cow_exclusive_page_is_a_noop():
    a = PageAllocator(4, 8)
    a.open_table(0)
    a.grow(0, 2)
    page, src = a.make_writable(0, 1)
    assert page == 1 and src is None
    a.check()


def test_prefix_share_adopt_and_cow():
    a = PageAllocator(8, 8)
    key = ("sys", 16)
    # prefiller owns 3 blocks; the first 2 become the pinned prefix
    a.open_table(0)
    a.grow(0, 3)
    a.register_shared(key, 0, 2)
    assert a.shared_blocks(key) == 2
    # prefix survives its prefiller
    a.free(0)
    assert a.n_free == 8 - 2
    a.check()
    # adopter prepends the shared pages, then CoW-splits block 1
    a.open_table(1)
    assert a.adopt_shared(key, 1) == 16
    assert a.n_blocks(1) == 2
    page, src = a.make_writable(1, 1)
    assert src == 1  # old shared page must be copied from
    assert page not in (0, 1)
    # shared page 1 still pinned for future adopters; adopter's copy private
    page2, src2 = a.make_writable(1, 1)
    assert page2 == page and src2 is None
    a.free(1)
    assert a.shared_blocks(key) == 2
    a.check()


def test_adopt_requires_empty_table():
    a = PageAllocator(8, 8)
    a.open_table(0)
    a.grow(0, 1)
    a.register_shared(("p",), 0, 1)
    a.open_table(1)
    a.grow(1, 1)
    with pytest.raises(ValueError):
        a.adopt_shared(("p",), 1)


def test_register_shared_twice_raises():
    a = PageAllocator(8, 8)
    a.open_table(0)
    a.grow(0, 1)
    a.register_shared(("p",), 0, 1)
    with pytest.raises(ValueError):
        a.register_shared(("p",), 0, 1)


def test_cow_out_of_pages():
    a = PageAllocator(2, 8)
    a.open_table(0)
    a.grow(0, 1)
    a.register_shared(("p",), 0, 1)
    a.free(0)
    a.open_table(1)
    a.adopt_shared(("p",), 1)
    a.grow(1, 2)  # takes the last free page
    with pytest.raises(OutOfPages):
        a.make_writable(1, 0)
    a.check()


def test_table_array_sentinels():
    a = PageAllocator(6, 8)
    a.open_table(3)
    a.grow(3, 2)
    arr = a.table_array([3, -1, 99], max_blocks=4)
    assert arr.dtype == np.int32
    assert arr.shape == (3, 4)
    assert list(arr[0]) == [0, 1, a.invalid, a.invalid]
    assert (arr[1] == a.invalid).all()   # empty lane
    assert (arr[2] == a.invalid).all()   # unknown rid
    assert a.invalid == a.n_pages


def test_reset_restores_fresh_state():
    a = PageAllocator(4, 8, watermark=1)
    a.open_table(0)
    a.grow(0, 2)
    a.register_shared(("p",), 0, 1)
    a.reset()
    assert a.n_free == 4
    assert a.shared_blocks(("p",)) == 0
    a.check()
    a.open_table(0)  # rid reusable after reset
    assert a.grow(0, 4) == [0, 1, 2, 3]
