"""Load-adaptive precision governor: hysteresis controller unit tests,
tier-ladder construction (narrow certified-exact fallback, overpacked
emergency tier with a certified MAE ceiling), and the engine-level swap
acceptance — a same-width tier swap mid-stream is bit-identical to never
swapping, and requests admitted *after* a swap match an engine built
directly on the target tier."""

import dataclasses

import jax
import pytest

import faultinject as fi
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serving import (
    ContinuousEngine,
    Engine,
    Governor,
    GovernorConfig,
    ServeConfig,
)

KEY = jax.random.PRNGKey(0)
CFG = dataclasses.replace(get_config("qwen1.5-110b", smoke=True),
                          dtype="float32")
PARAMS = T.init_params(KEY, CFG)


def _engine(quant="dsp_tuned", slots=2, chunk=4, **kw):
    return Engine(CFG, PARAMS, ServeConfig(
        n_slots=slots, max_len=32, prefill_chunk=chunk, quant_mode=quant, **kw
    ))


def _cengine(quant="dsp_tuned", slots=2, chunk=4, **kw):
    kw.setdefault("page_size", 8)
    return ContinuousEngine(CFG, PARAMS, ServeConfig(
        n_slots=slots, max_len=32, prefill_chunk=chunk, quant_mode=quant, **kw
    ))


# ---- config validation ---------------------------------------------------


def test_governor_config_validation():
    with pytest.raises(ValueError, match="hysteresis band"):
        GovernorConfig(queue_high=2, queue_low=2)
    with pytest.raises(ValueError, match="emergency_queue_high"):
        GovernorConfig(queue_high=8, emergency_queue_high=8)
    with pytest.raises(ValueError, match="hold_steps"):
        GovernorConfig(hold_steps=0)
    with pytest.raises(ValueError, match="window"):
        GovernorConfig(window=0)


def test_governor_needs_two_tiers():
    with pytest.raises(ValueError, match="2 tiers"):
        Governor(GovernorConfig(), n_tiers=1)


def test_governor_requires_packed_quant_mode():
    with pytest.raises(ValueError, match="governor"):
        ServeConfig(n_slots=2, max_len=32, governor=True, quant_mode="native")


# ---- hysteresis controller (pure unit tests) -----------------------------


def test_no_swap_before_hold_steps():
    g = Governor(GovernorConfig(queue_high=4, hold_steps=3), n_tiers=2)
    assert g.observe(10) == 0 and g.observe(10) == 0
    assert g.observe(10) == 1  # third consecutive hot observation fires
    assert g.n_swaps == 1 and g.history == [(3, 0, 1)]


def test_noise_below_hold_steps_never_swaps():
    g = Governor(GovernorConfig(queue_high=4, hold_steps=3), n_tiers=2)
    for _ in range(10):  # hot, hot, calm, hot, hot, calm...
        assert g.observe(10) == 0
        assert g.observe(10) == 0
        assert g.observe(0) == 0
    assert g.n_swaps == 0


def test_hysteresis_band_holds_current_tier():
    cfg = GovernorConfig(queue_high=8, queue_low=2, hold_steps=2)
    g = Governor(cfg, n_tiers=2)
    for _ in range(2):
        g.observe(9)
    assert g.active == 1
    # depth inside (queue_low, queue_high): hold forever, no recovery
    for _ in range(20):
        assert g.observe(5) == 1
    assert g.n_swaps == 1
    # only a drained queue recovers
    g.observe(1)
    assert g.observe(1) == 0 and g.n_swaps == 2


def test_recovery_steps_down_one_rung_at_a_time():
    cfg = GovernorConfig(queue_high=4, emergency_queue_high=10, hold_steps=2)
    g = Governor(cfg, n_tiers=3)
    for _ in range(2):
        g.observe(20)  # escalates straight to the emergency tier
    assert g.active == 2
    for _ in range(2):
        g.observe(0)
    assert g.active == 1, "recovery must re-earn each rung"
    for _ in range(2):
        g.observe(0)
    assert g.active == 0
    assert [h[1:] for h in g.history] == [(0, 2), (2, 1), (1, 0)]


def test_slow_step_signal_degrades_without_queue():
    cfg = GovernorConfig(queue_high=100, emergency_queue_high=200,
                         slow_step_ms=5.0, hold_steps=2)
    g = Governor(cfg, n_tiers=2)
    g.observe(0, slow_step_ms=50.0)
    assert g.observe(0, slow_step_ms=50.0) == 1
    # 0.0 means "no signal recorded yet", never hot
    g2 = Governor(cfg, n_tiers=2)
    for _ in range(5):
        assert g2.observe(0, slow_step_ms=0.0) == 0


def test_counters_reset_on_swap_min_dwell():
    g = Governor(GovernorConfig(queue_high=4, hold_steps=3), n_tiers=2)
    for _ in range(3):
        g.observe(10)
    assert g.active == 1
    # immediately calm: still needs a full hold_steps run to recover
    g.observe(0)
    g.observe(0)
    assert g.active == 1
    g.observe(0)
    assert g.active == 0


# ---- tier ladder construction --------------------------------------------


def test_build_tiers_narrow_is_certified_exact():
    eng = _engine(governor=GovernorConfig(narrow_bits=(4, 4)))
    assert [t.name for t in eng.tiers] == ["primary", "narrow"]
    for tier in eng.tiers:
        assert tier.max_certified_mae == 0.0 and tier.summary()["exact"]
    # tier tables cover the same layers as the primary plan table
    assert set(eng.tiers[1].plan_table) == set(eng.plan_table)


def test_emergency_tier_is_overpacked_within_ceiling():
    eng = _engine(governor=GovernorConfig(emergency_tier=True,
                                          emergency_max_mae=0.5))
    assert [t.name for t in eng.tiers] == ["primary", "narrow", "emergency"]
    emergency = eng.tiers[2]
    assert 0.0 < emergency.max_certified_mae <= 0.5
    for report in emergency.plan_table.values():
        assert not report.certificate.exact  # genuinely overpacked
    assert emergency.summary()["exact"] is False


def test_emergency_tier_impossible_ceiling_raises():
    # below every non-exact plan's certified bound (the tightest
    # overpacked a4w4 certificate sits around 5.6e-21)
    with pytest.raises(ValueError, match="emergency_max_mae"):
        _engine(governor=GovernorConfig(emergency_tier=True,
                                        emergency_max_mae=1e-30))


def test_set_tier_validation():
    plain = _engine(quant="dsp_tuned")
    with pytest.raises(RuntimeError, match="governor"):
        plain.set_tier(1)
    gov = _engine(governor=GovernorConfig())
    with pytest.raises(ValueError, match="out of range"):
        gov.set_tier(5)
    gov.set_tier(0)  # same-tier no-op
    assert gov.active_tier == 0


# ---- swap acceptance (the bit-identity claims) ---------------------------


def _tokens(engine, rid):
    return list(engine.scheduler.requests[rid].tokens)


def test_same_width_swap_is_bit_identical_mid_stream():
    """Both tiers hold certified-exact a4w4 plans — identical integer
    matmuls — so swapping mid-stream must not change a single token
    versus the never-swapped engine."""
    prompt = [5, 6, 7, 8]
    want = _engine(quant="dsp_tuned").generate([prompt], max_new=8)[0]

    eng = _engine(governor=GovernorConfig(hold_steps=10_000))
    rid = eng.submit(prompt, max_new=8)
    fi.run_steps(eng, 3)
    pre_swap = _tokens(eng, rid)
    assert pre_swap == want[:len(pre_swap)]  # prefix matches before swap
    eng.governor.active = 1  # pin: hold_steps keeps the governor quiet
    eng.set_tier(1)
    fi.drain(eng)
    assert _tokens(eng, rid) == want


@pytest.mark.slow
@pytest.mark.parametrize("make", [_engine, _cengine], ids=["slot", "cont"])
def test_post_swap_admission_matches_target_tier_engine(make):
    """A request admitted *after* the swap runs entirely on the narrow
    tier — its stream must match an engine built directly on that tier
    (narrow = uniform a4w4 exact plans = plan_bits (4,4), budget 0)."""
    prompt = [9, 10, 11]
    target = make(quant="dsp_tuned", plan_bits=(4, 4), error_budget=0.0)
    want = target.generate([prompt], max_new=8)[0]

    eng = make(quant="dsp_tuned", plan_bits=(8, 8),
               governor=GovernorConfig(hold_steps=10_000,
                                       narrow_bits=(4, 4)))
    eng.governor.active = 1
    eng.set_tier(1)
    rid = eng.submit(prompt, max_new=8)
    fi.drain(eng)
    assert _tokens(eng, rid) == want
