"""Property tests over random INT-N packing configurations (proptest shim).

Invariants:
  * full correction == exact outer product, for ANY valid config with δ≥0
  * naive extraction errs only by -1 per field (δ≥0) and only when a lower
    field is negative
  * MR-overpacking WCE is bounded by 2^|δ| scale effects (small-LSB claim)
  * approximate correction never increases the error rate vs naive
  * packed addition with guard bits is exact; without guards WCE == 1 in
    modular lane arithmetic
"""

import numpy as np
import pytest

from proptest import given, integers, sampled_from

from repro.core.addpack import (
    AddPackConfig,
    lane_add_expected,
    packed_lane_add,
)
from repro.core.correction import (
    error_stats,
    exhaustive_operands,
    outer_product_exact,
    simulate,
)
from repro.core.packing import intn_packing


def _random_operands(cfg, rng, n=512):
    a = np.stack(
        [rng.integers(0, 1 << w, size=n) for w in cfg.a_widths], axis=-1
    ).astype(np.int64)
    w = np.stack(
        [
            rng.integers(-(1 << (ww - 1)), 1 << (ww - 1), size=n)
            for ww in cfg.w_widths
        ],
        axis=-1,
    ).astype(np.int64)
    return a, w


@given(
    na=integers(1, 3),
    nw=integers(1, 2),
    wa=integers(2, 5),
    ww=integers(2, 5),
    delta=integers(0, 3),
    seed=integers(0, 2**31),
)
def test_full_correction_exact_for_any_config(na, nw, wa, ww, delta, seed):
    try:
        cfg = intn_packing((wa,) * na, (ww,) * nw, delta)
    except ValueError:
        return  # config exceeds the int64 budget; skip
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng)
    got = simulate(cfg, a, w, scheme="full")
    np.testing.assert_array_equal(got, outer_product_exact(cfg, a, w))


@given(
    wa=integers(2, 5), ww=integers(2, 5), delta=integers(0, 3),
    seed=integers(0, 2**31),
)
def test_naive_error_is_minus_one_only(wa, ww, delta, seed):
    cfg = intn_packing((wa, wa), (ww, ww), delta)
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng)
    err = simulate(cfg, a, w, scheme="naive") - outer_product_exact(cfg, a, w)
    assert set(np.unique(err)) <= {-1, 0}


@given(seed=integers(0, 2**31), delta=sampled_from([-1, -2, -3]))
def test_mr_wce_bound(seed, delta):
    from repro.core.packing import int4_packing

    cfg = int4_packing(delta=delta)
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng)
    err = np.abs(simulate(cfg, a, w, scheme="mr") - outer_product_exact(cfg, a, w))
    assert err.max() <= 2 ** (-delta)  # paper Table I: 1, 2, 4


def test_approx_never_worse_than_naive_exhaustive():
    from repro.core.packing import int4_packing

    cfg = int4_packing()
    a, w = exhaustive_operands(cfg)
    exact = outer_product_exact(cfg, a, w)
    naive = error_stats(exact, simulate(cfg, a, w, "naive"))
    approx = error_stats(exact, simulate(cfg, a, w, "approx"))
    assert approx.ep_bar < naive.ep_bar
    assert approx.mae_bar < naive.mae_bar


@given(
    width=integers(4, 12), lanes=integers(2, 5), guard=integers(1, 2),
    seed=integers(0, 2**31),
)
def test_addpack_guard_bits_exact(width, lanes, guard, seed):
    if lanes * (width + guard) - guard > 48:
        return
    cfg = AddPackConfig((width,) * lanes, guard_bits=guard)
    rng = np.random.default_rng(seed)
    lim = 1 << (width - 1)
    x = rng.integers(-lim, lim, (256, lanes))
    y = rng.integers(-lim, lim, (256, lanes))
    np.testing.assert_array_equal(
        packed_lane_add(cfg, x, y), lane_add_expected(cfg, x, y)
    )


@given(seed=integers(0, 2**31))
def test_addpack_no_guard_modular_wce_is_one(seed):
    cfg = AddPackConfig((9,) * 5, guard_bits=0)
    rng = np.random.default_rng(seed)
    x = rng.integers(-256, 256, (512, 5))
    y = rng.integers(-256, 256, (512, 5))
    got = packed_lane_add(cfg, x, y)
    want = lane_add_expected(cfg, x, y)
    diff = np.abs(got - want)
    mod = np.minimum(diff, 512 - diff)  # modular lane distance
    assert mod.max() <= 1  # paper Table III: WCE = 1
    assert (mod[:, 0] == 0).all()  # lowest lane is always exact
