"""Property tests over random INT-N packing configurations (proptest shim).

Invariants:
  * full correction == exact outer product, for ANY valid config with δ≥0
  * naive extraction errs only by -1 per field (δ≥0) and only when a lower
    field is negative
  * MR-overpacking WCE is bounded by 2^|δ| scale effects (small-LSB claim)
  * approximate correction never increases the error rate vs naive
  * packed addition with guard bits is exact; without guards WCE == 1 in
    modular lane arithmetic
  * ``addpack.accumulate`` with ``guard_bits=1`` is exact for ANY lane-width
    mix that fits the 48-bit accumulator (paper §VII/Fig. 8), for any number
    of accumulated terms whose per-chunk lane sums fit their lane — and the
    same claim holds through the Pallas ``addpack_accumulate`` kernel
  * with ``guard_bits=0`` one packed add errs by at most 1 per lane (in
    modular lane arithmetic) for any lane-width mix, lowest lane exact
"""

import numpy as np

from proptest import booleans, given, integers, sampled_from, tuples

from repro.core.addpack import (
    AddPackConfig,
    accumulate,
    lane_add_expected,
    packed_lane_add,
)
from repro.core.correction import (
    error_stats,
    exhaustive_operands,
    mr_restore,
    outer_product_exact,
    simulate,
)
from repro.core.packing import (
    extract_fields,
    intn_packing,
    multiply_packed,
    pack_activations,
    pack_weights,
)
from repro.tuning import enumerate_packing_configs

# The enumerator's full emission over the sub-byte width grid — the property
# tests below must hold for every config it is willing to emit.
_WIDTH_PAIRS = ((2, 2), (3, 4), (4, 4), (6, 6))
ENUMERATED = [
    cfg for a, w in _WIDTH_PAIRS for cfg in enumerate_packing_configs(a, w)
]
ENUMERATED_NONNEG = [c for c in ENUMERATED if c.delta >= 0]
ENUMERATED_OVERPACKED = [c for c in ENUMERATED if c.delta < 0]


def _random_operands(cfg, rng, n=512):
    a = np.stack(
        [rng.integers(0, 1 << w, size=n) for w in cfg.a_widths], axis=-1
    ).astype(np.int64)
    w = np.stack(
        [
            rng.integers(-(1 << (ww - 1)), 1 << (ww - 1), size=n)
            for ww in cfg.w_widths
        ],
        axis=-1,
    ).astype(np.int64)
    return a, w


@given(
    na=integers(1, 3),
    nw=integers(1, 2),
    wa=integers(2, 5),
    ww=integers(2, 5),
    delta=integers(0, 3),
    seed=integers(0, 2**31),
)
def test_full_correction_exact_for_any_config(na, nw, wa, ww, delta, seed):
    try:
        cfg = intn_packing((wa,) * na, (ww,) * nw, delta)
    except ValueError:
        return  # config exceeds the int64 budget; skip
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng)
    got = simulate(cfg, a, w, scheme="full")
    np.testing.assert_array_equal(got, outer_product_exact(cfg, a, w))


@given(
    wa=integers(2, 5), ww=integers(2, 5), delta=integers(0, 3),
    seed=integers(0, 2**31),
)
def test_naive_error_is_minus_one_only(wa, ww, delta, seed):
    cfg = intn_packing((wa, wa), (ww, ww), delta)
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng)
    err = simulate(cfg, a, w, scheme="naive") - outer_product_exact(cfg, a, w)
    assert set(np.unique(err)) <= {-1, 0}


@given(seed=integers(0, 2**31), delta=sampled_from([-1, -2, -3]))
def test_mr_wce_bound(seed, delta):
    from repro.core.packing import int4_packing

    cfg = int4_packing(delta=delta)
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng)
    err = np.abs(simulate(cfg, a, w, scheme="mr") - outer_product_exact(cfg, a, w))
    assert err.max() <= 2 ** (-delta)  # paper Table I: 1, 2, 4


def test_approx_never_worse_than_naive_exhaustive():
    from repro.core.packing import int4_packing

    cfg = int4_packing()
    a, w = exhaustive_operands(cfg)
    exact = outer_product_exact(cfg, a, w)
    naive = error_stats(exact, simulate(cfg, a, w, "naive"))
    approx = error_stats(exact, simulate(cfg, a, w, "approx"))
    assert approx.ep_bar < naive.ep_bar
    assert approx.mae_bar < naive.mae_bar


@given(
    width=integers(4, 12), lanes=integers(2, 5), guard=integers(1, 2),
    seed=integers(0, 2**31),
)
def test_addpack_guard_bits_exact(width, lanes, guard, seed):
    if lanes * (width + guard) - guard > 48:
        return
    cfg = AddPackConfig((width,) * lanes, guard_bits=guard)
    rng = np.random.default_rng(seed)
    lim = 1 << (width - 1)
    x = rng.integers(-lim, lim, (256, lanes))
    y = rng.integers(-lim, lim, (256, lanes))
    np.testing.assert_array_equal(
        packed_lane_add(cfg, x, y), lane_add_expected(cfg, x, y)
    )


@given(
    n_lanes=integers(2, 6), t_steps=integers(1, 11), seed=integers(0, 2**31)
)
def test_addpack_accumulate_guard_bit_exact_for_any_lane_mix(
    n_lanes, t_steps, seed
):
    """§VII/Fig. 8: one guard bit between lanes makes ``accumulate`` exact
    for ANY lane-width mix fitting 48 bits.  The guard absorbs the chunk's
    worst-case carry (chunk = 2**guard_bits = 2 packed adds between
    extractions), so no lane ever corrupts its neighbour; terms are drawn
    from the quarter range so each lane's own 2-term chunk sum fits its
    width — the regime the extraction reads back exactly."""
    rng = np.random.default_rng(seed)
    widths = tuple(int(rng.integers(3, 13)) for _ in range(n_lanes))
    if sum(widths) + (len(widths) - 1) > 48:
        return  # lane mix exceeds the accumulator; nothing to test
    cfg = AddPackConfig(widths, guard_bits=1)
    terms = np.stack(
        [
            rng.integers(-(1 << (w - 2)), 1 << (w - 2), (17, t_steps))
            for w in widths
        ],
        axis=-1,
    )
    got = accumulate(cfg, terms)
    np.testing.assert_array_equal(got, terms.sum(-2))


@given(
    n_lanes=integers(2, 5), seed=integers(0, 2**31)
)
def test_addpack_no_guard_wce_one_for_any_lane_mix(n_lanes, seed):
    """Without guards a packed add errs by at most 1 per lane — the carry
    out of the lane below corrupts exactly the LSB — for ANY width mix;
    the lowest lane has nothing below it and stays exact."""
    rng = np.random.default_rng(seed)
    widths = tuple(int(rng.integers(3, 11)) for _ in range(n_lanes))
    if sum(widths) > 48:
        return
    cfg = AddPackConfig(widths, guard_bits=0)
    x = np.stack(
        [rng.integers(-(1 << (w - 1)), 1 << (w - 1), 256) for w in widths],
        axis=-1,
    )
    y = np.stack(
        [rng.integers(-(1 << (w - 1)), 1 << (w - 1), 256) for w in widths],
        axis=-1,
    )
    got = packed_lane_add(cfg, x, y)
    want = lane_add_expected(cfg, x, y)
    for i, w in enumerate(widths):
        diff = np.abs(got[:, i] - want[:, i])
        mod = np.minimum(diff, (1 << w) - diff)  # modular lane distance
        assert mod.max() <= 1, (widths, i)
    assert (got[:, 0] == want[:, 0]).all()


@given(t_steps=sampled_from([1, 2, 3, 4, 8]), seed=integers(0, 2**31))
def test_addpack_kernel_matches_ref_and_core_accumulate(t_steps, seed):
    """The §VII claim exercised through the Pallas kernel: with its one
    guard bit, ``addpack_accumulate`` (two 14-bit lanes per int32 word) is
    bit-exact vs plain per-lane sums AND vs ``core.addpack.accumulate`` on
    the equivalent two-lane config, for half-range terms (2-term chunk sums
    fit the lane)."""
    from repro.kernels.addpack_acc import (
        GUARD_BITS,
        LANE_BITS,
        addpack_accumulate,
        ref_addpack_accumulate,
    )

    rng = np.random.default_rng(seed)
    lim = 1 << (LANE_BITS - 2)
    terms = rng.integers(-lim, lim, (t_steps, 2, 256)).astype(np.int32)
    got = np.asarray(addpack_accumulate(terms, block_n=256, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref_addpack_accumulate(terms)))
    cfg = AddPackConfig((LANE_BITS, LANE_BITS), guard_bits=GUARD_BITS,
                        total_bits=32)
    core = accumulate(cfg, terms.transpose(2, 0, 1))  # (N, T, lane) → (N, lane)
    np.testing.assert_array_equal(got, core.T)


@given(seed=integers(0, 2**31))
def test_addpack_no_guard_modular_wce_is_one(seed):
    cfg = AddPackConfig((9,) * 5, guard_bits=0)
    rng = np.random.default_rng(seed)
    x = rng.integers(-256, 256, (512, 5))
    y = rng.integers(-256, 256, (512, 5))
    got = packed_lane_add(cfg, x, y)
    want = lane_add_expected(cfg, x, y)
    diff = np.abs(got - want)
    mod = np.minimum(diff, 512 - diff)  # modular lane distance
    assert mod.max() <= 1  # paper Table III: WCE = 1
    assert (mod[:, 0] == 0).all()  # lowest lane is always exact


# ---- enumerator round-trips (tuning.plans → core.packing primitives) -----


def test_enumerator_emits_overpacked_configs():
    """The δ<0 family (§VI) is part of the emitted search space."""
    assert ENUMERATED_NONNEG and ENUMERATED_OVERPACKED


@given(seed=integers(0, 2**31), case=integers(0, 10**6))
def test_roundtrip_exact_for_every_emitted_nonneg_config(seed, case):
    """pack → one wide multiply → extract recovers the exact outer product
    for EVERY δ≥0 config the enumerator emits (full correction, Eqn. 7).

    Spelled with the raw primitives (pack_activations/pack_weights/
    multiply_packed/extract_fields) rather than ``simulate`` so the
    round-trip itself — not just the convenience wrapper — is the property.
    """
    cfg = ENUMERATED_NONNEG[case % len(ENUMERATED_NONNEG)]
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng, n=256)
    assert pack_activations(cfg, a).shape == a.shape[:-1]
    assert (pack_weights(cfg, w) < 0).any() or (w >= 0).all()
    p = multiply_packed(cfg, a, w)
    fields = extract_fields(cfg, p, round_half_up=True)
    np.testing.assert_array_equal(fields, outer_product_exact(cfg, a, w))


@given(seed=integers(0, 2**31), case=integers(0, 10**6))
def test_mr_restore_bounds_error_for_every_emitted_overpacked_config(seed, case):
    """For every δ<0 config emitted, restoring the corrupted MSBs from the
    exactly-recomputed LSBs of the field above (Eqns. 8/9) bounds the
    remaining error by 2^|δ| — the spill of the field *below*, which the
    restore deliberately leaves (paper Table I: WCE 1/2/4 at δ −1/−2/−3)."""
    cfg = ENUMERATED_OVERPACKED[case % len(ENUMERATED_OVERPACKED)]
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng, n=256)
    exact = outer_product_exact(cfg, a, w)
    restored = np.abs(simulate(cfg, a, w, scheme="mr") - exact)
    assert restored.max() <= 2 ** (-cfg.delta)
    # the bottom field has nothing below it: always exact after restore
    bottom = int(np.argmin(cfg.r_offsets))
    assert (restored[..., bottom] == 0).all()


@given(
    seed=integers(0, 2**31),
    case=integers(0, 10**6),
    half_up=booleans(),
)
def test_mr_restore_is_identity_for_nonneg_delta(seed, case, half_up):
    """mr_restore touches nothing when fields don't overlap (δ ≥ 0)."""
    cfg = ENUMERATED_NONNEG[case % len(ENUMERATED_NONNEG)]
    rng = np.random.default_rng(seed)
    a, w = _random_operands(cfg, rng, n=128)
    fields = extract_fields(cfg, multiply_packed(cfg, a, w), round_half_up=half_up)
    np.testing.assert_array_equal(mr_restore(cfg, fields, a, w), fields)


@given(pair=tuples(integers(0, 3), integers(0, 2**31)))
def test_emitted_configs_fit_dsp48_ports(pair):
    """Everything the enumerator emits respects the 17/26/47-bit budgets."""
    idx, _ = pair
    for cfg in enumerate_packing_configs(*_WIDTH_PAIRS[idx]):
        assert cfg.fits_dsp48()
        if cfg.delta < 0:  # overlap never reaches past the adjacent field
            width = cfg.a_widths[0] + cfg.w_widths[0]
            assert 2 * (width + cfg.delta) >= width
