"""Benchmark regression gate for the CI slow lane.

Reads ``BENCH_serving.json`` (fresh from the harness step that precedes it
in the workflow) and fails the job when a headline serving ratio regresses
below its floor:

* ``decode.int4_packed_vs_float >= 1.0`` — prepacked packed decode holds
  the float baseline's throughput.
* ``decode.dsp_mixed_vs_uniform_int4 >= 1.0`` — the mixed-precision claim:
  sensitivity-allocated per-layer widths serve at least as fast as the
  uniform int4 baseline.
* ``families.moe.int4_packed_vs_float >= 0.75`` — the per-expert packed
  MoE row (split expert stacks, each expert served through its own
  packed plan).  The floor sits below parity on purpose: per-expert
  dispatch runs E small GEMVs where the float path runs one stacked
  einsum, and on CPU that overhead measures ~0.79x float (per-step
  median, repeating within a few percent).  With the default slack the
  threshold is 0.63 — low enough for runner noise, high enough to catch
  the regression class where expert stacks silently fall back to a
  repack-per-step or per-token path (the 0.29x class).

Both floors carry a ``--slack`` (default 0.12), and the margin is doing
real work: on CPU every exact packed plan runs the identical f32 GEMM as
the float path through the ``w_f32`` shortcut plus a small quantize/
zero-point overhead, so the TRUE ratio sits at parity-minus-epsilon —
measured 0.94–1.0 with the per-step-median methodology, repeating within
±2 %.  The slack sits well below that documented worst honest
measurement (0.94 − 0.02 = 0.92 > 1.0 − 0.12 = 0.88), so a loaded
nightly runner at the low end still passes.  The regression class this
gate exists for is the catastrophic one — e.g. the pre-PR-4
per-step-repacking path at 0.29x — and that it catches at any slack
below 0.7.  ``--strict`` sets the slack to zero for quiet-machine (TPU)
runs where the density claim is real.

``--tuning BENCH_tuning.json`` additionally gates the plan table's static
pedigree: every row is emitted with its ``certificate`` summary
(``tuner.PlanReport.to_json``), and the gate cross-checks measurement
against proof — ``provably_exact`` rows must carry an ``exact`` verdict,
certified-exact rows must have measured zero error, and bounded rows must
carry a positive certified MAE bound.  A mismatch means the verifier and
the measurement harness disagree about the same plan — always a bug.

``--traffic BENCH_traffic.json`` additionally gates the continuous-
batching claim from the traffic bench (Poisson arrivals, mixed lengths,
memory-parity engines):

* ``ratios.continuous_vs_fifo_tok_s >= 1.0`` — continuous batching
  sustains at least the fixed-slot engine's throughput on the same KV
  budget.
* ``ratios.fifo_vs_continuous_ttft_p99 >= 1.0`` — its tail TTFT is no
  worse than FIFO's (the ratio is FIFO's p99 over continuous's, so >1
  means continuous wins the tail).
* ``ratios.ungoverned_vs_governed_ttft_p99 >= 1.2`` — the degradation
  claim: under a saturating burst, the governed engine (precision-tier
  governor + per-request deadlines) bounds its *served* tail TTFT where
  the ungoverned twin's tail grows with the queue.  The floor sits above
  parity on purpose, and the governed engine clears it through two
  stacked mechanisms: the narrow-tier swap is a real ~2x decode speedup
  on CPU (a4w4 exact serves at float speed via the f32 shortcut; the
  a8w8 primary's 4-column packed path costs ~2x float), and the
  deadline — calibrated to a fraction of the ungoverned makespan —
  sheds whatever still can't make it, bounding the served tail at
  roughly that fraction.  The honest ratio lands well above 1.2 on any
  machine speed (measured ~5x).  The regression class this row catches
  is the degradation machinery not engaging at all — no tier swap,
  nothing shed, governed == ungoverned — which collapses the ratio to
  ~1.0, below the floor at any slack under 0.2.

Traffic floors share the same ``--slack``: the replay is wall-clock
driven on a shared runner, so per-run jitter in makespan and tail TTFT
is real.  The measured headroom is large (the ratios land well above
their floors on CPU — the paged pool runs more lanes per byte, prefill
interleaves with decode, and shedding bounds the governed tail), so the
gate is calibrated to catch the regression class where the mechanism
stops paying for itself at all, not 5 % drifts.

ALL failing ratios across ALL requested files are reported before the
nonzero exit, so one slow-lane run shows the full regression picture.

Exit status 0 when every gate holds, 1 with a per-gate report otherwise —
``python -m benchmarks.check_bench`` after ``python -m benchmarks.run
--only serving`` is the whole contract.
"""

from __future__ import annotations

import argparse
import json
import sys

# (dotted JSON path, floor) — the serving headline ratios under gate
GATES = (
    ("decode.int4_packed_vs_float", 1.0),
    ("decode.dsp_mixed_vs_uniform_int4", 1.0),
    ("families.moe.int4_packed_vs_float", 0.75),
)
# (dotted JSON path, floor) — the traffic-bench continuous-batching gates
TRAFFIC_GATES = (
    ("ratios.continuous_vs_fifo_tok_s", 1.0),
    ("ratios.fifo_vs_continuous_ttft_p99", 1.0),
    ("ratios.ungoverned_vs_governed_ttft_p99", 1.2),
)
DEFAULT_SLACK = 0.12


def _lookup(blob: dict, dotted: str):
    node = blob
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check(bench_path: str, slack: float = DEFAULT_SLACK,
          gates=GATES) -> list[str]:
    """Gate failures for ``bench_path`` (empty list == all gates hold)."""
    try:
        with open(bench_path) as f:
            blob = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{bench_path}: unreadable benchmark JSON ({e})"]
    failures = []
    for dotted, floor in gates:
        value = _lookup(blob, dotted)
        if value is None:
            failures.append(
                f"{dotted}: missing from {bench_path} — the harness must "
                "emit every gated ratio"
            )
        elif value < floor - slack:
            failures.append(
                f"{dotted}: {value:.4f} < floor {floor} - slack {slack}"
            )
    return failures


def check_tuning(tuning_path: str) -> list[str]:
    """Certificate-coherence failures for a BENCH_tuning.json plan table."""
    try:
        with open(tuning_path) as f:
            blob = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{tuning_path}: unreadable benchmark JSON ({e})"]
    rows = blob.get("plan_table")
    if not rows:
        return [f"{tuning_path}: plan_table missing or empty"]
    failures = []
    for row in rows:
        plan = row.get("plan", "<unnamed>")
        cert = row.get("certificate")
        if not isinstance(cert, dict) or "verdict" not in cert:
            failures.append(
                f"{plan}: row carries no certificate summary — "
                "PlanReport.to_json must stamp the verdict"
            )
            continue
        verdict = cert["verdict"]
        if row.get("provably_exact") and verdict != "exact":
            failures.append(
                f"{plan}: provably_exact but certificate verdict "
                f"{verdict!r}"
            )
        if verdict == "exact" and (
            row.get("mae_per_extraction") != 0 or row.get("wce") != 0
        ):
            failures.append(
                f"{plan}: certified exact but measured "
                f"mae_per_extraction={row.get('mae_per_extraction')} "
                f"wce={row.get('wce')}"
            )
        if verdict == "bounded" and not (
            (cert.get("mae_per_extraction") or 0) > 0
        ):
            failures.append(
                f"{plan}: bounded verdict without a positive certified "
                "MAE bound"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", action="append", default=None,
                    help="serving benchmark JSON (repeatable; default "
                    "BENCH_serving.json)")
    ap.add_argument("--tuning", default=None,
                    help="also gate a BENCH_tuning.json plan table's "
                    "certificate coherence")
    ap.add_argument("--traffic", default=None,
                    help="also gate a BENCH_traffic.json's continuous-"
                    "batching ratios (TRAFFIC_GATES)")
    ap.add_argument("--slack", type=float, default=DEFAULT_SLACK,
                    help="noise margin subtracted from each floor")
    ap.add_argument("--strict", action="store_true",
                    help="no noise margin (slack 0)")
    args = ap.parse_args(argv)
    slack = 0.0 if args.strict else args.slack
    bench_paths = args.bench or ["BENCH_serving.json"]
    failures = []
    for path in bench_paths:
        failures.extend(f"{path}: {msg}" for msg in check(path, slack=slack))
    if args.traffic:
        failures.extend(
            f"{args.traffic}: {msg}" for msg in
            check(args.traffic, slack=slack, gates=TRAFFIC_GATES)
        )
    if args.tuning:
        failures.extend(
            f"{args.tuning}: {msg}" for msg in check_tuning(args.tuning)
        )
    for f in failures:
        print(f"[check_bench] FAIL {f}")
    if not failures:
        for path in bench_paths:
            for dotted, floor in GATES:
                print(f"[check_bench] ok {path}:{dotted} "
                      f"(floor {floor}, slack {slack})")
        if args.traffic:
            for dotted, floor in TRAFFIC_GATES:
                print(f"[check_bench] ok {args.traffic}:{dotted} "
                      f"(floor {floor}, slack {slack})")
        if args.tuning:
            print(f"[check_bench] ok {args.tuning}: plan-table "
                  "certificates coherent")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
