"""Benchmark regression gate for the CI slow lane.

Reads ``BENCH_serving.json`` (fresh from the harness step that precedes it
in the workflow) and fails the job when a headline serving ratio regresses
below its floor:

* ``decode.int4_packed_vs_float >= 1.0`` — prepacked packed decode holds
  the float baseline's throughput.
* ``decode.dsp_mixed_vs_uniform_int4 >= 1.0`` — the mixed-precision claim:
  sensitivity-allocated per-layer widths serve at least as fast as the
  uniform int4 baseline.

Both floors carry a ``--slack`` (default 0.12), and the margin is doing
real work: on CPU every exact packed plan runs the identical f32 GEMM as
the float path through the ``w_f32`` shortcut plus a small quantize/
zero-point overhead, so the TRUE ratio sits at parity-minus-epsilon —
measured 0.94–1.0 with the per-step-median methodology, repeating within
±2 %.  The slack sits well below that documented worst honest
measurement (0.94 − 0.02 = 0.92 > 1.0 − 0.12 = 0.88), so a loaded
nightly runner at the low end still passes.  The regression class this
gate exists for is the catastrophic one — e.g. the pre-PR-4
per-step-repacking path at 0.29x — and that it catches at any slack
below 0.7.  ``--strict`` sets the slack to zero for quiet-machine (TPU)
runs where the density claim is real.

Exit status 0 when every gate holds, 1 with a per-gate report otherwise —
``python -m benchmarks.check_bench`` after ``python -m benchmarks.run
--only serving`` is the whole contract.
"""

from __future__ import annotations

import argparse
import json
import sys

# (dotted JSON path, floor) — the serving headline ratios under gate
GATES = (
    ("decode.int4_packed_vs_float", 1.0),
    ("decode.dsp_mixed_vs_uniform_int4", 1.0),
)
DEFAULT_SLACK = 0.12


def _lookup(blob: dict, dotted: str):
    node = blob
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check(bench_path: str, slack: float = DEFAULT_SLACK) -> list[str]:
    """Gate failures for ``bench_path`` (empty list == all gates hold)."""
    try:
        with open(bench_path) as f:
            blob = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{bench_path}: unreadable benchmark JSON ({e})"]
    failures = []
    for dotted, floor in GATES:
        value = _lookup(blob, dotted)
        if value is None:
            failures.append(
                f"{dotted}: missing from {bench_path} — the harness must "
                "emit every gated ratio"
            )
        elif value < floor - slack:
            failures.append(
                f"{dotted}: {value:.4f} < floor {floor} - slack {slack}"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default="BENCH_serving.json",
                    help="path to the serving benchmark JSON")
    ap.add_argument("--slack", type=float, default=DEFAULT_SLACK,
                    help="noise margin subtracted from each floor")
    ap.add_argument("--strict", action="store_true",
                    help="no noise margin (slack 0)")
    args = ap.parse_args(argv)
    slack = 0.0 if args.strict else args.slack
    failures = check(args.bench, slack=slack)
    for f in failures:
        print(f"[check_bench] FAIL {f}")
    if not failures:
        for dotted, floor in GATES:
            print(f"[check_bench] ok {dotted} (floor {floor}, slack {slack})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
