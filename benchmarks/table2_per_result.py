"""Paper Table II: per-result error statistics for INT4 and MR δ=-2."""

from __future__ import annotations

from repro.core.correction import scheme_stats
from repro.core.packing import int4_packing

from .bench_util import emit, time_us


def run() -> None:
    for tag, cfg, scheme in (
        ("int4", int4_packing(), "naive"),
        ("mr_d-2", int4_packing(-2), "mr"),
    ):
        us = time_us(lambda c=cfg, s=scheme: scheme_stats(c, s), iters=1, warmup=0)
        st = scheme_stats(cfg, scheme)
        for n, (mae, ep, wce) in enumerate(zip(st.mae, st.ep, st.wce)):
            emit(
                f"table2/{tag}/r{n}", us,
                f"MAE={mae:.2f} EP={ep:.2f}% WCE={wce}",
            )
