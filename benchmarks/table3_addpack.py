"""Paper Table III: addition packing error statistics (five 9-bit adders,
no guard bits), exhaustive over the carry-generating lane pair."""

from __future__ import annotations

import numpy as np

from repro.core.addpack import AddPackConfig, lane_add_expected, packed_lane_add

from .bench_util import emit, time_us


def _measure():
    cfg = AddPackConfig((9, 9), guard_bits=0)
    a0 = np.arange(512)
    lo_x, lo_y = np.meshgrid(a0, a0, indexing="ij")
    rng = np.random.default_rng(0)
    hi_x = rng.integers(-256, 256, lo_x.shape)
    hi_y = rng.integers(-256, 256, lo_x.shape)
    x = np.stack([lo_x.ravel() - 256, hi_x.ravel()], -1)
    y = np.stack([lo_y.ravel() - 256, hi_y.ravel()], -1)
    got = packed_lane_add(cfg, x, y)
    want = lane_add_expected(cfg, x, y)
    diff = np.abs(got[:, 1] - want[:, 1])
    mod = np.minimum(diff, 512 - diff)  # modular lane distance (paper WCE=1)
    return mod.mean(), (mod > 0).mean() * 100, mod.max()


def run() -> None:
    us = time_us(_measure, iters=1, warmup=0)
    mae, ep, wce = _measure()
    emit(
        "table3/addition_packing", us,
        f"MAE={mae:.2f} EP={ep:.2f}% WCE={wce} (paper: 0.51/51.83%/1)",
    )
    # guard-bit variant is exact (paper Fig. 8)
    cfg = AddPackConfig((9,) * 4, guard_bits=1)
    rng = np.random.default_rng(1)
    x = rng.integers(-256, 256, (100_000, 4))
    y = rng.integers(-256, 256, (100_000, 4))
    exact = (packed_lane_add(cfg, x, y) == lane_add_expected(cfg, x, y)).all()
    emit("table3/guard_bit_variant", 0.0, f"exact={bool(exact)}")
