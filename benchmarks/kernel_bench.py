"""Kernel micro-benchmarks: packed vs unpacked matmul paths.

CPU timings (interpret mode for Pallas) are NOT the perf claim — the perf
claim is the §Roofline analysis; these timings regression-track the
reference implementations and report achieved arithmetic densities.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ref import INT4_EXACT, INT4_MR_OVERPACKED

from .bench_util import emit, time_us


def run() -> None:
    rng = np.random.default_rng(0)
    m = k = n = 256
    x = jnp.asarray(rng.integers(0, 16, (m, k)).astype(np.int8))
    w = jnp.asarray(rng.integers(-8, 8, (k, n)).astype(np.int8))

    exact = jax.jit(ref.ref_quantized_matmul)
    packed = jax.jit(lambda a, b: ref.ref_packed_matmul(a, b, INT4_EXACT))
    over = jax.jit(lambda a, b: ref.ref_packed_matmul(a, b, INT4_MR_OVERPACKED))

    base_us = time_us(lambda: np.asarray(exact(x, w)))
    emit("kernel/int_matmul_exact_256", base_us, "oracle int32 matmul")
    us = time_us(lambda: np.asarray(packed(x, w)))
    emit(
        "kernel/packed_int4_exact_256", us,
        f"2 products/mul, chunk={INT4_EXACT.chunk}, bit-exact",
    )
    us = time_us(lambda: np.asarray(over(x, w)))
    err = np.abs(np.asarray(over(x, w)) - np.asarray(exact(x, w)))
    emit(
        "kernel/packed_int4_mr_over_256", us,
        f"chunk={INT4_MR_OVERPACKED.chunk} MAE={err.mean():.3f} WCE={err.max()}",
    )

    wp = ref.pack_int4_weights(w)
    x8 = jnp.asarray(rng.integers(-128, 128, (m, k)).astype(np.int8))
    prod = jax.jit(ref.ref_int4_matmul)
    us = time_us(lambda: np.asarray(prod(x8, wp)))
    emit(
        "kernel/int4_packed_storage_256", us,
        f"weight bytes halved: {wp.size}B vs {w.size}B",
    )
    run_extra()


def run_extra() -> None:
    """Flash-attention and addpack kernels (interpret-mode parity checks)."""
    import numpy as np
    from repro.kernels.flash_attention import flash_attention, ref_attention
    from repro.kernels.addpack_acc import addpack_accumulate, ref_addpack_accumulate

    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)).astype(np.float32))
    us = time_us(lambda: np.asarray(flash_attention(q, k, v, interpret=True)), warmup=1, iters=2)
    err = float(jnp.abs(flash_attention(q, k, v, interpret=True) - ref_attention(q, k, v)).max())
    emit("kernel/flash_attention_512", us, f"maxerr={err:.1e} (S x S never materialized)")

    terms = jnp.asarray(rng.integers(-2000, 2000, (64, 2, 256)).astype(np.int32))
    us = time_us(lambda: np.asarray(addpack_accumulate(terms, interpret=True)), warmup=1, iters=2)
    ok = bool((addpack_accumulate(terms, interpret=True) == ref_addpack_accumulate(terms)).all())
    emit("kernel/addpack_accumulate_64x2x256", us, f"exact={ok} (2 lanes per int32 add)")
