"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh) cell, derives the three roofline terms on TPU v5e
constants and identifies the dominant bottleneck:

  compute    = HLO_FLOPs_per_chip / 197e12 FLOP/s        (bf16 MXU peak)
  memory     = HLO_bytes_per_chip / 819e9 B/s            (HBM bandwidth)
  collective = collective_bytes_per_chip / 50e9 B/s      (ICI, 1-link eff.)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` with the scan-body
extrapolation done by the dry-run (XLA counts loop bodies once).
Collective bytes are the per-device result sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
optimized HLO.  ``MODEL_FLOPS = 6·N·D`` (dense) or ``6·N_active·D`` (MoE);
the ratio MODEL/HLO exposes remat and dispatch overheads.

Notes on accounting (EXPERIMENTS.md §Roofline):
  * cost_analysis "bytes accessed" counts every HLO buffer touch; real HBM
    traffic is lower for fusion-resident buffers — the memory term is an
    upper bound.
  * the collective term assumes serialized transfers on ONE 50 GB/s ICI
    link per chip — a lower bound on achievable overlap (v5e has 4 links).
"""

from __future__ import annotations

import glob
import json
import math
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}
# training does fwd+bwd: 3x the fwd matmul work (6ND counts it: 6 = 2*3)
STEP_MULT = {"train_4k": 1.0, "prefill_32k": 1 / 3, "decode_32k": 1 / 3, "long_500k": 1 / 3}


def active_fraction(arch: str) -> float:
    """Share of expert parameters that are active per token."""
    from repro.models.registry import get_config

    cfg = get_config(arch)
    if not cfg.n_experts:
        return 1.0
    return cfg.experts_per_token / cfg.n_experts


def expert_param_share(arch: str) -> float:
    """Fraction of total params that live in expert stacks (by tree walk)."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as T
    from repro.models.registry import get_config

    cfg = get_config(arch)
    if not cfg.n_experts:
        return 0.0
    tree = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    tot = exp = 0
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = math.prod(leaf.shape)
        tot += n
        if "moe/" in key and "router" not in key:
            exp += n
    return exp / tot


def analyze(record: dict) -> dict:
    arch, shape = record["arch"], record["shape"]
    chips = record["n_devices"]
    compute_s = record["flops"] / PEAK_FLOPS
    memory_s = record["bytes_accessed"] / HBM_BW
    coll_s = record["collectives"]["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]

    n = record["n_params"]
    share = expert_param_share(arch)
    n_active = n * (1 - share) + n * share * active_fraction(arch)
    model_flops = 6 * n_active * TOKENS[shape] * STEP_MULT[shape]
    model_flops_per_chip = model_flops / chips
    hlo = record["flops"] or 1.0
    ratio = model_flops_per_chip / hlo
    # roofline fraction: useful model FLOPs per chip-second at the bound
    frac = model_flops_per_chip / PEAK_FLOPS / bound_s if bound_s else 0.0
    return {
        "arch": arch,
        "shape": shape,
        "mesh": record["mesh"],
        "variant": record.get("variant", "baseline"),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_ratio": ratio,
        "roofline_fraction": frac,
        "n_params": n,
        "n_active": int(n_active),
    }


LEVERS = {
    "compute": "cut recompute (remat policy) / shed non-model FLOPs so HLO→model ratio rises",
    "memory": "tighten fusion & bf16 residents; chunk attention to kill S² f32 traffic",
    "collective": "reshard to reduce gathered bytes (bf16 gathers, reduce-scatter grads, 1-axis TP)",
}


def run(out_dir: str = "artifacts/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        rows.append(analyze(rec))
    rows.sort(key=lambda r: (r["shape"], r["arch"], r["mesh"], r["variant"]))
    for r in rows:
        var = "" if r["variant"] == "baseline" else f"__{r['variant']}"
        print(
            f"roofline/{r['arch']}__{r['shape']}__{r['mesh']}{var},0.0,"
            f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"collective={r['collective_s']:.4f}s dominant={r['dominant']} "
            f"model/hlo={r['model_flops_ratio']:.2f} "
            f"roofline_frac={r['roofline_fraction']:.3f}"
        )
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows
