"""Packing-plan autotuner benchmark: the plan table and its serving payoff.

Two sections, written to ``BENCH_tuning.json``:

* **plan table** — every enumerated int4 plan inside the default error
  budget, scored (MAE/EP/WCE per extraction) and wall-clock autotuned over
  the block-size sweep (``tuning.autotune_block`` with ``bench_util``
  timing) on a representative matmul shape.

* **decode tok/s** — steady-state serving decode with the hardcoded
  ``INT4_EXACT`` pair-packed spec (``quant_mode="dsp_packed"``, weights
  re-quantized every call — the pre-tuner baseline) vs the tuner's
  per-layer selection (``quant_mode="dsp_tuned"``, weights quantized once
  onto the fastest in-budget plan).  The acceptance claim lives here: a
  non-default plan beats the hardcoded spec within the default budget.

* **a8w8 column packing** — the best provably-exact multi-DSP column plan
  for 8-bit operands (``n_columns > 1`` — no single-word plan exists inside
  int32), block-autotuned on the kernel probe shape, against the exact int8
  dense matmul baseline on the same shape.

Emits ``name,us_per_call,derived`` CSV rows like the other benchmarks.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import INT4_EXACT, ref_quantized_matmul
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import Engine, ServeConfig
from repro.tuning import DEFAULT_ERROR_BUDGET, rank_plans

from .bench_util import emit, time_us

CFG = ModelConfig(
    name="tuning-bench", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32",
)
SLOTS = 2
MAX_LEN = 128
DECODE_STEPS = 16
# kernel-level probe: decode-like M (slot count), a d_model-scale K/N
KERNEL_SHAPE = (8, 256, 128)
KERNEL_BLOCKS = ((32, 128, 64), (32, 128, 128), (64, 128, 128))


def _bench_decode(params, quant_mode: str) -> tuple[float, Engine]:
    eng = Engine(CFG, params, ServeConfig(
        n_slots=SLOTS, max_len=MAX_LEN, prefill_chunk=16, max_new=MAX_LEN,
        quant_mode=quant_mode,
    ))
    rng = np.random.default_rng(0)
    for _ in range(SLOTS):
        eng.submit(list(rng.integers(2, CFG.vocab_size, size=8)))
    eng.step()  # compile decode
    t0 = time.perf_counter()
    for _ in range(DECODE_STEPS):
        eng.step()
    return SLOTS * DECODE_STEPS / (time.perf_counter() - t0), eng


def run(out_path: str = "BENCH_tuning.json") -> dict:
    # ---- plan table: every in-budget plan, proxy-ranked (cheap), then
    # wall-clock autotuning for the head of the ranking + the baseline ----
    from repro.tuning import autotune_block

    ranked = rank_plans(4, 4, error_budget=DEFAULT_ERROR_BUDGET)

    # Off-TPU every Pallas kernel timing below runs the INTERPRETER — fine
    # for ranking blocks against each other, meaningless against jitted XLA
    # baselines.  The flag rides on every timed row (not just the config) so
    # an interpreted number can never masquerade as a real speedup.
    interpreted = jax.default_backend() != "tpu"
    interp_tag = " [interpreted]" if interpreted else ""

    timed_rows = []
    contenders = ranked[:3]
    if INT4_EXACT not in [r.spec for r in contenders]:
        contenders = contenders + [r for r in rank_plans(4, 4, error_budget=0.0)
                                   if r.spec == INT4_EXACT][:1]
    for report in contenders:
        timings = autotune_block(
            report.spec, KERNEL_SHAPE, blocks=KERNEL_BLOCKS, timer=time_us,
            warmup=1, iters=3,
        )
        best = timings[0]
        row = report.to_json()
        row["block"] = list(best.block)
        row["us_per_call"] = best.us_per_call
        row["interpreted"] = interpreted
        timed_rows.append(row)
        emit(f"tuning_kernel_{report.name}", best.us_per_call,
             f"block={best.block} mae/extr={report.mae_per_extraction:.4f}"
             + interp_tag)

    # ---- a8w8 column packing vs the int8 dense baseline -----------------
    a8_report = rank_plans(8, 8, error_budget=0.0)[0]  # provably exact only
    a8_timings = autotune_block(
        a8_report.spec, KERNEL_SHAPE, blocks=KERNEL_BLOCKS, timer=time_us,
        warmup=1, iters=3,
    )
    a8_best = a8_timings[0]
    m, k, n = KERNEL_SHAPE
    rng8 = np.random.default_rng(8)
    x8 = jnp.asarray(rng8.integers(0, 256, (m, k)), jnp.int32)
    w8 = jnp.asarray(rng8.integers(-128, 128, (k, n)), jnp.int32)
    int8_dense = jax.jit(ref_quantized_matmul)
    int8_us = time_us(lambda: np.asarray(int8_dense(x8, w8)), warmup=1, iters=3)
    a8_row = a8_report.to_json()
    a8_row["block"] = list(a8_best.block)
    a8_row["us_per_call"] = a8_best.us_per_call
    a8_row["int8_dense_us_per_call"] = int8_us
    a8_row["words_per_pair"] = a8_report.spec.n_columns
    # off-TPU the packed kernel runs the Pallas INTERPRETER while the int8
    # dense baseline is jitted XLA — the pair of timings is only a real
    # head-to-head on a TPU backend; elsewhere this row documents the plan
    # + its autotuned block, not a speedup claim
    a8_row["interpreted"] = a8_row["kernel_interpreted"] = interpreted
    emit(f"tuning_kernel_a8w8_{a8_report.name}", a8_best.us_per_call,
         f"block={a8_best.block} columns={a8_report.spec.n_columns} exact"
         + interp_tag)
    emit("tuning_kernel_int8_dense_baseline", int8_us,
         f"shape={KERNEL_SHAPE} exact int32 matmul"
         + (" (vs interpreted kernel: not a head-to-head)"
            if interpreted else ""))

    # ---- serving decode: hardcoded spec vs tuned per-layer plans --------
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    tok_s_hardcoded, _ = _bench_decode(params, "dsp_packed")
    tok_s_tuned, tuned_eng = _bench_decode(params, "dsp_tuned")
    tuned_plans = sorted({r.name for r in tuned_eng.plan_table.values()})

    result = {
        "config": {
            "model": CFG.name, "slots": SLOTS, "decode_steps": DECODE_STEPS,
            "error_budget_mae_per_extraction": DEFAULT_ERROR_BUDGET,
            "kernel_probe_shape": list(KERNEL_SHAPE),
            "hardcoded_spec": INT4_EXACT.name(),
            "backend": jax.default_backend(),
            # off-TPU the kernel timings run the Pallas interpreter — use
            # them for block ranking, not cross-plan comparison; the decode
            # section times the actual serving path
            "kernel_timings_interpreted": jax.default_backend() != "tpu",
        },
        "plan_table": [r.to_json() for r in ranked],
        "kernel_timings": timed_rows,
        "a8w8_column_packed": a8_row,
        "decode": {
            "dsp_packed_hardcoded_tok_s": tok_s_hardcoded,
            "dsp_tuned_tok_s": tok_s_tuned,
            "speedup": tok_s_tuned / tok_s_hardcoded,
            "tuned_plans": tuned_plans,
            "non_default_plan_selected": tuned_plans != [INT4_EXACT.name()],
        },
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    emit("tuning_decode_dsp_packed_hardcoded", 1e6 / tok_s_hardcoded,
         f"{tok_s_hardcoded:.1f} tok/s ({INT4_EXACT.name()})")
    emit("tuning_decode_dsp_tuned", 1e6 / tok_s_tuned,
         f"{tok_s_tuned:.1f} tok/s ({','.join(tuned_plans)}; "
         f"{tok_s_tuned / tok_s_hardcoded:.2f}x)")
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
