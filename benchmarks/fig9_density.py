"""Paper Fig. 9: multiplication packing density per technique, plus the
TPU-adapted (32-bit VPU budget) equivalents."""

from __future__ import annotations

from repro.core.packing import int4_packing, int8_packing, intn_packing
from repro.kernels.ref import INT2_EXACT, INT4_EXACT, INT4_MR_OVERPACKED

from .bench_util import emit


def run() -> None:
    rows = [
        ("int8_xilinx", int8_packing()),
        ("int4_xilinx", int4_packing()),
        ("intn_6x_3bit", intn_packing((4, 4, 4), (3, 3), delta=0)),
        ("overpack_6x_d-2", intn_packing((4, 4, 4), (5, 5), delta=-2)),
        ("mr_overpack_4x6bit_d-2", intn_packing((6, 6), (6, 6), delta=-2)),
    ]
    for name, cfg in rows:
        emit(
            f"fig9/{name}", 0.0,
            f"rho={cfg.packing_density():.3f} results={cfg.n_results} "
            f"fits_dsp48={cfg.fits_dsp48()}",
        )
    # TPU adaptation: products per 32-bit VPU multiply and K-chunk length
    for name, spec in (
        ("tpu_int4_exact", INT4_EXACT),
        ("tpu_int4_mr_overpacked", INT4_MR_OVERPACKED),
        ("tpu_int2_exact", INT2_EXACT),
    ):
        emit(
            f"fig9/{name}", 0.0,
            f"products_per_mul=2 chunk={spec.chunk} p={spec.p} "
            f"(extraction amortized over {spec.n_pairs} pairs)",
        )
