"""Serving benchmark: prefill and decode tokens/s, float vs packed.

Measures the serving stack's claims:

* **prefill** — the engine's batched chunked prefill (one ``T.forward`` per
  ``chunk`` tokens) against the seed's per-token scan (one forward per
  token, the pre-rebuild baseline, reimplemented here for comparison).
* **decode** — steady-state decode tokens/s with float weights vs the
  PREPACKED weight paths: ``int4_packed`` (nibble storage, operands decoded
  once at engine build), ``dsp_tuned`` (per-layer pair-packed plans,
  weight words packed once) and ``dsp_mixed`` (sensitivity-allocated
  per-layer ``(a_bits, w_bits)`` — ``tuning.suggest_budget`` picks a
  budget at which the bench model genuinely mixes widths; the row
  carries vs-float AND vs-uniform-int4 ratios plus the allocation).
  Decode steps are interleaved ONE STEP at a time across the engines and
  each mode reports its MEDIAN per-step time: load bursts on a shared
  machine inflate a few samples of every mode equally and the median
  ignores them, where the old best-of-window methodology let a single
  quiet window decide a mode's figure (observed ±15 % ratio swings at
  these step costs; the per-step median repeats within ±2 %).
* **per-phase tuned blocks** — one ``autotune_phase_blocks`` sweep on the
  bench's layer shape, pinning that prefill and decode tune independently
  (decode gets small-M GEMV blocks).
* **family rows** — float vs prepacked-int4 decode for one SSM and one
  MoE registry smoke config (``--family <arch>`` overrides the default
  pair), proving the packed path's non-dense coverage carries its
  throughput claim: recurrent state rides chunked prefill and MoE
  experts serve split per-expert packed leaves.

Emits ``name,us_per_call,derived`` CSV rows like the other benchmarks and
writes the raw numbers to ``BENCH_serving.json``.
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import Engine, ServeConfig
from repro.tuning import (
    allocate_mixed_plans,
    measure_layer_sensitivity,
    suggest_budget,
)

from .bench_util import emit

CFG = ModelConfig(
    name="serve-bench", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32",
)
SLOTS = 2
MAX_LEN = 256
PROMPT_LEN = 128
CHUNK = 16
# decode measurement volume: DECODE_STEPS * DECODE_TRIALS per-step samples
# per mode (step-interleaved; must stay under the MAX_LEN slot budget)
DECODE_STEPS = 32
DECODE_TRIALS = 6
# dsp_mixed sensitivity pass: candidate width pairs + calibration volume
# (smoke tests shrink these like the shape constants above)
MIXED_WIDTHS = ((4, 4), (8, 4), (4, 8), (8, 8))
CALIB_TOKENS = 32
# non-dense family rows (--family overrides): one SSM and one MoE smoke
# config decode float vs packed through the same interleaved-median loop
FAMILY_ARCHS = ("xlstm-1.3b", "moonshot-v1-16b-a3b")
FAMILY_MAX_LEN = 128


@partial(jax.jit, static_argnums=(1,))
def _per_token_prefill(params, cfg, cache, tokens, slot):
    """The seed engine's prefill: one forward per token through a scan."""
    one_cache = jax.tree.map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1), cache
    )

    def body(carry, tok_pos):
        cache_s, _ = carry
        tok, pos = tok_pos
        logits, new_c, _ = T.forward(
            params, cfg, tok[None, None], positions=pos[None, None],
            cache=cache_s,
        )
        return (new_c, logits[0, -1]), None

    pos = jnp.arange(tokens.shape[0])
    init = jnp.zeros((cfg.vocab_size,), jnp.float32)
    (one_cache, last), _ = jax.lax.scan(body, (one_cache, init), (tokens, pos))
    return one_cache, last


def _block(tree):
    jax.block_until_ready(jax.tree.leaves(tree)[0])


def _bench_prefill_per_token(params, prompt) -> float:
    cache = T.init_cache(CFG, SLOTS, MAX_LEN)
    toks = jnp.asarray(prompt, jnp.int32)
    _block(_per_token_prefill(params, CFG, cache, toks, 0))  # compile
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        _block(_per_token_prefill(params, CFG, cache, toks, 0))
    dt = (time.perf_counter() - t0) / iters
    return len(prompt) / dt


def _bench_prefill_chunked(params, prompt) -> float:
    eng = Engine(CFG, params, ServeConfig(
        n_slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK, max_new=1,
    ))
    eng.generate([list(prompt)])  # compile both jit programs, free the slot
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        eng.generate([list(prompt)])
    dt = (time.perf_counter() - t0) / iters
    return len(prompt) / dt


def _decode_engine(params, quant_mode: str, mixed_allocation=None,
                   cfg: ModelConfig = None, max_len: int = None,
                   **cfg_kwargs) -> Engine:
    """An engine warmed into steady-state decode (slots full, jit traced)."""
    cfg = CFG if cfg is None else cfg
    max_len = MAX_LEN if max_len is None else max_len
    eng = Engine(cfg, params, ServeConfig(
        n_slots=SLOTS, max_len=max_len, prefill_chunk=CHUNK,
        max_new=max_len, quant_mode=quant_mode, **cfg_kwargs,
    ), mixed_allocation=mixed_allocation)
    rng = np.random.default_rng(0)
    for _ in range(SLOTS):
        eng.submit(list(rng.integers(2, cfg.vocab_size, size=8)))
    eng.step()  # compile decode
    return eng


def _bench_decode_modes(engines: dict[str, Engine]) -> dict[str, float]:
    """Steady-state decode tok/s per mode from MEDIAN per-step time over
    step-interleaved samples (mode A step, mode B step, ... repeated):
    every mode samples the same machine-load profile and the median
    discards the burst outliers that made window-best figures swing."""
    times: dict[str, list[float]] = {m: [] for m in engines}
    for _ in range(DECODE_STEPS * DECODE_TRIALS):
        for mode, eng in engines.items():
            t0 = time.perf_counter()
            eng.step()
            times[mode].append(time.perf_counter() - t0)
    return {
        m: SLOTS / statistics.median(v) for m, v in times.items()
    }


def _bench_family(arch: str) -> dict:
    """Float vs prepacked-int4 steady-state decode for a registry smoke
    config (the non-dense families the packed path now serves: recurrent
    state rides the chunked-prefill valid mask, MoE experts serve split
    per-expert packed leaves)."""
    import dataclasses as _dc

    from repro.models.registry import get_config

    cfg = _dc.replace(get_config(arch, smoke=True), dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engines = {
        "native": _decode_engine(params, "native", cfg=cfg,
                                 max_len=FAMILY_MAX_LEN),
        "int4_packed": _decode_engine(params, "int4_packed", cfg=cfg,
                                      max_len=FAMILY_MAX_LEN),
    }
    decode = _bench_decode_modes(engines)
    return {
        "arch": arch,
        "family": cfg.family,
        "float_tok_s": decode["native"],
        "int4_packed_tok_s": decode["int4_packed"],
        "int4_packed_vs_float": decode["int4_packed"] / decode["native"],
    }


def _mixed_allocation(params):
    """The bench's mixed-precision operating point: one sensitivity pass
    (the expensive stage — n_paths x n_widths probe forwards), then
    ``suggest_budget`` starts at half the error a full demotion would add
    and backs off until the greedy allocator demotes only the tolerant
    layers — so the bench model serves a genuinely mixed per-layer width
    assignment (the acceptance claim).  The allocation is handed to the
    engine so the pass runs ONCE, not again inside the engine build."""
    cfg_q = dataclasses.replace(
        CFG, quant=dataclasses.replace(CFG.quant, mode="dsp_tuned")
    )
    sens = measure_layer_sensitivity(
        params, cfg_q, widths=MIXED_WIDTHS, n_calib_tokens=CALIB_TOKENS
    )
    budget = suggest_budget(sens, widths=MIXED_WIDTHS, fraction=0.5)
    return allocate_mixed_plans(sens, budget, widths=MIXED_WIDTHS)


def _phase_tuned_blocks() -> dict:
    """Per-phase block tuning on the bench's layer shape: the decode GEMV
    (M = slot count) and the chunked-prefill grid tune independently."""
    from repro.kernels.ref import INT4_EXACT
    from repro.tuning import autotune_phase_blocks

    shapes = {
        "prefill": (SLOTS * CHUNK, CFG.d_model, CFG.d_ff),
        "decode": (SLOTS, CFG.d_model, CFG.d_ff),
    }
    phased = autotune_phase_blocks(INT4_EXACT, shapes, warmup=1, iters=3)
    return {
        phase: {"block": list(t.block), "us_per_call": t.us_per_call}
        for phase, t in phased.items()
    }


def run(out_path: str = "BENCH_serving.json", families=None) -> dict:
    families = FAMILY_ARCHS if families is None else families
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    prompt = list(np.random.default_rng(0).integers(2, CFG.vocab_size,
                                                    size=PROMPT_LEN))
    per_token = _bench_prefill_per_token(params, prompt)
    chunked = _bench_prefill_chunked(params, prompt)
    mixed = _mixed_allocation(params)
    engines = {
        "native": _decode_engine(params, "native"),
        "int4_packed": _decode_engine(params, "int4_packed"),
        "dsp_tuned": _decode_engine(params, "dsp_tuned"),
        "dsp_mixed": _decode_engine(
            params, "dsp_mixed", mixed_allocation=mixed,
            mixed_budget=mixed.budget,
            width_candidates=MIXED_WIDTHS, calib_tokens=CALIB_TOKENS,
        ),
    }
    decode = _bench_decode_modes(engines)
    dec_float = decode["native"]
    dec_packed = decode["int4_packed"]
    dec_tuned = decode["dsp_tuned"]
    dec_mixed = decode["dsp_mixed"]
    tuned_blocks = _phase_tuned_blocks()
    family_rows = {}
    for arch in families:
        row = _bench_family(arch)
        family_rows[row["family"]] = row

    result = {
        "config": {"slots": SLOTS, "prompt_len": PROMPT_LEN, "chunk": CHUNK,
                   "decode_steps": DECODE_STEPS,
                   "decode_trials": DECODE_TRIALS, "model": CFG.name,
                   "backend": jax.default_backend()},
        "prefill": {
            "per_token_tok_s": per_token,
            "chunked_tok_s": chunked,
            "speedup": chunked / per_token,
        },
        "decode": {
            # the packed rows run the PREPACKED fast path: weights packed /
            # decoded once at engine build, zero per-step repacking
            "decode_path": "prepacked",
            "methodology": "per-step-interleaved-median",
            "float_tok_s": dec_float,
            "int4_packed_tok_s": dec_packed,
            "dsp_tuned_tok_s": dec_tuned,
            "dsp_mixed_tok_s": dec_mixed,
            "int4_packed_vs_float": dec_packed / dec_float,
            "dsp_tuned_vs_float": dec_tuned / dec_float,
            "dsp_mixed_vs_float": dec_mixed / dec_float,
            # uniform-int4 = the int4_packed row (the nibble-prepacked
            # uniform-width baseline the mixed allocator competes with)
            "dsp_mixed_vs_uniform_int4": dec_mixed / dec_packed,
        },
        # the per-layer width allocation behind the dsp_mixed row
        # (assignments, distinct_widths, budget, cost vs uniform base)
        "mixed": mixed.summary(),
        "tuned_blocks": tuned_blocks,
        # non-dense family decode rows keyed by family name ("ssm", "moe")
        "families": family_rows,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    emit("serving_prefill_per_token", 1e6 / per_token,
         f"{per_token:.1f} tok/s")
    emit("serving_prefill_chunked", 1e6 / chunked,
         f"{chunked:.1f} tok/s ({chunked / per_token:.1f}x per-token)")
    emit("serving_decode_float", 1e6 / dec_float, f"{dec_float:.1f} tok/s")
    emit("serving_decode_int4_packed", 1e6 / dec_packed,
         f"{dec_packed:.1f} tok/s (prepacked; "
         f"{dec_packed / dec_float:.2f}x float)")
    emit("serving_decode_dsp_tuned", 1e6 / dec_tuned,
         f"{dec_tuned:.1f} tok/s (prepacked plans; "
         f"{dec_tuned / dec_float:.2f}x float)")
    emit("serving_decode_dsp_mixed", 1e6 / dec_mixed,
         f"{dec_mixed:.1f} tok/s ({mixed.distinct_widths} widths; "
         f"{dec_mixed / dec_float:.2f}x float, "
         f"{dec_mixed / dec_packed:.2f}x uniform-int4)")
    for phase, row in tuned_blocks.items():
        emit(f"serving_tuned_block_{phase}", row["us_per_call"],
             f"block={tuple(row['block'])}")
    for fam, row in family_rows.items():
        emit(f"serving_family_{fam}_int4",
             1e6 / row["int4_packed_tok_s"],
             f"{row['int4_packed_tok_s']:.1f} tok/s "
             f"({row['int4_packed_vs_float']:.2f}x float; {row['arch']})")
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--family", action="append", metavar="ARCH", default=None,
        help="registry arch for a family decode row (repeatable; "
             f"default: {', '.join(FAMILY_ARCHS)})",
    )
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="output JSON path")
    cli = ap.parse_args()
    print("name,us_per_call,derived")
    run(cli.out, families=cli.family)
