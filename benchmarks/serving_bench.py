"""Serving benchmark: prefill and decode tokens/s, float vs packed.

Measures the serving rebuild's two claims:

* **prefill** — the engine's batched chunked prefill (one ``T.forward`` per
  ``chunk`` tokens) against the seed's per-token scan (one forward per
  token, the pre-rebuild baseline, reimplemented here for comparison).
* **decode** — steady-state decode tokens/s with float weights vs the
  packed int4 decode path (``quant_mode="int4_packed"``).

Emits ``name,us_per_call,derived`` CSV rows like the other benchmarks and
writes the raw numbers to ``BENCH_serving.json``.
"""

from __future__ import annotations

import json
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import Engine, ServeConfig

from .bench_util import emit

CFG = ModelConfig(
    name="serve-bench", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32",
)
SLOTS = 2
MAX_LEN = 256
PROMPT_LEN = 128
CHUNK = 16
DECODE_STEPS = 32


@partial(jax.jit, static_argnums=(1,))
def _per_token_prefill(params, cfg, cache, tokens, slot):
    """The seed engine's prefill: one forward per token through a scan."""
    one_cache = jax.tree.map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1), cache
    )

    def body(carry, tok_pos):
        cache_s, _ = carry
        tok, pos = tok_pos
        logits, new_c, _ = T.forward(
            params, cfg, tok[None, None], positions=pos[None, None],
            cache=cache_s,
        )
        return (new_c, logits[0, -1]), None

    pos = jnp.arange(tokens.shape[0])
    init = jnp.zeros((cfg.vocab_size,), jnp.float32)
    (one_cache, last), _ = jax.lax.scan(body, (one_cache, init), (tokens, pos))
    return one_cache, last


def _block(tree):
    jax.block_until_ready(jax.tree.leaves(tree)[0])


def _bench_prefill_per_token(params, prompt) -> float:
    cache = T.init_cache(CFG, SLOTS, MAX_LEN)
    toks = jnp.asarray(prompt, jnp.int32)
    _block(_per_token_prefill(params, CFG, cache, toks, 0))  # compile
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        _block(_per_token_prefill(params, CFG, cache, toks, 0))
    dt = (time.perf_counter() - t0) / iters
    return len(prompt) / dt


def _bench_prefill_chunked(params, prompt) -> float:
    eng = Engine(CFG, params, ServeConfig(
        n_slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK, max_new=1,
    ))
    eng.generate([list(prompt)])  # compile both jit programs, free the slot
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        eng.generate([list(prompt)])
    dt = (time.perf_counter() - t0) / iters
    return len(prompt) / dt


def _bench_decode(params, quant_mode: str) -> float:
    eng = Engine(CFG, params, ServeConfig(
        n_slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
        max_new=MAX_LEN, quant_mode=quant_mode,
    ))
    rng = np.random.default_rng(0)
    for _ in range(SLOTS):
        eng.submit(list(rng.integers(2, CFG.vocab_size, size=8)))
    eng.step()  # compile decode
    t0 = time.perf_counter()
    for _ in range(DECODE_STEPS):
        eng.step()
    dt = time.perf_counter() - t0
    return SLOTS * DECODE_STEPS / dt


def run(out_path: str = "BENCH_serving.json") -> dict:
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    prompt = list(np.random.default_rng(0).integers(2, CFG.vocab_size,
                                                    size=PROMPT_LEN))
    per_token = _bench_prefill_per_token(params, prompt)
    chunked = _bench_prefill_chunked(params, prompt)
    dec_float = _bench_decode(params, "native")
    dec_packed = _bench_decode(params, "int4_packed")

    result = {
        "config": {"slots": SLOTS, "prompt_len": PROMPT_LEN, "chunk": CHUNK,
                   "decode_steps": DECODE_STEPS, "model": CFG.name},
        "prefill": {
            "per_token_tok_s": per_token,
            "chunked_tok_s": chunked,
            "speedup": chunked / per_token,
        },
        "decode": {
            "float_tok_s": dec_float,
            "int4_packed_tok_s": dec_packed,
        },
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    emit("serving_prefill_per_token", 1e6 / per_token,
         f"{per_token:.1f} tok/s")
    emit("serving_prefill_chunked", 1e6 / chunked,
         f"{chunked:.1f} tok/s ({chunked / per_token:.1f}x per-token)")
    emit("serving_decode_float", 1e6 / dec_float, f"{dec_float:.1f} tok/s")
    emit("serving_decode_int4_packed", 1e6 / dec_packed,
         f"{dec_packed:.1f} tok/s")
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
