"""Benchmark harness: one module per paper table/figure + kernel and
roofline reports.  Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default=None,
        help="comma-separated subset: "
             "table1,table2,table3,fig9,kernel,roofline,serving,tuning,"
             "traffic",
    )
    args = ap.parse_args()
    from . import (
        fig9_density,
        kernel_bench,
        roofline,
        serving_bench,
        table1_packing,
        table2_per_result,
        table3_addpack,
        traffic_bench,
        tuning_bench,
    )

    print("name,us_per_call,derived")
    mods = {
        "table1": table1_packing.run,
        "table2": table2_per_result.run,
        "table3": table3_addpack.run,
        "fig9": fig9_density.run,
        "kernel": kernel_bench.run,
        "roofline": roofline.run,
        "serving": serving_bench.run,
        "tuning": tuning_bench.run,
        "traffic": traffic_bench.run,
    }
    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(selected) - set(mods))
        if unknown:
            # a typo'd --only must fail loudly, not skip benchmarks: a CI
            # lane that silently produced no BENCH_*.json looks green
            ap.error(
                f"unknown benchmark name(s): {', '.join(unknown)} "
                f"(valid: {', '.join(mods)})"
            )
    else:
        selected = list(mods)
    for name in selected:
        mods[name]()


if __name__ == "__main__":
    main()
