"""Traffic benchmark: continuous batching vs fixed-slot FIFO under load.

Replays ONE seeded open-loop workload — Poisson arrivals at ``RATE_HZ``
with a 70/25/5 mix of short, long and XL requests — against both engines
at **memory parity**: the fixed-slot ``Engine`` gets ``FIFO_SLOTS`` dense
windows of the chunk-padded ``MAX_LEN`` grid, and ``ContinuousEngine``
gets the same KV budget as a shared page pool (``n_pages * page_size ==
FIFO_SLOTS * grid``) spread over more lanes.  The mechanisms under test:

* the paged pool admits by *actual* footprint (a short request holds a
  handful of 8-token pages, not a 192-token window), so more requests
  decode concurrently on the same memory;
* prefill runs one chunk per engine step *interleaved* with decode,
  where the slot engine's admission runs a whole prompt's chunks while
  every decoding slot stalls — the head-of-line blocking a mixed-length
  queue exposes.

The arrival clock is wall time: arrivals whose timestamp has passed are
submitted before each engine step, and the engine sleeps only when truly
idle.  The rate is chosen to saturate both engines, so the measured
makespan is capacity-limited and ``sustained tok/s`` compares real
throughput, not offered load.

Each engine replays the workload ``REPEATS`` times and the run with the
higher sustained tok/s is reported (same treatment for both engines):
the replay clock is wall time on a shared CPU, and best-of repeats keeps
a transient system hiccup in one replay from polluting the gated ratios.

Reported per engine: sustained tok/s (emitted tokens / makespan), p50/p99
TTFT, p50/p99 per-output-token latency (both from the scheduler's
percentile aggregation), finished/preempted counts.  The headline ratios
``continuous_vs_fifo_tok_s`` and ``fifo_vs_continuous_ttft_p99`` are
gated in ``check_bench.py`` (see TRAFFIC_GATES there for the documented
noise slack).  Emits ``name,us_per_call,derived`` CSV rows and writes
``BENCH_traffic.json``.

**Degradation replay.**  A second workload — every request submitted at
once, a saturating burst — runs against two ``dsp_tuned`` continuous
engines: one *ungoverned* (no deadline, no governor: every request
waits however long the queue takes) and one *governed* (precision-tier
governor + per-request deadline).  The deadline is calibrated from the
ungoverned replay's own makespan (``DEGRADE_DEADLINE_FRAC`` of it), so
the burst saturates the deadline on any machine speed.  The governed
engine swaps to its narrow tier while the queue is deep and sheds
requests that cannot make their deadline, which bounds the *served*
tail: ``ratios.ungoverned_vs_governed_ttft_p99`` lands well above 1 and
is gated in ``check_bench.py``.  Mechanism note (measured, CPU): the
a4w4 narrow tier serves at float speed through the proven-exact f32
shortcut (~1.0x native), while the a8w8 primary's 4-column packed path
costs ~2x float per decode step — so the swap buys a genuine ~2x
throughput here and the queue can drain *before* deadlines fire (a
healthy run may shed zero requests); deadline shedding is the backstop
that bounds the tail when even the narrow tier can't keep up.  The
gate catches the regression class where the degradation machinery
stops engaging (no swap, no shed → governed == ungoverned → ratio
collapses to ~1.0).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import (ContinuousEngine, Engine, GovernorConfig,
                           ServeConfig, percentile)

from .bench_util import emit

# Decode must be weight-bandwidth-bound for continuous batching to pay:
# at serving shapes the per-step cost is dominated by streaming the
# weights, so a wider decode batch amortizes the same weight traffic over
# more emitted tokens (measured here: an 8-lane step costs ~2x a 2-lane
# step, not 4x).  A toy-width model (d_model=64) is compute-bound — every
# extra lane costs proportionally more and NO batching scheme can win —
# so the bench model is sized to the bandwidth-bound regime the serving
# stack actually targets (it is the same regime that makes the paper's
# packed-weight decode pay, README "Packed-weight decode").
CFG = ModelConfig(
    name="traffic-bench", family="dense", n_layers=2, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab_size=1024, dtype="float32",
)
MAX_LEN = 192
CHUNK = 8
PAGE_SIZE = 8
# memory parity: FIFO_SLOTS dense windows == N_PAGES * PAGE_SIZE pooled.
# max_len is provisioned for the rare XL request (the worst case a server
# must accept), so each dense slot reserves a 192-token window while the
# typical request needs ~20-60 tokens — the regime paged allocation
# exists for.  On the same budget the paged pool runs 8 lanes where the
# dense engine affords 2 windows.
FIFO_SLOTS = 2
CONT_LANES = 8
WATERMARK = 8   # one in-flight growth page per lane
# workload: open-loop Poisson arrivals at a saturating rate (the offered
# token rate is several times either engine's capacity, so makespan is
# capacity-limited and sustained tok/s compares real throughput).
# 70 % short / 25 % long / 5 % XL; the XL class is what forces
# max_len=192 provisioning.
N_REQUESTS = 96
RATE_HZ = 400.0
SHORT_PROMPT = (4, 9)      # rng.integers bounds (lo, hi)
SHORT_MAX_NEW = (24, 33)
LONG_PROMPT = (24, 33)
LONG_MAX_NEW = (48, 65)
XL_PROMPT = (64, 97)
XL_MAX_NEW = (32, 49)
SEED = 0
REPEATS = 2  # best-of replays per engine (wall-clock noise suppression)
# degradation replay: a saturating burst (all requests at t=0) against a
# governed engine (precision tiers + calibrated per-request deadline) and
# an ungoverned twin.  The deadline is DEGRADE_DEADLINE_FRAC of the
# ungoverned replay's measured makespan — self-calibrating, so the burst
# saturates the deadline at any machine speed.
DEGRADE_REQUESTS = 32
DEGRADE_DEADLINE_FRAC = 1.0 / 3.0
DEGRADE_PRIMARY_BITS = (8, 8)   # governed tier 0 (and the ungoverned twin)
DEGRADE_NARROW_BITS = (4, 4)    # governed tier 1, swapped in under load
DEGRADE_QUEUE_HIGH = 6
DEGRADE_HOLD_STEPS = 2


def _grid() -> int:
    return -(-MAX_LEN // CHUNK) * CHUNK


def _workload(rng: np.random.Generator):
    """[(prompt, max_new), ...] + arrival offsets (seconds)."""
    reqs = []
    for _ in range(N_REQUESTS):
        u = rng.random()
        if u < 0.70:
            p_lo, p_hi = SHORT_PROMPT
            n_lo, n_hi = SHORT_MAX_NEW
        elif u < 0.95:
            p_lo, p_hi = LONG_PROMPT
            n_lo, n_hi = LONG_MAX_NEW
        else:
            p_lo, p_hi = XL_PROMPT
            n_lo, n_hi = XL_MAX_NEW
        prompt = list(rng.integers(2, CFG.vocab_size,
                                   size=int(rng.integers(p_lo, p_hi))))
        reqs.append((prompt, int(rng.integers(n_lo, n_hi))))
    arrivals = np.cumsum(rng.exponential(1.0 / RATE_HZ, size=N_REQUESTS))
    return reqs, arrivals


def _replay(engine, reqs, arrivals) -> dict:
    """Open-loop replay: submit arrivals whose wall-clock time has passed,
    step the engine, sleep only when idle.  Metrics are computed over the
    replay's own requests (warm-up requests on the same engine instance
    are excluded by rid), from each request's recorded timestamps."""
    first_rid = engine.scheduler.next_rid
    preempted_before = engine.stats().get("preempted", 0)
    t_start = time.monotonic()
    i = 0
    while True:
        now = time.monotonic() - t_start
        while i < len(reqs) and arrivals[i] <= now:
            prompt, max_new = reqs[i]
            engine.submit(prompt, max_new=max_new, admit=False)
            i += 1
        if engine.active.any() or engine.scheduler.n_queued:
            engine.step()
        elif i < len(reqs):
            time.sleep(max(0.0, min(arrivals[i] - now, 0.01)))
        else:
            break
    makespan = time.monotonic() - t_start
    done = [r for r in engine.scheduler.requests.values()
            if r.done and r.rid >= first_rid]
    total_tokens = sum(len(r.tokens) for r in done)
    ttfts = [r.prefill_done_at - r.submitted_at for r in done]
    latencies = [r.finished_at - r.submitted_at for r in done]
    tpots = [(r.finished_at - r.prefill_done_at) / (len(r.tokens) - 1)
             for r in done if len(r.tokens) > 1]
    return {
        "finished": len(done),
        "preempted": engine.stats().get("preempted", 0) - preempted_before,
        "total_tokens": total_tokens,
        "makespan_s": makespan,
        "sustained_tok_s": total_tokens / makespan if makespan > 0 else 0.0,
        "p50_ttft_s": percentile(ttfts, 50.0),
        "p99_ttft_s": percentile(ttfts, 99.0),
        "p50_tpot_s": percentile(tpots, 50.0),
        "p99_tpot_s": percentile(tpots, 99.0),
        "mean_latency_s": sum(latencies) / len(latencies) if latencies
        else 0.0,
    }


def _best_replay(engine, reqs, arrivals) -> dict:
    """Best of ``REPEATS`` replays by sustained tok/s.  Rid bracketing in
    ``_replay`` keeps each repeat's metrics independent, and the engine
    drains fully between repeats (all pages freed), so repeats start from
    identical state with warm jit caches."""
    rows = [_replay(engine, reqs, arrivals) for _ in range(REPEATS)]
    return max(rows, key=lambda r: r["sustained_tok_s"])


def _warm(engine) -> None:
    """Trace every jitted program before timing.  The engines jit their
    step functions per instance, so warm-up must run on the instance the
    replay uses; two mixed-length prompts exercise prefill (multi-chunk
    and single-chunk lanes), decode, sampling and the lm head."""
    long_prompt = list(range(2, 2 + LONG_PROMPT[0]))
    engine.generate([[2, 3, 4, 5], long_prompt], max_new=3)


def build_engines(params):
    grid = _grid()
    n_pages = FIFO_SLOTS * grid // PAGE_SIZE
    fifo = Engine(CFG, params, ServeConfig(
        n_slots=FIFO_SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
        max_new=MAX_LEN,
    ))
    cont = ContinuousEngine(CFG, params, ServeConfig(
        n_slots=CONT_LANES, max_len=MAX_LEN, prefill_chunk=CHUNK,
        max_new=MAX_LEN, page_size=PAGE_SIZE, n_pages=n_pages,
        watermark_pages=WATERMARK,
    ))
    return fifo, cont


def _burst_replay(engine, reqs) -> dict:
    """Closed-burst replay: submit everything at once, step until the
    engine drains (deadline shedding empties the queue on the governed
    engine; the ungoverned one serves every request).  Metrics cover
    *served* requests only — a shed request has no honest latency, and
    the scheduler already keeps cancellations out of its percentiles."""
    first_rid = engine.scheduler.next_rid
    t_start = time.monotonic()
    for prompt, max_new in reqs:
        engine.submit(prompt, max_new=max_new, admit=False)
    while engine.active.any() or engine.scheduler.n_queued:
        engine.step()
    makespan = time.monotonic() - t_start
    done = [r for r in engine.scheduler.requests.values()
            if r.done and r.rid >= first_rid]
    served = [r for r in done if not r.cancelled]
    total_tokens = sum(len(r.tokens) for r in served)
    ttfts = [r.prefill_done_at - r.submitted_at for r in served
             if r.prefill_done_at is not None]
    latencies = [r.finished_at - r.submitted_at for r in served]
    row = {
        "finished": len(served),
        "shed": len(done) - len(served),
        "total_tokens": total_tokens,
        "makespan_s": makespan,
        "sustained_tok_s": total_tokens / makespan if makespan > 0 else 0.0,
        "p50_ttft_s": percentile(ttfts, 50.0),
        "p99_ttft_s": percentile(ttfts, 99.0),
        "mean_latency_s": sum(latencies) / len(latencies) if latencies
        else 0.0,
    }
    stats = engine.stats()
    if "governor" in stats:
        row["governor_swaps"] = stats["governor"]["swaps"]
        row["final_tier"] = stats["governor"]["tier"]
    return row


def _degradation(params, reqs) -> tuple[dict, dict, float]:
    """(ungoverned_row, governed_row, deadline_ms).  The ungoverned twin
    runs first; its makespan calibrates the governed engine's deadline."""
    grid = _grid()
    n_pages = FIFO_SLOTS * grid // PAGE_SIZE
    base = dict(n_slots=CONT_LANES, max_len=MAX_LEN, prefill_chunk=CHUNK,
                max_new=MAX_LEN, page_size=PAGE_SIZE, n_pages=n_pages,
                watermark_pages=WATERMARK, quant_mode="dsp_tuned",
                plan_bits=DEGRADE_PRIMARY_BITS)
    plain = ContinuousEngine(CFG, params, ServeConfig(**base))
    _warm(plain)
    plain_row = _burst_replay(plain, reqs)

    deadline_ms = 1e3 * plain_row["makespan_s"] * DEGRADE_DEADLINE_FRAC
    governed = ContinuousEngine(CFG, params, ServeConfig(
        **base,
        governor=GovernorConfig(queue_high=DEGRADE_QUEUE_HIGH,
                                hold_steps=DEGRADE_HOLD_STEPS,
                                narrow_bits=DEGRADE_NARROW_BITS),
        deadline_ms=deadline_ms,
    ))
    _warm(governed)
    governed_row = _burst_replay(governed, reqs)
    return plain_row, governed_row, deadline_ms


def run(out_path: str = "BENCH_traffic.json") -> dict:
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    reqs, arrivals = _workload(np.random.default_rng(SEED))
    fifo, cont = build_engines(params)
    _warm(fifo)
    _warm(cont)
    fifo_row = _best_replay(fifo, reqs, arrivals)
    cont_row = _best_replay(cont, reqs, arrivals)
    degrade_reqs, _ = _workload(np.random.default_rng(SEED + 1))
    degrade_reqs = degrade_reqs[:DEGRADE_REQUESTS]
    plain_row, governed_row, deadline_ms = _degradation(params, degrade_reqs)

    ratios = {
        "continuous_vs_fifo_tok_s": (
            cont_row["sustained_tok_s"] / fifo_row["sustained_tok_s"]
            if fifo_row["sustained_tok_s"] else 0.0
        ),
        # >1 means FIFO's tail TTFT is worse (continuous wins the tail)
        "fifo_vs_continuous_ttft_p99": (
            fifo_row["p99_ttft_s"] / cont_row["p99_ttft_s"]
            if cont_row["p99_ttft_s"] else 0.0
        ),
        # >1 means the ungoverned burst's served tail TTFT is worse —
        # the degradation stack (tier governor + deadline shedding)
        # bounds the governed tail by construction
        "ungoverned_vs_governed_ttft_p99": (
            plain_row["p99_ttft_s"] / governed_row["p99_ttft_s"]
            if governed_row["p99_ttft_s"] else 0.0
        ),
    }
    result = {
        "config": {
            "model": CFG.name, "backend": jax.default_backend(),
            "max_len": MAX_LEN, "chunk": CHUNK, "page_size": PAGE_SIZE,
            "fifo_slots": FIFO_SLOTS, "cont_lanes": CONT_LANES,
            "n_pages": FIFO_SLOTS * _grid() // PAGE_SIZE,
            "watermark_pages": WATERMARK,
            "n_requests": N_REQUESTS, "rate_hz": RATE_HZ, "seed": SEED,
            "repeats": REPEATS,
            # lists, not tuples, so the dict equals its JSON round-trip
            "short": {"prompt": list(SHORT_PROMPT),
                      "max_new": list(SHORT_MAX_NEW)},
            "long": {"prompt": list(LONG_PROMPT),
                     "max_new": list(LONG_MAX_NEW)},
            "xl": {"prompt": list(XL_PROMPT), "max_new": list(XL_MAX_NEW)},
        },
        "fifo": fifo_row,
        "continuous": cont_row,
        "degradation": {
            "n_requests": DEGRADE_REQUESTS,
            "deadline_ms": deadline_ms,
            "deadline_frac": DEGRADE_DEADLINE_FRAC,
            "primary_bits": list(DEGRADE_PRIMARY_BITS),
            "narrow_bits": list(DEGRADE_NARROW_BITS),
            "ungoverned": plain_row,
            "governed": governed_row,
        },
        "ratios": ratios,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    for name, row in (("fifo", fifo_row), ("continuous", cont_row)):
        emit(
            f"traffic_{name}",
            1e6 / row["sustained_tok_s"] if row["sustained_tok_s"] else 0.0,
            f"{row['sustained_tok_s']:.1f} tok/s sustained, "
            f"ttft p50 {row['p50_ttft_s'] * 1e3:.0f}ms "
            f"p99 {row['p99_ttft_s'] * 1e3:.0f}ms, "
            f"{row['finished']} finished, {row['preempted']} preempted",
        )
    emit("traffic_continuous_vs_fifo",
         ratios["continuous_vs_fifo_tok_s"],
         f"{ratios['continuous_vs_fifo_tok_s']:.2f}x sustained tok/s, "
         f"{ratios['fifo_vs_continuous_ttft_p99']:.2f}x p99-TTFT win")
    for name, row in (("ungoverned", plain_row), ("governed", governed_row)):
        emit(
            f"traffic_degrade_{name}",
            1e3 * row["p99_ttft_s"],
            f"ttft p99 {row['p99_ttft_s'] * 1e3:.0f}ms, "
            f"{row['finished']} served, {row['shed']} shed, "
            f"{row.get('governor_swaps', 0)} tier swaps",
        )
    emit("traffic_degrade_ttft_win",
         ratios["ungoverned_vs_governed_ttft_p99"],
         f"{ratios['ungoverned_vs_governed_ttft_p99']:.2f}x served p99-TTFT "
         f"win at a {deadline_ms:.0f}ms deadline "
         f"({DEGRADE_DEADLINE_FRAC:.2f}x ungoverned makespan)")
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
