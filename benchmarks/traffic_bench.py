"""Traffic benchmark: continuous batching vs fixed-slot FIFO under load.

Replays ONE seeded open-loop workload — Poisson arrivals at ``RATE_HZ``
with a 70/25/5 mix of short, long and XL requests — against both engines
at **memory parity**: the fixed-slot ``Engine`` gets ``FIFO_SLOTS`` dense
windows of the chunk-padded ``MAX_LEN`` grid, and ``ContinuousEngine``
gets the same KV budget as a shared page pool (``n_pages * page_size ==
FIFO_SLOTS * grid``) spread over more lanes.  The mechanisms under test:

* the paged pool admits by *actual* footprint (a short request holds a
  handful of 8-token pages, not a 192-token window), so more requests
  decode concurrently on the same memory;
* prefill runs one chunk per engine step *interleaved* with decode,
  where the slot engine's admission runs a whole prompt's chunks while
  every decoding slot stalls — the head-of-line blocking a mixed-length
  queue exposes.

The arrival clock is wall time: arrivals whose timestamp has passed are
submitted before each engine step, and the engine sleeps only when truly
idle.  The rate is chosen to saturate both engines, so the measured
makespan is capacity-limited and ``sustained tok/s`` compares real
throughput, not offered load.

Each engine replays the workload ``REPEATS`` times and the run with the
higher sustained tok/s is reported (same treatment for both engines):
the replay clock is wall time on a shared CPU, and best-of repeats keeps
a transient system hiccup in one replay from polluting the gated ratios.

Reported per engine: sustained tok/s (emitted tokens / makespan), p50/p99
TTFT, p50/p99 per-output-token latency (both from the scheduler's
percentile aggregation), finished/preempted counts.  The headline ratios
``continuous_vs_fifo_tok_s`` and ``fifo_vs_continuous_ttft_p99`` are
gated in ``check_bench.py`` (see TRAFFIC_GATES there for the documented
noise slack).  Emits ``name,us_per_call,derived`` CSV rows and writes
``BENCH_traffic.json``.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import ContinuousEngine, Engine, ServeConfig, percentile

from .bench_util import emit

# Decode must be weight-bandwidth-bound for continuous batching to pay:
# at serving shapes the per-step cost is dominated by streaming the
# weights, so a wider decode batch amortizes the same weight traffic over
# more emitted tokens (measured here: an 8-lane step costs ~2x a 2-lane
# step, not 4x).  A toy-width model (d_model=64) is compute-bound — every
# extra lane costs proportionally more and NO batching scheme can win —
# so the bench model is sized to the bandwidth-bound regime the serving
# stack actually targets (it is the same regime that makes the paper's
# packed-weight decode pay, README "Packed-weight decode").
CFG = ModelConfig(
    name="traffic-bench", family="dense", n_layers=2, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab_size=1024, dtype="float32",
)
MAX_LEN = 192
CHUNK = 8
PAGE_SIZE = 8
# memory parity: FIFO_SLOTS dense windows == N_PAGES * PAGE_SIZE pooled.
# max_len is provisioned for the rare XL request (the worst case a server
# must accept), so each dense slot reserves a 192-token window while the
# typical request needs ~20-60 tokens — the regime paged allocation
# exists for.  On the same budget the paged pool runs 8 lanes where the
# dense engine affords 2 windows.
FIFO_SLOTS = 2
CONT_LANES = 8
WATERMARK = 8   # one in-flight growth page per lane
# workload: open-loop Poisson arrivals at a saturating rate (the offered
# token rate is several times either engine's capacity, so makespan is
# capacity-limited and sustained tok/s compares real throughput).
# 70 % short / 25 % long / 5 % XL; the XL class is what forces
# max_len=192 provisioning.
N_REQUESTS = 96
RATE_HZ = 400.0
SHORT_PROMPT = (4, 9)      # rng.integers bounds (lo, hi)
SHORT_MAX_NEW = (24, 33)
LONG_PROMPT = (24, 33)
LONG_MAX_NEW = (48, 65)
XL_PROMPT = (64, 97)
XL_MAX_NEW = (32, 49)
SEED = 0
REPEATS = 2  # best-of replays per engine (wall-clock noise suppression)


def _grid() -> int:
    return -(-MAX_LEN // CHUNK) * CHUNK


def _workload(rng: np.random.Generator):
    """[(prompt, max_new), ...] + arrival offsets (seconds)."""
    reqs = []
    for _ in range(N_REQUESTS):
        u = rng.random()
        if u < 0.70:
            p_lo, p_hi = SHORT_PROMPT
            n_lo, n_hi = SHORT_MAX_NEW
        elif u < 0.95:
            p_lo, p_hi = LONG_PROMPT
            n_lo, n_hi = LONG_MAX_NEW
        else:
            p_lo, p_hi = XL_PROMPT
            n_lo, n_hi = XL_MAX_NEW
        prompt = list(rng.integers(2, CFG.vocab_size,
                                   size=int(rng.integers(p_lo, p_hi))))
        reqs.append((prompt, int(rng.integers(n_lo, n_hi))))
    arrivals = np.cumsum(rng.exponential(1.0 / RATE_HZ, size=N_REQUESTS))
    return reqs, arrivals


def _replay(engine, reqs, arrivals) -> dict:
    """Open-loop replay: submit arrivals whose wall-clock time has passed,
    step the engine, sleep only when idle.  Metrics are computed over the
    replay's own requests (warm-up requests on the same engine instance
    are excluded by rid), from each request's recorded timestamps."""
    first_rid = engine.scheduler.next_rid
    preempted_before = engine.stats().get("preempted", 0)
    t_start = time.monotonic()
    i = 0
    while True:
        now = time.monotonic() - t_start
        while i < len(reqs) and arrivals[i] <= now:
            prompt, max_new = reqs[i]
            engine.submit(prompt, max_new=max_new, admit=False)
            i += 1
        if engine.active.any() or engine.scheduler.n_queued:
            engine.step()
        elif i < len(reqs):
            time.sleep(max(0.0, min(arrivals[i] - now, 0.01)))
        else:
            break
    makespan = time.monotonic() - t_start
    done = [r for r in engine.scheduler.requests.values()
            if r.done and r.rid >= first_rid]
    total_tokens = sum(len(r.tokens) for r in done)
    ttfts = [r.prefill_done_at - r.submitted_at for r in done]
    latencies = [r.finished_at - r.submitted_at for r in done]
    tpots = [(r.finished_at - r.prefill_done_at) / (len(r.tokens) - 1)
             for r in done if len(r.tokens) > 1]
    return {
        "finished": len(done),
        "preempted": engine.stats().get("preempted", 0) - preempted_before,
        "total_tokens": total_tokens,
        "makespan_s": makespan,
        "sustained_tok_s": total_tokens / makespan if makespan > 0 else 0.0,
        "p50_ttft_s": percentile(ttfts, 50.0),
        "p99_ttft_s": percentile(ttfts, 99.0),
        "p50_tpot_s": percentile(tpots, 50.0),
        "p99_tpot_s": percentile(tpots, 99.0),
        "mean_latency_s": sum(latencies) / len(latencies) if latencies
        else 0.0,
    }


def _best_replay(engine, reqs, arrivals) -> dict:
    """Best of ``REPEATS`` replays by sustained tok/s.  Rid bracketing in
    ``_replay`` keeps each repeat's metrics independent, and the engine
    drains fully between repeats (all pages freed), so repeats start from
    identical state with warm jit caches."""
    rows = [_replay(engine, reqs, arrivals) for _ in range(REPEATS)]
    return max(rows, key=lambda r: r["sustained_tok_s"])


def _warm(engine) -> None:
    """Trace every jitted program before timing.  The engines jit their
    step functions per instance, so warm-up must run on the instance the
    replay uses; two mixed-length prompts exercise prefill (multi-chunk
    and single-chunk lanes), decode, sampling and the lm head."""
    long_prompt = list(range(2, 2 + LONG_PROMPT[0]))
    engine.generate([[2, 3, 4, 5], long_prompt], max_new=3)


def build_engines(params):
    grid = _grid()
    n_pages = FIFO_SLOTS * grid // PAGE_SIZE
    fifo = Engine(CFG, params, ServeConfig(
        n_slots=FIFO_SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
        max_new=MAX_LEN,
    ))
    cont = ContinuousEngine(CFG, params, ServeConfig(
        n_slots=CONT_LANES, max_len=MAX_LEN, prefill_chunk=CHUNK,
        max_new=MAX_LEN, page_size=PAGE_SIZE, n_pages=n_pages,
        watermark_pages=WATERMARK,
    ))
    return fifo, cont


def run(out_path: str = "BENCH_traffic.json") -> dict:
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    reqs, arrivals = _workload(np.random.default_rng(SEED))
    fifo, cont = build_engines(params)
    _warm(fifo)
    _warm(cont)
    fifo_row = _best_replay(fifo, reqs, arrivals)
    cont_row = _best_replay(cont, reqs, arrivals)

    ratios = {
        "continuous_vs_fifo_tok_s": (
            cont_row["sustained_tok_s"] / fifo_row["sustained_tok_s"]
            if fifo_row["sustained_tok_s"] else 0.0
        ),
        # >1 means FIFO's tail TTFT is worse (continuous wins the tail)
        "fifo_vs_continuous_ttft_p99": (
            fifo_row["p99_ttft_s"] / cont_row["p99_ttft_s"]
            if cont_row["p99_ttft_s"] else 0.0
        ),
    }
    result = {
        "config": {
            "model": CFG.name, "backend": jax.default_backend(),
            "max_len": MAX_LEN, "chunk": CHUNK, "page_size": PAGE_SIZE,
            "fifo_slots": FIFO_SLOTS, "cont_lanes": CONT_LANES,
            "n_pages": FIFO_SLOTS * _grid() // PAGE_SIZE,
            "watermark_pages": WATERMARK,
            "n_requests": N_REQUESTS, "rate_hz": RATE_HZ, "seed": SEED,
            "repeats": REPEATS,
            # lists, not tuples, so the dict equals its JSON round-trip
            "short": {"prompt": list(SHORT_PROMPT),
                      "max_new": list(SHORT_MAX_NEW)},
            "long": {"prompt": list(LONG_PROMPT),
                     "max_new": list(LONG_MAX_NEW)},
            "xl": {"prompt": list(XL_PROMPT), "max_new": list(XL_MAX_NEW)},
        },
        "fifo": fifo_row,
        "continuous": cont_row,
        "ratios": ratios,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    for name, row in (("fifo", fifo_row), ("continuous", cont_row)):
        emit(
            f"traffic_{name}",
            1e6 / row["sustained_tok_s"] if row["sustained_tok_s"] else 0.0,
            f"{row['sustained_tok_s']:.1f} tok/s sustained, "
            f"ttft p50 {row['p50_ttft_s'] * 1e3:.0f}ms "
            f"p99 {row['p99_ttft_s'] * 1e3:.0f}ms, "
            f"{row['finished']} finished, {row['preempted']} preempted",
        )
    emit("traffic_continuous_vs_fifo",
         ratios["continuous_vs_fifo_tok_s"],
         f"{ratios['continuous_vs_fifo_tok_s']:.2f}x sustained tok/s, "
         f"{ratios['fifo_vs_continuous_ttft_p99']:.2f}x p99-TTFT win")
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
