"""Timing + CSV helpers for the benchmark harness."""

from __future__ import annotations

import time


def time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
