"""Timing + CSV helpers for the benchmark harness."""

from __future__ import annotations

import time


def _block(result) -> None:
    """Block on device results so async dispatch can't fake a win.

    ``jax.block_until_ready`` walks arbitrary pytrees and passes through
    non-JAX values (numpy arrays, floats), so wall-clock rows measure the
    computation, not the dispatch.  Guarded import keeps the pure-numpy
    paper tables importable without JAX initialized.
    """
    try:
        import jax

        jax.block_until_ready(result)
    except ImportError:  # pragma: no cover - jax is a hard dep in practice
        pass


def time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        _block(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args)
    _block(result)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
