"""Paper Table I: error statistics of every packing approach (exhaustive,
all 65 536 4-bit input combinations), plus the wide-multiply sim timing."""

from __future__ import annotations

from repro.core.correction import scheme_stats
from repro.core.packing import int4_packing

from .bench_util import emit, time_us


def run() -> None:
    rows = [
        ("xilinx_int4_naive", int4_packing(), "naive"),
        ("int4_full_correction", int4_packing(), "full"),
        ("int4_approx_correction", int4_packing(), "approx"),
        ("overpacking_d-1", int4_packing(-1), "naive"),
        ("overpacking_d-2", int4_packing(-2), "naive"),
        ("overpacking_d-3", int4_packing(-3), "naive"),
        ("mr_overpacking_d-1", int4_packing(-1), "mr"),
        ("mr_overpacking_d-2", int4_packing(-2), "mr"),
        ("mr_overpacking_d-3", int4_packing(-3), "mr"),
        ("BEYOND_mr+full_d-1", int4_packing(-1), "mr+full"),
        ("BEYOND_mr+full_d-2", int4_packing(-2), "mr+full"),
    ]
    for name, cfg, scheme in rows:
        us = time_us(lambda c=cfg, s=scheme: scheme_stats(c, s), iters=1, warmup=0)
        st = scheme_stats(cfg, scheme)
        emit(
            f"table1/{name}", us,
            f"MAE={st.mae_bar:.2f} EP={st.ep_bar:.2f}% WCE={st.wce_bar}",
        )
