#!/usr/bin/env python
"""Doc lint: module docstrings + architecture-doc cross-references.

The tree's docstrings cite the architecture documents by section —
``DESIGN.md §4``, or ``EXPERIMENTS.md §Perf cell A`` — and the documents
cite source files back.  Those references rot silently: ``runtime/
sharding.py`` shipped citing a DESIGN.md that did not exist for nine
PRs.  This lint makes both directions fail CI instead:

1. every Python module under ``src/repro/`` has a module docstring;
2. every ``DESIGN`` / ``EXPERIMENTS`` section citation in the tree
   (``src``, ``tests``, ``benchmarks``, ``examples``, ``tools`` and the
   top-level ``*.md``) resolves to a real ``§``-anchored heading, and a
   qualifier riding the citation (``cell A``, ``cells A/C``,
   ``iteration 7``) appears verbatim in that section's body;
3. every repo-relative file path named in DESIGN.md / EXPERIMENTS.md /
   README.md (backticked or in a layout block) exists — module-style
   paths like ``runtime/tp_packed.py`` are resolved under ``src/repro/``.

Run from the repo root (CI runs it in the fast-lane static-analysis
job): ``python tools/doc_lint.py``.  Exit 0 = clean, 1 = findings (one
per line, ``path:line: message``).
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

#: documents whose sections may be cited as ``<NAME>.md §<token>``
DOCS = ("DESIGN", "EXPERIMENTS")

#: where citations are collected from
CITING_DIRS = ("src", "tests", "benchmarks", "examples", "tools")

# ``DESIGN.md §4`` / ``EXPERIMENTS §Perf`` (the ``.md`` is optional in
# prose); ``\s+`` tolerates citations wrapped across comment lines.
CITE_RE = re.compile(
    r"\b(%s)(?:\.md)?\s+§([A-Za-z0-9][A-Za-z0-9-]*)" % "|".join(DOCS)
)
# qualifier immediately after a §Perf citation: "cell A", "cells A/C",
# "iteration 7" (optionally comma-separated from the section token)
QUAL_RE = re.compile(r"^[,\s]*\(?(cells?\s+[A-Z](?:/[A-Z])*|iterations?\s+\d+)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
# backticked repo paths in the docs; skip templates (<arch>, BENCH_*)
PATH_RE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|toml|yml))`")


def iter_py_files():
    for d in CITING_DIRS:
        base = ROOT / d
        if base.is_dir():
            yield from sorted(base.rglob("*.py"))


def check_module_docstrings(findings: list[str]) -> None:
    for path in sorted((ROOT / "src" / "repro").rglob("*.py")):
        try:
            tree = ast.parse(path.read_text(encoding="utf-8"))
        except SyntaxError as e:  # pragma: no cover - would fail tests too
            findings.append(f"{path.relative_to(ROOT)}:{e.lineno}: {e.msg}")
            continue
        if ast.get_docstring(tree) is None:
            findings.append(
                f"{path.relative_to(ROOT)}:1: missing module docstring"
            )


def parse_sections(doc: Path) -> dict[str, str]:
    """Map ``§``-anchored heading token -> section body text."""
    text = doc.read_text(encoding="utf-8")
    sections: dict[str, str] = {}
    matches = list(HEADING_RE.finditer(text))
    for i, m in enumerate(matches):
        for tok in re.findall(r"§([A-Za-z0-9][A-Za-z0-9-]*)", m.group(1)):
            end = matches[i + 1].start() if i + 1 < len(matches) else len(text)
            sections[tok] = text[m.start():end]
    return sections


def check_citations(findings: list[str]) -> None:
    sections = {}
    for name in DOCS:
        doc = ROOT / f"{name}.md"
        sections[name] = parse_sections(doc) if doc.exists() else None

    # ISSUE.md / CHANGES.md are driver/log files that quote section
    # syntax as placeholders; they are not citation sources
    files = list(iter_py_files()) + sorted(
        p for p in ROOT.glob("*.md") if p.name not in ("ISSUE.md", "CHANGES.md")
    )
    for path in files:
        text = path.read_text(encoding="utf-8")
        # normalize comment/docstring wrapping so qualifiers split across
        # lines ("...§Perf\n# iteration 1") still attach to the citation
        flat = re.sub(r"\s*\n\s*#?\s*", " ", text)
        for m in CITE_RE.finditer(flat):
            doc, tok = m.group(1), m.group(2)
            line = text[: text.find(m.group(0).split()[0])].count("\n") + 1
            rel = path.relative_to(ROOT)
            if sections[doc] is None:
                findings.append(f"{rel}:{line}: cites missing {doc}.md")
                continue
            body = sections[doc].get(tok)
            if body is None:
                findings.append(
                    f"{rel}:{line}: {doc}.md has no section anchored §{tok}"
                )
                continue
            q = QUAL_RE.match(flat[m.end():m.end() + 40])
            if q:
                qual = re.sub(r"\s+", " ", q.group(1))
                # "cells A/C" / "iterations 1-2" expand to each member
                plural, _, spec = qual.partition(" ")
                singular = plural.rstrip("s")
                for part in re.split(r"[/,]| and ", spec):
                    want = f"{singular} {part.strip()}"
                    if part.strip() and want not in body:
                        findings.append(
                            f"{rel}:{line}: {doc}.md §{tok} does not "
                            f"mention {want!r}"
                        )


def check_doc_paths(findings: list[str]) -> None:
    for name in ("DESIGN.md", "EXPERIMENTS.md", "README.md"):
        doc = ROOT / name
        if not doc.exists():
            findings.append(f"{name}:1: document missing")
            continue
        text = doc.read_text(encoding="utf-8")
        for m in PATH_RE.finditer(text):
            rel = m.group(1)
            if any(c in rel for c in "<>*{"):
                continue
            candidates = (ROOT / rel, ROOT / "src" / rel,
                          ROOT / "src" / "repro" / rel)
            if not any(c.exists() for c in candidates):
                line = text[: m.start()].count("\n") + 1
                findings.append(f"{name}:{line}: dangling path {rel!r}")


def run() -> list[str]:
    findings: list[str] = []
    check_module_docstrings(findings)
    check_citations(findings)
    check_doc_paths(findings)
    return findings


def main() -> int:
    findings = run()
    for f in findings:
        print(f)
    print(f"doc_lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
