"""End-to-end driver: train a ~100M-param LM with int4 QAT (the paper's
low-precision arithmetic as a first-class training feature).

Quick smoke (couple of minutes on CPU):
  PYTHONPATH=src python examples/train_lm.py --steps 30

The full deliverable run (a few hundred steps):
  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.packed_linear import LinearSpec
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.schedule import cosine_with_warmup

# ~100M params: 16 x (4*640^2 + 3*640*2560) + 2 * 8192*640 embeddings
CFG_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=16, d_model=640, n_heads=10,
    n_kv_heads=5, d_ff=2560, vocab_size=8192, dtype="float32",
    quant=LinearSpec(mode="qat4"), remat="none",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--no-qat", action="store_true")
    args = ap.parse_args()

    cfg = CFG_100M
    if args.no_qat:
        cfg = dataclasses.replace(cfg, quant=LinearSpec(mode="native"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, quant={cfg.quant.mode}")

    state = {"params": params, "opt": adamw_init(params)}
    sched = cosine_with_warmup(args.lr, warmup=20, total=args.steps)
    step_fn = jax.jit(
        make_train_step(cfg, AdamWConfig(lr=args.lr), lr_schedule=sched),
        donate_argnums=(0,),
    )
    data = SyntheticStream(
        DataConfig(cfg.vocab_size, args.seq + 1, args.batch, seed=0)
    ).start()

    t0 = time.time()
    first = None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            first = loss if first is None else first
            print(
                f"[train_lm] step {step:4d} loss={loss:.4f} "
                f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True,
            )
    data.stop()
    print(f"[train_lm] loss {first:.3f} -> {float(metrics['loss']):.3f}")


if __name__ == "__main__":
    main()
