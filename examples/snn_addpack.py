"""Spiking-neural-network accumulation with addition packing (paper §VII).

An SNN layer integrates weighted spikes: ``v[t+1] = v[t] + W @ s[t]``.
With binary spikes the MAC degenerates to masked adds — the paper packs
several narrow accumulators into one 48-bit adder.  This demo packs four
10-bit membrane accumulators per adder (2 guard bits -> exact) and checks
a leaky integrate-and-fire layer end to end.

Run:  PYTHONPATH=src python examples/snn_addpack.py
"""

import numpy as np

from repro.core.addpack import AddPackConfig, accumulate

rng = np.random.default_rng(0)

N_IN, N_OUT, T_STEPS = 64, 16, 32
THRESHOLD = 64

w = rng.integers(-8, 8, (N_IN, N_OUT))        # int4 weights
spikes = (rng.random((T_STEPS, N_IN)) < 0.15)  # Poisson-ish input spikes

# per-timestep weighted spike sums (these are the narrow addends)
drive = spikes.astype(np.int64) @ w           # (T, N_OUT), small ints

cfg = AddPackConfig((10, 10, 10, 10), guard_bits=2)
assert cfg.bits_used() <= 48

# pack N_OUT accumulators into groups of 4 lanes
groups = drive.reshape(T_STEPS, N_OUT // 4, 4).transpose(1, 0, 2)
packed_v = np.stack([accumulate(cfg, g) for g in groups])  # (groups, 4)
v_packed = packed_v.reshape(N_OUT)
v_exact = drive.sum(0)

print(f"[snn] membrane potentials (packed)  : {v_packed[:8]} ...")
print(f"[snn] membrane potentials (exact)   : {v_exact[:8]} ...")
assert (v_packed == v_exact).all(), "guard bits must make packing exact"
print(f"[snn] exact with {cfg.guard_bits} guard bits; "
      f"{cfg.n_lanes} accumulators per 48-bit adder "
      f"(density {cfg.packing_density():.2f})")

fired = v_packed > THRESHOLD
print(f"[snn] neurons fired: {fired.sum()}/{N_OUT}")

# without guard bits: approximate integration (bounded per-step LSB error)
loose = AddPackConfig((12, 12, 12, 12), guard_bits=0)
v_loose = np.stack(
    [accumulate(loose, g, headroom_bits=0) for g in groups]
).reshape(N_OUT)
err = np.abs(v_loose - v_exact)
print(f"[snn] no-guard variant: max |error| = {err.max()} "
      f"(paper §VII: carry corrupts only the victim LSB)")
