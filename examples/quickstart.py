"""Quickstart: the paper's DSP-packing in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.correction import scheme_stats, simulate
from repro.core.packing import int4_packing, intn_packing, outer_product_exact
from repro.core.addpack import AddPackConfig, packed_lane_add, lane_add_expected

print("=" * 70)
print("1. Pack four 4-bit multiplications into ONE wide multiply (paper §III)")
cfg = int4_packing()
a = np.array([[3, 10]])     # unsigned activations
w = np.array([[-7, 5]])     # signed weights
print(f"   a={a[0]}, w={w[0]}")
print(f"   exact outer product   : {outer_product_exact(cfg, a, w)[0]}")
print(f"   naive (Xilinx) extract: {simulate(cfg, a, w, 'naive')[0]}  <- biased!")
print(f"   full correction       : {simulate(cfg, a, w, 'full')[0]}")
print(f"   approx correction     : {simulate(cfg, a, w, 'approx')[0]}")

print()
print("2. Exhaustive error statistics (paper Table I)")
for scheme in ("naive", "full", "approx"):
    print(f"   {scheme:8s}: {scheme_stats(cfg, scheme).row()}")

print()
print("3. Overpacking: six 4-bit multiplies per DSP at bounded error (§VI)")
six = intn_packing((4, 4, 4), (5, 5), delta=-2)
print(f"   density rho={six.packing_density():.3f} (INT4 baseline: 0.667)")
over = int4_packing(delta=-2)
print(f"   naive overpacking : {scheme_stats(over, 'naive').row()}")
print(f"   MR-overpacking    : {scheme_stats(over, 'mr').row()}")
print(f"   MR+round (ours)   : {scheme_stats(over, 'mr+full').row()}")

print()
print("4. Addition packing (paper §VII): five 9-bit adders in one 48-bit add")
apc = AddPackConfig((9, 9, 9, 9, 9), guard_bits=0)
x = np.array([[100, -200, 5, 17, -9]])
y = np.array([[-50, 130, 25, -4, 77]])
print(f"   packed result: {packed_lane_add(apc, x, y)[0]}")
print(f"   expected     : {lane_add_expected(apc, x, y)[0]}")

print()
print("5. The TPU adaptation: pair-packed int32 matmul (kernels/, DESIGN.md §2)")
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ref import INT4_EXACT

rng = np.random.default_rng(0)
x_q = jnp.asarray(rng.integers(0, 16, (8, 32)).astype(np.int8))
w_q = jnp.asarray(rng.integers(-8, 8, (32, 8)).astype(np.int8))
packed = ref.ref_packed_matmul(x_q, w_q, INT4_EXACT)
exact = ref.ref_quantized_matmul(x_q, w_q)
print(f"   packed matmul == exact int matmul: {bool((packed == exact).all())}")
print("   (one int32 VPU multiply computes TWO int4 products)")
