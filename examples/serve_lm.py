"""Serve a small LM with batched requests and packed int4 weights — the
decode path is weight-bandwidth-bound, exactly where DSP-packing's density
pays off (DESIGN.md §2).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.core.packed_linear import LinearSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.engine import Engine, ServeConfig

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=1024, vocab_size=4096, dtype="float32",
)


def run(quant: str) -> float:
    cfg = dataclasses.replace(CFG, quant=LinearSpec(mode=quant))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, ServeConfig(n_slots=4, max_len=64))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(2, 4096, size=6)) for _ in range(6)]
    t0 = time.time()
    outs = eng.generate(prompts, max_new=12)
    dt = time.time() - t0
    toks = sum(len(v) for v in outs.values())
    print(f"[serve_lm] quant={quant:12s} {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    return dt


if __name__ == "__main__":
    run("native")
    run("int8")
    run("int4_packed")   # packed nibble storage -> half the weight bytes
    run("dsp_packed")    # paper-faithful pair-packed arithmetic
