"""Serve a small LM with batched requests and packed int4 weights — the
decode path is weight-bandwidth-bound, exactly where DSP-packing's density
pays off (DESIGN.md §2).

Demonstrates the serving stack end to end: chunked batched prefill, the
request scheduler, per-request sampling, and the packed-weight decode path
(`quant_mode="int4_packed"` packs weights once at engine build and decodes
through the packed matmul kernel).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import Engine, SamplingParams, ServeConfig

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=4,
    n_kv_heads=2, d_ff=1024, vocab_size=4096, dtype="float32",
)


def run(quant_mode: str, sampling: SamplingParams | None = None) -> float:
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = Engine(CFG, params, ServeConfig(
        n_slots=4, max_len=64, prefill_chunk=8, quant_mode=quant_mode,
    ))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(2, 4096, size=6)) for _ in range(6)]
    t0 = time.time()
    outs = eng.generate(prompts, max_new=12, sampling=sampling)
    dt = time.time() - t0
    stats = eng.stats()
    toks = sum(len(v) for v in outs.values())
    mode = "greedy" if sampling is None else "sampled"
    print(f"[serve_lm] quant={quant_mode:12s} {mode:7s} {toks} tokens in "
          f"{dt:.1f}s (prefill {stats['prefill_tok_s']:.1f} tok/s, "
          f"decode {stats['decode_tok_s']:.1f} tok/s)")
    return dt


if __name__ == "__main__":
    run("native")
    run("native", SamplingParams(temperature=0.8, top_k=40, top_p=0.95))
    run("int8")
    run("int4_packed")   # nibbles packed once; decode runs the packed kernel
    run("dsp_packed")    # paper-faithful pair-packed arithmetic
    run("dsp_tuned")     # per-layer autotuned packing plans (repro.tuning)
