"""dbrx-132b — [moe] 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4 fine-grained

Source: hf:databricks/dbrx-base (unverified tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='dbrx-132b',
    family='moe',
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    experts_per_token=4,
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name='dbrx-132b-smoke',
    family='moe',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    experts_per_token=2,
)
