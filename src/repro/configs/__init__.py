"""Assigned architecture configs (``--arch <id>``).

Each module defines ``FULL`` (the exact published config) and ``SMOKE`` (a
reduced same-family config for CPU tests).  ``repro.models.registry``
collects them.
"""

from . import (
    dbrx_132b,
    h2o_danube_3_4b,
    internlm2_20b,
    jamba_v01_52b,
    llava_next_mistral_7b,
    moonshot_v1_16b_a3b,
    qwen15_110b,
    starcoder2_7b,
    whisper_large_v3,
    xlstm_1_3b,
)

ALL = {
    m.FULL.name: m
    for m in (
        qwen15_110b,
        starcoder2_7b,
        internlm2_20b,
        h2o_danube_3_4b,
        dbrx_132b,
        moonshot_v1_16b_a3b,
        xlstm_1_3b,
        jamba_v01_52b,
        whisper_large_v3,
        llava_next_mistral_7b,
    )
}
