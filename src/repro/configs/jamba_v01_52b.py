"""jamba-v0.1-52b — [hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, Mamba+attn 1:7, MoE 16e top-2

Source: arXiv:2403.19887 (hf tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='jamba-v0.1-52b',
    family='hybrid',
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    attn_every=8,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)

SMOKE = ModelConfig(
    name='jamba-v0.1-52b-smoke',
    family='hybrid',
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    experts_per_token=2,
    attn_every=8,
)
