"""whisper-large-v3 — [audio] enc-dec 32L d_model=1280 20H d_ff=5120 vocab=51866, conv frontend stubbed

Source: arXiv:2212.04356 (unverified tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='whisper-large-v3',
    family='encdec',
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    n_encoder_layers=32,
    encoder_len=1500,
    mlp_variant='gelu',
)

SMOKE = ModelConfig(
    name='whisper-large-v3-smoke',
    family='encdec',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    n_encoder_layers=2,
    encoder_len=16,
    mlp_variant='gelu',
)
