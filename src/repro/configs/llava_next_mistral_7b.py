"""llava-next-mistral-7b — [vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, anyres patch stub

Source: hf:llava-hf/llava-v1.6-mistral-7b-hf (unverified tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='llava-next-mistral-7b',
    family='vlm',
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_patches=2880,
    rope_theta=1000000.0,
    sliding_window=None,
)

SMOKE = ModelConfig(
    name='llava-next-mistral-7b-smoke',
    family='vlm',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_patches=8,
)
