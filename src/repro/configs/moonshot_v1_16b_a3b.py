"""moonshot-v1-16b-a3b — [moe] 48L d_model=2048 16H (kv=16) d_ff=1408 vocab=163840, MoE 64e top-6 (kimi/moonlight)

Source: hf:moonshotai/Moonlight-16B-A3B (hf tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='moonshot-v1-16b-a3b',
    family='moe',
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    experts_per_token=6,
)

SMOKE = ModelConfig(
    name='moonshot-v1-16b-a3b-smoke',
    family='moe',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
)
