"""starcoder2-7b — [dense] 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152, GQA+RoPE

Source: arXiv:2402.19173 (hf tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='starcoder2-7b',
    family='dense',
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_variant='gelu',
    rope_theta=1000000.0,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name='starcoder2-7b-smoke',
    family='dense',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    mlp_variant='gelu',
)
