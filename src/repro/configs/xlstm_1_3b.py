"""xlstm-1.3b — [ssm] 48L d_model=2048 4H d_ff=0 vocab=50304, sLSTM + mLSTM blocks (1:7)

Source: arXiv:2405.04517 (unverified tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='xlstm-1.3b',
    family='ssm',
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name='xlstm-1.3b-smoke',
    family='ssm',
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    slstm_every=4,
    tie_embeddings=True,
)
