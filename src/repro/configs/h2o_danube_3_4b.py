"""h2o-danube-3-4b — [dense] 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, llama+mistral mix, SWA

Source: arXiv:2401.16818 (unverified tier)
"""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name='h2o-danube-3-4b',
    family='dense',
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
)

SMOKE = ModelConfig(
    name='h2o-danube-3-4b-smoke',
    family='dense',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    sliding_window=32,
)
