"""AdamW in pure JAX (pytree-native, sharding-transparent).

Optimizer state mirrors the parameter tree leaf-for-leaf, so whatever
NamedSharding the params carry propagates to ``m``/``v`` automatically under
jit — FSDP sharding of optimizer state costs nothing extra here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(
    params,
    grads,
    state,
    cfg: AdamWConfig,
    lr_schedule: Callable[[jax.Array], jax.Array] | None = None,
):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.zeros(())
    step = state["step"] + 1
    lr = cfg.lr if lr_schedule is None else lr_schedule(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)},
    )
