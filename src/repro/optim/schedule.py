"""LR schedules (cosine with linear warmup, constant, rsqrt)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_with_warmup", "constant", "rsqrt"]


def cosine_with_warmup(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def rsqrt(peak_lr: float, warmup: int):
    def sched(step):
        step = step.astype(jnp.float32)
        return peak_lr * jnp.minimum(step / max(warmup, 1), jnp.sqrt(warmup / jnp.maximum(step, 1.0)))

    return sched
