"""Packing-plan enumeration (paper §IV/§VI generalized to a search space).

The paper's contribution is that DSP packing is a *family* of layouts —
any operand widths, any number of multiplications, any δ-spacing including
negative-δ Overpacking — not the two Xilinx app-note configs.  This module
materializes that family for both compute models in the repo:

* :func:`enumerate_specs` — every legal :class:`PackedDotSpec` for the
  pair-packed int32 Pallas path, for a requested ``(a_bits, w_bits)``.
  For exact-spacing schemes (``naive``/``full``) the minimal legal spacing
  is emitted per accumulation count (wider spacing only wastes bits: the
  error profile is independent of ``p`` once the middle field fits).  For
  the mr schemes every overpacked spacing down to ``max_mr_bits`` below the
  exact minimum is emitted — each trades error for packing density.  The
  multi-DSP *column* axis (``n_columns``) is searched on top: spreading one
  dot product across several packed words lifts the per-word int32 budget,
  so 8-bit operands — which admit NO single-word plan — get provably exact
  plans, at a cost the scorer charges per extra word.

* :func:`enumerate_packing_configs` — every legal :class:`PackingConfig`
  under the DSP48E2 port budgets (the hardware-truth simulation), over a
  δ range that includes Overpacking.  Negative δ is clamped so fields only
  ever overlap their immediate neighbour (``spacing >= ceil(width/2)``) —
  the regime the paper's MR restore (Eqns. 8/9) is defined for.
"""

from __future__ import annotations

import dataclasses

from ..core.packing import PackingConfig, intn_packing
from ..kernels.ref import CORRECTIONS, PackedDotSpec

__all__ = [
    "min_exact_p",
    "enumerate_specs",
    "certified_plans",
    "enumerate_packing_configs",
    "spec_to_json",
    "spec_from_json",
    "DEFAULT_N_PAIRS",
    "DEFAULT_MAX_MR_BITS",
    "DEFAULT_N_COLUMNS",
]


def spec_to_json(spec: PackedDotSpec) -> dict:
    """Loss-free JSON form of a spec (plan-database persistence).

    Field-for-field ``asdict``: round-tripping through
    :func:`spec_from_json` re-runs the constructor's legality checks, so a
    stored plan that predates a tightened invariant fails loudly at load
    instead of serving an illegal layout."""
    return dataclasses.asdict(spec)


def spec_from_json(d: dict) -> PackedDotSpec:
    """Inverse of :func:`spec_to_json` (revalidates via ``__post_init__``)."""
    fields = {f.name for f in dataclasses.fields(PackedDotSpec)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(
            f"unknown PackedDotSpec fields {sorted(unknown)} — stale "
            "plan-database entry from a different schema; invalidate it"
        )
    return PackedDotSpec(**d)

DEFAULT_N_PAIRS = (1, 2, 4, 8, 16, 32)
DEFAULT_MAX_MR_BITS = 4
# Multi-DSP column counts searched per plan (the wide-datapath related
# work's missing axis): 1 = classic single-word packing; >1 spreads one dot
# product across several packed int32 words, lifting the per-word budget.
DEFAULT_N_COLUMNS = (1, 2, 4)


def min_exact_p(a_bits: int, w_bits: int, n_pairs: int,
                n_columns: int = 1) -> int:
    """Smallest spacing whose accumulated middle field never overflows.

    The middle field holds ``Σ (a_even·w_even + a_odd·w_odd)`` over
    ``n_pairs`` packed words; its magnitude is bounded by
    ``n_pairs · 2 · a_max · |w_min|`` and the signed field needs one more
    bit than that magnitude.  With column packing each word only carries a
    ``ceil(a_bits / n_columns)``-bit activation slice, so ``a_max`` (and
    hence the minimal spacing) shrinks per column."""
    col_bits_a = -(-a_bits // n_columns)
    max_a = (1 << col_bits_a) - 1
    max_w = 1 << (w_bits - 1)
    return (n_pairs * 2 * max_a * max_w).bit_length() + 1


def enumerate_specs(
    a_bits: int,
    w_bits: int,
    corrections: tuple[str, ...] = CORRECTIONS,
    n_pairs_choices: tuple[int, ...] = DEFAULT_N_PAIRS,
    max_mr_bits: int = DEFAULT_MAX_MR_BITS,
    min_p: int = 2,
    n_columns_choices: tuple[int, ...] = DEFAULT_N_COLUMNS,
) -> tuple[PackedDotSpec, ...]:
    """Every legal pair-packed plan for ``(a_bits, w_bits)``.

    Legality is delegated to ``PackedDotSpec.__post_init__`` (the int32
    accumulator and field budgets, applied per column), so "the enumerator
    emits it" and "the kernel accepts it" are the same predicate by
    construction.  Column counts beyond the operand width, or yielding the
    same slice width as a smaller count, are skipped (identical plans).
    The result may still be empty for exotic width/choice combinations —
    callers are expected to handle that — but the column axis means every
    width pair up to a8w8 now has at least one provably exact plan.
    """
    specs: list[PackedDotSpec] = []
    seen_slice_widths: set[int] = set()
    for n_requested in n_columns_choices:
        if n_requested > a_bits:
            continue
        col_bits_a = -(-a_bits // n_requested)
        if col_bits_a in seen_slice_widths:
            continue  # same slice width: same plan, regardless of count
        seen_slice_widths.add(col_bits_a)
        # canonical count for this slice width — e.g. requesting 4 columns
        # of a 6-bit activation means 2-bit slices, which only need THREE
        # columns (the spec constructor rejects trailing-empty columns)
        n_columns = -(-a_bits // col_bits_a)
        for n_pairs in n_pairs_choices:
            p_exact = min_exact_p(a_bits, w_bits, n_pairs, n_columns)
            for correction in corrections:
                if correction in ("naive", "full"):
                    try:
                        specs.append(
                            PackedDotSpec(a_bits, w_bits, p_exact, n_pairs,
                                          correction, n_columns=n_columns)
                        )
                    except ValueError:
                        pass  # exceeds the int32 budget at this n_pairs
                else:  # mr / mr+full: squeeze spacing below the exact minimum
                    for mr_bits in range(1, max_mr_bits + 1):
                        p = p_exact - mr_bits
                        if p < min_p:
                            continue
                        try:
                            specs.append(
                                PackedDotSpec(
                                    a_bits, w_bits, p, n_pairs, correction,
                                    mr_bits, n_columns=n_columns,
                                )
                            )
                        except ValueError:
                            pass
    return tuple(specs)


def certified_plans(
    a_bits: int,
    w_bits: int,
    **enumerate_kwargs,
) -> tuple[tuple[PackedDotSpec, "object"], ...]:
    """Enumerated specs stamped with their static certificates.

    Every plan the enumerator emits is paired with the
    :class:`~repro.analysis.verify.PlanCertificate` proving its legality
    and error bound (the verifier memoizes, so stamping is cheap).  The
    enumerator and constructor guarantee legality by construction; the
    certificate additionally carries the exact/bounded verdict, the tight
    per-extraction WCE with its witness, and the analytic MAE — consumers
    (the tuner's budget filter, benchmarks, the serving planner) read
    those instead of re-measuring."""
    from ..analysis.verify import certify_spec

    specs = enumerate_specs(a_bits, w_bits, **enumerate_kwargs)
    return tuple((spec, certify_spec(spec)) for spec in specs)


def enumerate_packing_configs(
    a_bits: int,
    w_bits: int,
    n_a_choices: tuple[int, ...] = (1, 2, 3),
    n_w_choices: tuple[int, ...] = (1, 2),
    deltas: tuple[int, ...] | range = range(-3, 5),
) -> tuple[PackingConfig, ...]:
    """Every legal DSP48E2 packing config for uniform ``(a_bits, w_bits)``.

    Filters by :meth:`PackingConfig.fits_dsp48` (the 17/26/47-bit port
    budgets) and restricts Overpacking to single-neighbour overlap —
    ``spacing >= ceil(result_width / 2)`` — which is the regime the MR
    restore handles (each field is only contaminated by the field directly
    above it).
    """
    width = a_bits + w_bits
    configs: list[PackingConfig] = []
    for n_a in n_a_choices:
        for n_w in n_w_choices:
            if n_a * n_w < 2:
                continue  # a single product is not a packing
            for delta in deltas:
                spacing = width + delta
                if delta < 0 and 2 * spacing < width:
                    continue  # would overlap beyond the adjacent field
                try:
                    cfg = intn_packing((a_bits,) * n_a, (w_bits,) * n_w, delta)
                except ValueError:
                    continue
                if cfg.fits_dsp48():
                    configs.append(cfg)
    return tuple(configs)
