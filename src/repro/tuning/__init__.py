"""Packing-plan subsystem: enumerate → score → autotune → select.

The paper generalizes DSP packing to arbitrary widths, multiplication
counts and δ-spacings (§IV, §VI); this package turns that generality into
a searchable plan space for the Pallas compute path and picks, per layer,
the fastest plan whose error fits a user budget.  See ``plans`` (the
enumerators), ``score`` (error metrics), ``autotune`` (block-size sweep),
``tuner`` (budgeted selection, per-layer tables) and ``mixed``
(sensitivity-driven per-layer width allocation — the ``dsp_mixed``
serving mode).
"""

from .autotune import (
    DECODE_BLOCKS,
    DEFAULT_BLOCKS,
    PHASE_BLOCKS,
    BlockTiming,
    autotune_block,
    autotune_phase_blocks,
    candidate_blocks,
    default_timer,
)
from .plans import (
    DEFAULT_MAX_MR_BITS,
    DEFAULT_N_COLUMNS,
    DEFAULT_N_PAIRS,
    enumerate_packing_configs,
    enumerate_specs,
    min_exact_p,
    spec_from_json,
    spec_to_json,
)
from .mixed import (
    DEFAULT_MIXED_BUDGET,
    DEFAULT_WIDTH_CANDIDATES,
    PROBES,
    LayerSensitivity,
    MixedAllocation,
    allocate_mixed_plans,
    measure_layer_sensitivity,
    mixed_precision_plan,
    suggest_budget,
)
from .plandb import (
    SCHEMA_VERSION,
    PlanDB,
    allocation_from_json,
    allocation_to_json,
    plan_key,
    report_from_json,
    report_to_json,
)
from .score import SpecScore, config_error_stats, plan_cost_proxy, spec_error_stats
from .tuner import (
    DEFAULT_ERROR_BUDGET,
    PlanReport,
    plan_linear_layers,
    rank_plans,
    select_plan,
)

__all__ = [
    "BlockTiming",
    "autotune_block",
    "autotune_phase_blocks",
    "candidate_blocks",
    "default_timer",
    "DECODE_BLOCKS",
    "DEFAULT_BLOCKS",
    "PHASE_BLOCKS",
    "DEFAULT_MAX_MR_BITS",
    "DEFAULT_N_COLUMNS",
    "DEFAULT_N_PAIRS",
    "enumerate_packing_configs",
    "enumerate_specs",
    "min_exact_p",
    "SpecScore",
    "config_error_stats",
    "plan_cost_proxy",
    "spec_error_stats",
    "DEFAULT_ERROR_BUDGET",
    "DEFAULT_MIXED_BUDGET",
    "DEFAULT_WIDTH_CANDIDATES",
    "PROBES",
    "SCHEMA_VERSION",
    "PlanDB",
    "plan_key",
    "allocation_to_json",
    "allocation_from_json",
    "report_to_json",
    "report_from_json",
    "spec_to_json",
    "spec_from_json",
    "LayerSensitivity",
    "MixedAllocation",
    "allocate_mixed_plans",
    "measure_layer_sensitivity",
    "mixed_precision_plan",
    "suggest_budget",
    "PlanReport",
    "plan_linear_layers",
    "rank_plans",
    "select_plan",
]
