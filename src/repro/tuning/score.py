"""Error scoring for packing plans (paper §VIII metrics over plan space).

Two scorers, one per compute model:

* :func:`spec_error_stats` — matmul-level error of a pair-packed
  :class:`PackedDotSpec`: run the bit-accurate ``ref_packed_matmul`` against
  the mathematically exact integer matmul over an operand grid and reduce
  with ``correction.error_stats`` (Eqns. 10-12).  The grid is exhaustive
  when the per-extraction operand space is small enough (the matmul's
  rows × columns cross product enumerates every (a-tuple, w-tuple)
  combination in one call), sampled otherwise.

* :func:`config_error_stats` — DSP48-level error of a
  :class:`PackingConfig` under a ``core.correction`` scheme, exhaustive
  when the paper's ``N`` is small, sampled otherwise.

MAE grows linearly with the number of extractions for the biased schemes,
so plan comparison uses :attr:`SpecScore.mae_per_extraction` — the same
per-packed-multiply normalization as the paper's tables.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.correction import ErrorStats, error_stats, exhaustive_operands, simulate
from ..core.packing import PackingConfig, outer_product_exact
from ..kernels import ref
from ..kernels.ref import PackedDotSpec

__all__ = [
    "SpecScore",
    "spec_error_stats",
    "spec_operand_grid",
    "config_error_stats",
    "plan_cost_proxy",
]

# Exhaustive matmul probes are capped at this many rows/columns; beyond it
# the operand grid is sampled (the paper's exhaustive tables stop at 4-bit
# pairs for the same reason: 16^4 is tractable, 16^8 is not).
EXHAUSTIVE_LIMIT = 4096


def plan_cost_proxy(spec: PackedDotSpec) -> float:
    """Relative int32 multiply-accumulate work per K element (lower=faster).

    One packed multiply per ``chunk`` K elements — times ``n_columns``,
    because a multi-DSP column plan spends one packed word PER COLUMN per
    pair position (more words ≈ more DSPs on the FPGA, more int32 lanes on
    the VPU).  The mr restore adds half a multiply for its contamination
    dot (its operands are ``mr_bits``-masked, but the MXU does not care),
    again per column.  Fewer extractions per K is the whole throughput
    story of longer accumulation chains, so the proxy ranks exactly like
    wall-clock on every shape we have measured; wall-clock
    (``tuner.rank_plans(autotune=True)``) remains the source of truth for
    the benchmark harness."""
    return spec.n_columns * (1.5 if spec.uses_mr else 1.0) / spec.chunk


@dataclasses.dataclass(frozen=True)
class SpecScore:
    """Error metrics of one plan over a probe matmul."""

    spec: PackedDotSpec
    stats: ErrorStats
    n_extractions: int
    exhaustive: bool
    n_samples: int = 4096  # measured output values behind the stats

    @property
    def certificate(self):
        """The plan's static :class:`~repro.analysis.verify.PlanCertificate`
        (cached at the verifier)."""
        from ..analysis.verify import certify_spec

        return certify_spec(self.spec)

    @property
    def mae(self) -> float:
        return self.stats.mae_bar

    @property
    def mae_per_extraction(self) -> float:
        """MAE per packed multiply — certificate-backed for unproven zeros.

        A sampled grid observing zero error is evidence, not proof: when
        the measurement says zero but the plan is not certified exact, the
        certificate's analytic mean-error derivation (exact distribution
        convolution, see ``analysis.verify``) replaces the observation —
        it is provably positive for every non-exact dot plan, so an
        ``error_budget=0`` selection admits exactly the certified-exact
        plans."""
        observed = self.stats.mae_bar / self.n_extractions
        if observed > 0.0 or self.exhaustive:
            return observed
        cert = self.certificate
        if cert.exact:
            return 0.0
        return float(cert.mae_per_extraction)

    @property
    def ep(self) -> float:
        return self.stats.ep_bar

    @property
    def wce(self) -> int:
        return self.stats.wce_bar


def _all_tuples(n_vals: int, length: int, lo: int) -> np.ndarray:
    """(n_vals**length, length) grid of every value tuple."""
    grids = np.meshgrid(*([np.arange(n_vals) + lo] * length), indexing="ij")
    return np.stack([g.reshape(-1) for g in grids], axis=-1)


def spec_operand_grid(
    spec: PackedDotSpec,
    n_extractions: int,
    samples: int,
    seed: int,
) -> tuple[np.ndarray, np.ndarray, bool]:
    """Probe operands (x (M, K), w (K, N)) for a spec, K = chunk·extractions.

    Exhaustive when one extraction's operand tuples fit ``EXHAUSTIVE_LIMIT``
    on each side (then ``n_extractions`` is forced to 1 and the matmul's
    M×N cross product covers every combination); sampled otherwise."""
    chunk = spec.chunk
    n_a_tuples = (1 << spec.bits_a) ** chunk
    n_w_tuples = (1 << spec.bits_w) ** chunk
    if n_a_tuples <= EXHAUSTIVE_LIMIT and n_w_tuples <= EXHAUSTIVE_LIMIT:
        x = _all_tuples(1 << spec.bits_a, chunk, 0)
        w = _all_tuples(1 << spec.bits_w, chunk, -(1 << (spec.bits_w - 1))).T
        return x.astype(np.int32), w.astype(np.int32), True
    rng = np.random.default_rng(seed)
    k = chunk * n_extractions
    m = n = max(8, int(np.sqrt(samples)))
    x = rng.integers(0, 1 << spec.bits_a, (m, k)).astype(np.int32)
    w = rng.integers(
        -(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, n)
    ).astype(np.int32)
    return x, w, False


def spec_error_stats(
    spec: PackedDotSpec,
    n_extractions: int = 4,
    samples: int = 4096,
    seed: int = 0,
) -> SpecScore:
    """Matmul-level error of ``spec`` vs the exact integer matmul."""
    x, w, exhaustive = spec_operand_grid(spec, n_extractions, samples, seed)
    if exhaustive:
        n_extractions = 1
    got = np.asarray(ref.ref_packed_matmul(x, w, spec))
    want = np.asarray(ref.ref_quantized_matmul(x, w))
    stats = error_stats(want.reshape(-1, 1), got.reshape(-1, 1))
    return SpecScore(spec, stats, n_extractions, exhaustive, got.size)


def _sampled_operands(
    cfg: PackingConfig, samples: int, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    a = np.stack(
        [rng.integers(0, 1 << wd, size=samples) for wd in cfg.a_widths], axis=-1
    ).astype(np.int64)
    w = np.stack(
        [
            rng.integers(-(1 << (wd - 1)), 1 << (wd - 1), size=samples)
            for wd in cfg.w_widths
        ],
        axis=-1,
    ).astype(np.int64)
    return a, w


def config_error_stats(
    cfg: PackingConfig,
    scheme: str,
    samples: int = 8192,
    seed: int = 0,
    exhaustive_limit: int = 1 << 16,
) -> ErrorStats:
    """DSP48-level error of a config under a correction scheme.

    Exhaustive over the paper's full operand space ``N`` when it fits
    ``exhaustive_limit`` (matching Tables I/II), sampled otherwise."""
    n_total = 1
    for wd in cfg.a_widths:
        n_total *= 1 << wd
    for wd in cfg.w_widths:
        n_total *= 1 << wd
    if n_total <= exhaustive_limit:
        a, w = exhaustive_operands(cfg)
    else:
        a, w = _sampled_operands(cfg, samples, seed)
    expected = outer_product_exact(cfg, a, w)
    actual = simulate(cfg, a, w, scheme=scheme)
    return error_stats(expected, actual)
