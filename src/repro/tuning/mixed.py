"""Sensitivity-driven mixed-precision serving plans (per-layer widths).

The generalized packing scheme is parameterized over arbitrary
``(a_bits, w_bits)`` pairs, and the DSP48 cost asymmetry the paper
quantifies — narrower operands pack more multiplications per word — means
width choice buys decode throughput layer by layer.  This module closes
the loop the uniform ``ServeConfig.plan_bits`` knob left open (the
DeepBurning-MixQ framing from PAPERS.md): *measure* how much each layer
can tolerate, then *allocate* widths under a model-level error budget.

Two stages:

* :func:`measure_layer_sensitivity` — per packable weight path (the
  serving "layer": one scan-group role like ``/groups/mlp/up/w``, plus
  ``lm_head``), quantize THAT path alone onto an exact packing plan at
  each candidate width pair and measure the model-level damage on
  calibration activations: mean logit-KL (default) or relative logit MSE
  against the float forward.  This runs the real serving arithmetic
  (``DspTunedLeaf`` + per-path plan), not a fake-quant proxy, so the
  numbers are exactly what serving at that width would produce.

* :func:`allocate_mixed_plans` — greedy budgeted allocation: every layer
  starts at the reference (widest) candidate and the allocator repeatedly
  applies the demotion with the best cost-saved-per-error-added ratio
  that still fits the remaining budget.  Tolerant layers end up on narrow
  widths (more packed multiplications per int32 word — cheaper plans),
  sensitive layers keep wide/exact plans.  Measured error deltas are
  floored at ``noise_floor`` before admission, so ``mixed_budget=0``
  degenerates to the uniform reference-width plan by construction (the
  same sampled-zero skepticism as ``score.SpecScore``).

The result's ``plans`` table is keyed by tree path and routes straight
into ``core.packed_params.quantize_for_serving`` — the engine's
``quant_mode="dsp_mixed"`` is exactly this pipeline at build time.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .score import plan_cost_proxy
from .tuner import PlanReport, select_plan

__all__ = [
    "DEFAULT_WIDTH_CANDIDATES",
    "DEFAULT_MIXED_BUDGET",
    "LayerSensitivity",
    "MixedAllocation",
    "PROBES",
    "measure_layer_sensitivity",
    "allocate_mixed_plans",
    "suggest_budget",
    "mixed_precision_plan",
]


class _ProbeCounter:
    """Counts sensitivity-probe forwards (the expensive part of a mixed
    build).  The plan database's warm-build tests assert this stays at
    zero across a cache-hit engine build — the proof that a warm build
    skipped measurement entirely rather than re-running it and discarding
    the result."""

    def __init__(self) -> None:
        self.count = 0

    def reset(self) -> int:
        """Zero the counter, returning the value it held."""
        prev, self.count = self.count, 0
        return prev


PROBES = _ProbeCounter()

# Candidate (a_bits, w_bits) pairs searched per layer.  Every pair has
# proven-exact plans in the enumerator (a4w4/a8w4 single-word, a4w8/a8w8
# via multi-DSP columns), so the packing itself never adds error on top of
# the quantization the sensitivity pass measures.  The asymmetric pairs
# matter: weight width drives storage (nibble packing needs w<=4) while
# activation width drives the quantization noise floor.
DEFAULT_WIDTH_CANDIDATES = ((4, 4), (8, 4), (4, 8), (8, 8))

# Default model-level budget: total added mean logit-KL (nats, summed over
# demoted layers) the allocator may spend relative to the uniform
# reference-width plan.  Calibrated on the smoke zoo: enough to demote the
# tolerant half of the layers, never the logit-dominating ones.
DEFAULT_MIXED_BUDGET = 0.05

# Measured error deltas below this are treated as sampling noise, not as
# evidence a narrower width is free (cf. the sampled-zero floor in
# score.SpecScore): every admitted demotion charges at least this much,
# so a zero budget admits none.
NOISE_FLOOR = 1e-9


def _widest(widths) -> tuple[int, int]:
    """The reference candidate: most total bits, activation bits breaking
    ties (activation noise dominates the measured logit damage)."""
    return max(widths, key=lambda b: (b[0] + b[1], b[0]))


@dataclasses.dataclass(frozen=True)
class LayerSensitivity:
    """Measured model-level damage of quantizing one layer alone."""

    path: str
    n_values: int  # weight element count — the cost weighting
    # (a_bits, w_bits) -> mean logit divergence vs the float forward
    errors: dict[tuple[int, int], float]

    def delta(self, bits: tuple[int, int], base: tuple[int, int]) -> float:
        """Error added by serving this layer at ``bits`` instead of
        ``base``, floored at the measurement noise floor."""
        return max(self.errors[bits] - self.errors[base], NOISE_FLOOR)


@dataclasses.dataclass(frozen=True)
class MixedAllocation:
    """The allocator's verdict: one width pair (and plan) per layer."""

    assignments: dict[str, tuple[int, int]]  # path -> (a_bits, w_bits)
    plans: dict[str, PlanReport]             # path -> selected plan
    base_bits: tuple[int, int]
    budget: float
    predicted_error: float  # sum of admitted per-layer error deltas
    cost: float             # proxy-weighted packed-word work, allocated
    base_cost: float        # same, uniform reference widths
    sensitivities: tuple[LayerSensitivity, ...]

    @property
    def distinct_widths(self) -> int:
        return len(set(self.assignments.values()))

    @property
    def cost_vs_uniform_base(self) -> float:
        """Allocated packed-word work relative to the uniform reference
        widths (1.0 when nothing was demoted — or nothing is packable)."""
        return self.cost / self.base_cost if self.base_cost else 1.0

    def summary(self) -> dict:
        """JSON-ready digest (benchmarks, the serve CLI printout)."""
        return {
            "base_bits": list(self.base_bits),
            "budget": self.budget,
            "predicted_error": self.predicted_error,
            "cost_vs_uniform_base": self.cost_vs_uniform_base,
            "distinct_widths": self.distinct_widths,
            "assignments": {
                p: f"a{a}w{w}" for p, (a, w) in sorted(self.assignments.items())
            },
            # static pedigree of each layer's plan: exact vs bounded, and
            # the certified worst case when bounded
            "certificates": {
                p: self.plans[p].certificate.to_json_summary()
                for p in sorted(self.plans)
            },
        }


def _log_softmax(x: np.ndarray) -> np.ndarray:
    m = x.max(axis=-1, keepdims=True)
    return x - m - np.log(np.exp(x - m).sum(axis=-1, keepdims=True))


def _divergence(base_logits, got_logits, metric: str) -> float:
    """Mean per-position divergence between two (B, S, V) logit tensors."""
    base = np.asarray(base_logits, np.float64)
    got = np.asarray(got_logits, np.float64)
    if metric == "mse":
        return float(np.mean((got - base) ** 2) / max(np.mean(base**2), 1e-12))
    if metric != "kl":
        raise ValueError(f"metric {metric!r} not in ('kl', 'mse')")
    lp, lq = _log_softmax(base), _log_softmax(got)
    return float(np.mean(np.sum(np.exp(lp) * (lp - lq), axis=-1)))


def measure_layer_sensitivity(
    params,
    cfg,
    widths=DEFAULT_WIDTH_CANDIDATES,
    n_calib_tokens: int = 32,
    calib_batch: int = 2,
    seed: int = 0,
    metric: str = "kl",
    exact_first: bool = True,
) -> list[LayerSensitivity]:
    """Per-layer quantization damage at each candidate width pair.

    For every packable weight path, quantize that path ALONE onto the
    selected exact plan at each ``(a_bits, w_bits)`` in ``widths`` and run
    the model on seeded calibration tokens; the recorded error is the mean
    logit-KL (or relative MSE) against the float forward.  Deterministic
    per ``(params, cfg, widths, seed)`` — the allocator and its tests
    rely on that.  ``cfg.quant.mode`` must route tuned leaves (the engine
    passes its already-switched ``dsp_tuned`` config)."""
    from ..core.packed_params import (
        iter_packable_weights,
        quantize_for_serving,
        split_expert_stacks,
    )
    from ..models import transformer as T

    # Per-expert sensitivity: stacked MoE expert weights split into e<N>
    # leaves so each expert is probed (and later width-allocated) on its
    # own.  Idempotent — already-split trees pass through unchanged.
    params = split_expert_stacks(params)

    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(
        key, (calib_batch, n_calib_tokens), 2, cfg.vocab_size, jnp.int32
    )

    # Every probe tree has a different treedef (one converted path per
    # probe), so a jitted forward would recompile n_paths × n_widths
    # times; the eager forward runs each probe once and is the cheaper
    # trade at calibration sizes.
    def fwd(p):
        return T.forward(p, cfg, tokens)[0]

    base_logits = fwd(params)
    specs = {
        b: select_plan(b[0], b[1], error_budget=0.0, exact_first=exact_first)
        for b in widths
    }
    out = []
    targets = sorted(p for p, _ in iter_packable_weights(params))
    sizes = {p: int(np.prod(leaf.shape))
             for p, leaf in iter_packable_weights(params)}
    for path in targets:
        errors = {}
        for bits in widths:
            probe = quantize_for_serving(
                params, "dsp_tuned", plans={path: specs[bits]},
                only_planned=True, prepack=True,
            )
            PROBES.count += 1
            errors[bits] = _divergence(base_logits, fwd(probe), metric)
        out.append(LayerSensitivity(path, sizes[path], errors))
    return out


def _layer_costs(sens: LayerSensitivity, plans) -> dict[tuple[int, int], float]:
    """Packed-word work of serving this layer at each width: the plan's
    cost proxy (words per K element) times the weight element count."""
    return {
        bits: plan_cost_proxy(r.spec) * sens.n_values
        for bits, r in plans.items()
    }


def _plan_table(widths, error_budget, exact_first, shard_groups):
    """Per-width plan table at one shard count.  Widths with no shard-
    legal plan are simply absent (a8w8 8-way exceeds the int32 budget) —
    the allocator then never assigns them to a sharded row layer."""
    table = {}
    for b in widths:
        try:
            table[b] = select_plan(
                b[0], b[1], error_budget=error_budget,
                exact_first=exact_first, shard_groups=shard_groups,
            )
        except ValueError:
            if shard_groups == 1:
                raise
    return table


def allocate_mixed_plans(
    sensitivities,
    mixed_budget: float = DEFAULT_MIXED_BUDGET,
    widths=DEFAULT_WIDTH_CANDIDATES,
    base_bits: tuple[int, int] | None = None,
    error_budget: float = 0.0,
    exact_first: bool = True,
    shard_groups: int = 1,
) -> MixedAllocation:
    """Greedy budgeted width allocation over measured sensitivities.

    Every layer starts at ``base_bits`` (default: the widest candidate).
    Each round considers every (layer, cheaper width) demotion whose
    floored error delta still fits the remaining budget and applies the
    one with the best cost-saved / error-added ratio (ties broken by the
    larger saving, then path name — fully deterministic).  ``error_budget``
    is the PLAN-level MAE budget forwarded to ``select_plan`` per width;
    the default 0 keeps every per-layer plan provably exact, so the only
    error the model sees is the quantization the sensitivity pass
    measured.

    ``shard_groups > 1`` (tensor-parallel engines) keeps each layer's
    mixed width intact under partitioning — the DeepBurning-MixQ framing —
    by selecting shard-legal plans for ROW-partitioned layers (their
    packed words absorb every shard's products before extraction, see
    ``tuner.rank_plans``).  A width with no shard-legal plan is excluded
    for row layers; a row layer whose ``base_bits`` is excluded starts at
    the widest servable candidate instead (forced, so not charged against
    the budget, but included in ``predicted_error``)."""
    if base_bits is None:
        base_bits = _widest(widths)
    if base_bits not in widths:
        raise ValueError(f"base_bits {base_bits} not among candidates {widths}")
    plans = _plan_table(widths, error_budget, exact_first, 1)
    if shard_groups > 1:
        from ..runtime.sharding import linear_partition

        plans_row = _plan_table(widths, error_budget, exact_first,
                                shard_groups)

        def table_for(path):
            return plans_row if linear_partition(path) == "row" else plans
    else:
        def table_for(path):
            return plans

    # Certified packed-arithmetic error prior per candidate width: zero for
    # certificate-exact plans (the defaults), the certificate's analytic
    # per-extraction MAE bound otherwise.  A bounded plan's demotion charge
    # is floored at the *certified* error it adds over the current plan, so
    # a provably lossy plan can never be admitted for free just because the
    # calibration probe happened not to resolve its damage.
    def _prior(table):
        return {
            b: (0.0 if r.certificate.exact
                else float(r.certificate.mae_per_extraction))
            for b, r in table.items()
        }

    tables = {s.path: table_for(s.path) for s in sensitivities}
    priors = {s.path: _prior(tables[s.path]) for s in sensitivities}
    costs = {s.path: _layer_costs(s, tables[s.path]) for s in sensitivities}
    by_path = {s.path: s for s in sensitivities}
    current = {}
    starts = {}
    forced = 0.0
    for s in sensitivities:
        if base_bits in tables[s.path]:
            current[s.path] = base_bits
        else:
            cands = [b for b in widths if b in tables[s.path]]
            if not cands:
                raise ValueError(
                    f"no candidate width in {tuple(widths)} is servable for "
                    f"{s.path!r} at shard_groups={shard_groups}; lower the "
                    "tensor-parallel degree or narrow the candidates"
                )
            start = _widest(cands)
            current[s.path] = start
            forced += s.delta(start, base_bits)
        starts[s.path] = current[s.path]
    spent = 0.0
    while True:
        best = None  # (ratio, d_cost, path, bits, d_err)
        for path, sens in sorted(by_path.items()):
            cur = current[path]
            prior = priors[path]
            for bits in costs[path]:
                d_cost = costs[path][cur] - costs[path][bits]
                if d_cost <= 0:
                    continue
                d_err = max(sens.delta(bits, cur),
                            prior[bits] - prior[cur])
                if spent + d_err > mixed_budget:
                    continue
                better = best is None or (
                    (d_cost / d_err, d_cost) > (best[0], best[1])
                )
                if better:
                    best = (d_cost / d_err, d_cost, path, bits, d_err)
        if best is None:
            break
        _, _, path, bits, d_err = best
        current[path] = bits
        spent += d_err
    return MixedAllocation(
        assignments=current,
        plans={p: tables[p][b] for p, b in current.items()},
        base_bits=base_bits,
        budget=mixed_budget,
        predicted_error=spent + forced,
        cost=sum(costs[p][b] for p, b in current.items()),
        base_cost=sum(costs[p][starts[p]] for p in current),
        sensitivities=tuple(sensitivities),
    )


def suggest_budget(
    sensitivities,
    widths=DEFAULT_WIDTH_CANDIDATES,
    base_bits: tuple[int, int] | None = None,
    fraction: float = 0.5,
) -> float:
    """A budget that lands on a genuinely *mixed* assignment.

    Starts at ``fraction`` of the error a full demotion would add (every
    layer at its cheapest candidate) and halves until the greedy
    allocation holds at least two distinct width pairs — the first
    candidate budget can be uniform when every layer's first demotion
    rung fits inside it (e.g. all layers at ``a8w4``), which is a fine
    serving point but not the mixed operating point this helper is for.
    Deterministic for fixed sensitivities; the benchmark and the
    acceptance tests use it to pin a mixed per-layer table."""
    if base_bits is None:
        base_bits = _widest(widths)
    sensitivities = list(sensitivities)
    if len(sensitivities) < 2:
        raise ValueError(
            f"a mixed assignment needs at least two packable layers, got "
            f"{len(sensitivities)} — serve a uniform plan (dsp_tuned) "
            "instead"
        )
    cheapest = min(widths, key=lambda b: (b[0] + b[1], b))
    total = sum(s.delta(cheapest, base_bits) for s in sensitivities)
    budget = fraction * total
    for _ in range(12):
        alloc = allocate_mixed_plans(
            sensitivities, budget, widths=widths, base_bits=base_bits
        )
        if alloc.distinct_widths >= 2:
            return budget
        budget /= 2
    raise ValueError(
        "no mixed operating point found: every probed budget allocates a "
        "uniform width (layers are indistinguishable to the sensitivity "
        "pass — raise n_calib_tokens, or pick a mixed_budget by hand)"
    )


def mixed_precision_plan(
    params,
    cfg,
    mixed_budget: float = DEFAULT_MIXED_BUDGET,
    widths=DEFAULT_WIDTH_CANDIDATES,
    base_bits: tuple[int, int] | None = None,
    error_budget: float = 0.0,
    n_calib_tokens: int = 32,
    calib_batch: int = 2,
    seed: int = 0,
    metric: str = "kl",
    exact_first: bool = True,
    shard_groups: int = 1,
) -> MixedAllocation:
    """measure → allocate, end to end (the engine-build entry point).

    Sensitivity is measured single-device (quantization damage depends on
    the width, not the partitioning — the sharded arithmetic is bit-
    identical by construction); only the allocation's plan tables are
    shard-aware (see :func:`allocate_mixed_plans`)."""
    sens = measure_layer_sensitivity(
        params, cfg, widths=widths, n_calib_tokens=n_calib_tokens,
        calib_batch=calib_batch, seed=seed, metric=metric,
        exact_first=exact_first,
    )
    return allocate_mixed_plans(
        sens, mixed_budget=mixed_budget, widths=widths, base_bits=base_bits,
        error_budget=error_budget, exact_first=exact_first,
        shard_groups=shard_groups,
    )
