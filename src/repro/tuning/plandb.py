"""Persisted plan database: engine builds consult before they measure.

Build-time plan search is the expensive part of bringing a packed engine
up — the dsp_mixed sensitivity pass alone runs ``n_paths × n_widths``
calibration forwards — and it is repeated on every start even though its
result is a pure function of (model config, backend, weight shapes,
search settings).  This module persists that function's outputs so a
restarted or recovered production engine builds in seconds: the engine
computes a :func:`plan_key` fingerprint, asks :class:`PlanDB` for it, and
only falls back to measure-and-store on a miss.

Storage rides :class:`~repro.checkpoint.checkpointer.Checkpointer`
end-to-end rather than reimplementing durability:

* **Whole-DB-per-step.**  Every ``put`` writes ALL entries as one new
  checkpoint step (entries are small JSON — plans and measured floats, no
  arrays), so the newest step is always the complete database and the
  checkpointer's ``keep``-GC of older steps can never delete an entry a
  live engine was built from — whatever step it read, every entry it saw
  is also in every newer step.
* **Atomicity for free.**  ``Checkpointer._write`` publishes via
  tmp-dir + ``os.rename``; a crash mid-``put`` leaves the previous step
  intact and ``all_steps`` never offers the torn ``.tmp`` for restore, so
  the DB cannot be read half-written.
* **Explicit invalidation.**  Entries are wrapped in a
  ``{"schema": SCHEMA_VERSION, "entries": …}`` envelope; a version bump
  (or a corrupt envelope) makes :class:`PlanDB` treat the store as empty
  instead of deserializing stale layouts, and :meth:`PlanDB.invalidate`
  drops keys on demand.  Key staleness is structural: :func:`plan_key`
  folds in everything the search result depends on — model config,
  ``jax.default_backend()``, packable (path, shape) coverage, width
  candidates, budgets, seeds — so a changed model or backend simply
  misses rather than serving wrong plans.

Serialization round-trips the FULL measured record — every
:class:`~repro.tuning.tuner.PlanReport` float and, for dsp_mixed, the
complete :class:`~repro.tuning.mixed.MixedAllocation` including per-layer
sensitivities — so a warm build re-runs no scoring at all (the
``tuning.mixed.PROBES`` counter stays at zero; tests assert it).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any

from ..checkpoint.checkpointer import Checkpointer
from .mixed import LayerSensitivity, MixedAllocation
from .plans import spec_from_json, spec_to_json
from .tuner import PlanReport

__all__ = [
    "SCHEMA_VERSION",
    "PlanDB",
    "plan_key",
    "report_to_json",
    "report_from_json",
    "allocation_to_json",
    "allocation_from_json",
]

# Bump whenever the serialized layout (report fields, allocation envelope,
# key recipe) changes shape: old stores then read as empty and rebuild,
# never as garbled plans.
# v2: the tensor-parallel degree entered the key recipe (sharded row
# layers select different plans — widened-word constraint) and entries
# grew an optional "tiers" governor ladder.
SCHEMA_VERSION = 2


# ---- (de)serialization -----------------------------------------------------


def report_to_json(report: PlanReport) -> dict:
    """Loss-free JSON form of a scored/timed plan (all measured floats
    ride along — a warm load re-runs NO scoring)."""
    return {
        "spec": spec_to_json(report.spec),
        "mae": report.mae,
        "mae_per_extraction": report.mae_per_extraction,
        "ep": report.ep,
        "wce": report.wce,
        "cost_proxy": report.cost_proxy,
        "exhaustive": report.exhaustive,
        "block": list(report.block) if report.block else None,
        "us_per_call": report.us_per_call,
        "decode_block": (
            list(report.decode_block) if report.decode_block else None
        ),
        "decode_us_per_call": report.decode_us_per_call,
    }


def report_from_json(d: dict) -> PlanReport:
    return PlanReport(
        spec=spec_from_json(d["spec"]),
        mae=d["mae"],
        mae_per_extraction=d["mae_per_extraction"],
        ep=d["ep"],
        wce=int(d["wce"]),
        cost_proxy=d["cost_proxy"],
        exhaustive=bool(d["exhaustive"]),
        block=tuple(d["block"]) if d["block"] else None,
        us_per_call=d["us_per_call"],
        decode_block=tuple(d["decode_block"]) if d["decode_block"] else None,
        decode_us_per_call=d["decode_us_per_call"],
    )


def _bits_key(bits: tuple[int, int]) -> str:
    return f"{bits[0]},{bits[1]}"


def _bits_from_key(s: str) -> tuple[int, int]:
    a, w = s.split(",")
    return (int(a), int(w))


def allocation_to_json(alloc: MixedAllocation) -> dict:
    """Full mixed-allocation record, sensitivities included (so a warm
    engine exposes the same ``mixed_allocation`` a cold build would)."""
    return {
        "assignments": {p: list(b) for p, b in alloc.assignments.items()},
        "plans": {p: report_to_json(r) for p, r in alloc.plans.items()},
        "base_bits": list(alloc.base_bits),
        "budget": alloc.budget,
        "predicted_error": alloc.predicted_error,
        "cost": alloc.cost,
        "base_cost": alloc.base_cost,
        "sensitivities": [
            {
                "path": s.path,
                "n_values": s.n_values,
                "errors": {_bits_key(b): e for b, e in s.errors.items()},
            }
            for s in alloc.sensitivities
        ],
    }


def allocation_from_json(d: dict) -> MixedAllocation:
    return MixedAllocation(
        assignments={p: tuple(b) for p, b in d["assignments"].items()},
        plans={p: report_from_json(r) for p, r in d["plans"].items()},
        base_bits=tuple(d["base_bits"]),
        budget=d["budget"],
        predicted_error=d["predicted_error"],
        cost=d["cost"],
        base_cost=d["base_cost"],
        sensitivities=tuple(
            LayerSensitivity(
                path=s["path"],
                n_values=int(s["n_values"]),
                errors={_bits_from_key(k): v for k, v in s["errors"].items()},
            )
            for s in d["sensitivities"]
        ),
    )


# ---- keying ----------------------------------------------------------------


def _jsonable(obj: Any) -> Any:
    """Canonical JSON-able form for fingerprint material (tuples→lists,
    dataclasses→sorted dicts)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


def plan_key(cfg, serve_cfg, params) -> str:
    """Fingerprint of everything the plan search's result depends on.

    Folds in the full model config, the JAX backend (plan ranking is
    backend-aware via ``exact_first``/autotune timings), the packable
    (path, shape) coverage of the ACTUAL tree being quantized (post any
    projection fusion — the caller passes the tree it will quantize), and
    every ``ServeConfig`` knob the search reads.  Anything else changing
    (sampling, slots, pages…) keeps the key stable — those never alter
    plans.  A changed model/backend/search setting changes the key, so
    stale entries are unreachable rather than wrong.
    """
    import jax

    from ..core.packed_params import iter_packable_weights, split_expert_stacks

    shapes = sorted(
        (path, list(leaf.shape))
        for path, leaf in iter_packable_weights(split_expert_stacks(params))
    )
    material = {
        "schema": SCHEMA_VERSION,
        "model": _jsonable(cfg),
        "backend": jax.default_backend(),
        "shapes": [[p, s] for p, s in shapes],
        "search": {
            "quant_mode": serve_cfg.quant_mode,
            "plan_bits": _jsonable(serve_cfg.plan_bits),
            "error_budget": serve_cfg.error_budget,
            "autotune_plans": serve_cfg.autotune_plans,
            "mixed_budget": serve_cfg.mixed_budget,
            "width_candidates": _jsonable(serve_cfg.width_candidates),
            "calib_tokens": serve_cfg.calib_tokens,
            "seed": serve_cfg.seed,
            "use_kernel": serve_cfg.use_kernel,
            "fuse_projections": serve_cfg.fuse_projections,
            # the mesh shape is search material: row-partitioned layers
            # plan against the WIDENED word (tuner shard_groups), so a
            # tp=2 table is not servable at tp=1 and vice versa
            "tp": getattr(serve_cfg, "tp", 1),
        },
    }
    blob = json.dumps(material, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


# ---- the database ----------------------------------------------------------


class PlanDB:
    """Plan store over a ``Checkpointer`` directory (see module docstring
    for the whole-DB-per-step durability argument).

    Hit/miss/stale counters are plain attributes — the engine surfaces
    them in ``stats()`` and the warm-build tests assert on them.
    """

    def __init__(self, directory: str, keep: int = 3):
        self._ckpt = Checkpointer(directory, keep=keep)
        self.n_hits = 0
        self.n_misses = 0
        self.n_stale = 0

    @property
    def directory(self) -> str:
        return self._ckpt.directory

    # -- internal: read the newest complete envelope ------------------------
    def _load(self) -> dict[str, dict]:
        step = self._ckpt.latest_step()
        if step is None:
            return {}
        _, extra = self._ckpt.restore(step, like={})
        if not isinstance(extra, dict) or extra.get("schema") != SCHEMA_VERSION:
            # a different schema (or a foreign checkpoint dir) reads as
            # empty: rebuild-and-overwrite, never deserialize stale layouts
            self.n_stale += 1
            return {}
        entries = extra.get("entries", {})
        return entries if isinstance(entries, dict) else {}

    def _store(self, entries: dict[str, dict]) -> None:
        step = self._ckpt.latest_step()
        next_step = 0 if step is None else step + 1
        self._ckpt.save(
            next_step, {}, extra={"schema": SCHEMA_VERSION, "entries": entries}
        )

    # -- public API ---------------------------------------------------------
    def get(self, key: str) -> dict | None:
        """The stored entry for ``key`` (a JSON dict as given to ``put``),
        or None on miss."""
        entry = self._load().get(key)
        if entry is None:
            self.n_misses += 1
            return None
        self.n_hits += 1
        return entry

    def put(self, key: str, entry: dict) -> None:
        """Store ``entry`` under ``key`` as a new atomic step carrying the
        whole database (read-modify-write; last writer wins per key)."""
        entries = self._load()
        entries[key] = entry
        self._store(entries)

    def invalidate(self, key: str | None = None) -> int:
        """Drop one key (or every key when ``key`` is None); returns the
        number of entries dropped.  Written as a new step — the drop is
        atomic and crash-safe like any ``put``."""
        entries = self._load()
        if key is None:
            dropped = len(entries)
            entries = {}
        else:
            dropped = int(key in entries)
            entries.pop(key, None)
        if dropped:
            self._store(entries)
        return dropped

    def keys(self) -> list[str]:
        return sorted(self._load())

    def __len__(self) -> int:
        return len(self._load())
