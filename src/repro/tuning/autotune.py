"""Block-size autotuner for the pair-packed Pallas kernel.

The paper picks a packing *shape*; on TPU the other half of the throughput
frontier is the kernel's block shape.  This module sweeps ``(bm, bn, bk)``
candidates for a given spec and problem shape and times the jitted kernel.

Timing is pluggable: pass ``timer=`` any callable with the
``benchmarks.bench_util.time_us`` signature (``timer(fn, warmup=, iters=)``)
— the benchmark harness passes exactly that function — or use the built-in
default, which additionally blocks on the device result so async dispatch
doesn't fake a win.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.packed_matmul import packed_matmul
from ..kernels.ref import PackedDotSpec

__all__ = [
    "BlockTiming",
    "candidate_blocks",
    "autotune_block",
    "autotune_phase_blocks",
    "default_timer",
    "DEFAULT_BLOCKS",
    "DECODE_BLOCKS",
    "PHASE_BLOCKS",
]

# MXU/VPU-aligned sweep grid; filtered per spec/problem by candidate_blocks.
DEFAULT_BLOCKS = (
    (128, 128, 128),
    (128, 128, 256),
    (128, 256, 128),
    (256, 128, 128),
    (64, 128, 256),
    (64, 128, 128),
    (64, 64, 512),
    (32, 128, 128),
)

# Decode-phase sweep grid: a decode step is a GEMV over the slot batch
# (M of 1-16), so M blocks hug the batch instead of padding it 8-64x up to
# an MXU tile; N/K blocks still sweep the weight-streaming axis.
DECODE_BLOCKS = (
    (8, 128, 128),
    (8, 128, 256),
    (8, 256, 128),
    (8, 64, 256),
    (16, 128, 128),
    (16, 256, 128),
)

# The serving engine runs the same kernel in two regimes with very different
# M; each phase is tuned independently and the tuned plan carries one block
# per phase (tuner.PlanReport.block / .decode_block).
PHASE_BLOCKS = {"prefill": DEFAULT_BLOCKS, "decode": DECODE_BLOCKS}


@dataclasses.dataclass(frozen=True)
class BlockTiming:
    block: tuple[int, int, int]
    us_per_call: float


def default_timer(fn: Callable[[], object], warmup: int = 1, iters: int = 3) -> float:
    """``bench_util.time_us``-compatible timer that blocks on the result."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6


def candidate_blocks(
    spec: PackedDotSpec,
    m: int,
    k: int,
    n: int,
    blocks: Sequence[tuple[int, int, int]] | None = None,
    phase: str = "prefill",
) -> list[tuple[int, int, int]]:
    """Filter the sweep grid to blocks legal for ``spec`` and not absurdly
    oversized for the problem (> 2x padding waste on any axis is dropped,
    unless nothing survives — then the smallest legal block is kept).
    ``phase`` selects the default grid (decode sweeps small-M GEMV blocks)
    when ``blocks`` is not given."""
    if blocks is None:
        blocks = PHASE_BLOCKS[phase]
    legal = [b for b in blocks if b[2] % spec.chunk == 0]
    snug = [
        b for b in legal
        if b[0] <= 2 * m and b[1] <= 2 * n and b[2] <= 2 * k
    ]
    if snug:
        return snug
    if legal:
        return [min(legal, key=lambda b: b[0] * b[1] * b[2])]
    # every candidate's bk was smaller than one extraction chunk: build one
    return [(min(128, max(8, m)), min(128, max(8, n)), spec.chunk)]


def autotune_block(
    spec: PackedDotSpec,
    shape: tuple[int, int, int],
    blocks: Sequence[tuple[int, int, int]] | None = None,
    interpret: bool | None = None,
    timer: Callable[..., float] | None = None,
    warmup: int = 1,
    iters: int = 3,
    seed: int = 0,
    phase: str = "prefill",
    prepacked: bool = False,
) -> list[BlockTiming]:
    """Time every candidate block on a ``shape = (m, k, n)`` problem.

    Returns timings sorted fastest-first.  The kernel output is cross-checked
    bit-exact against the first block's result — a mistuned block may only
    be slow, never wrong.  ``phase`` picks the candidate grid when
    ``blocks`` is omitted; ``prepacked=True`` times the serving profile
    (weights packed ONCE outside the timed region, the prepacked kernel
    entry inside it) instead of the pack-per-call kernel."""
    from ..kernels.ops import auto_interpret
    from ..kernels.packed_matmul import packed_matmul_prepacked

    m, k, n = shape
    if interpret is None:
        interpret = auto_interpret()
    if timer is None:
        timer = default_timer
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 1 << spec.bits_a, (m, k)), jnp.int32)
    w = jnp.asarray(
        rng.integers(-(1 << (spec.bits_w - 1)), 1 << (spec.bits_w - 1), (k, n)),
        jnp.int32,
    )
    if prepacked:
        from ..kernels import ref as _ref

        packed = _ref.pack_weight_words(w, spec)
    cands = candidate_blocks(spec, m, k, n, blocks, phase=phase)
    timings: list[BlockTiming] = []
    reference = None
    for block in cands:
        if prepacked:
            def run(block=block):
                return packed_matmul_prepacked(
                    x, packed.words, packed.wsc, spec=spec, block=block,
                    interpret=interpret,
                )
        else:
            def run(block=block):
                return packed_matmul(
                    x, w, spec=spec, block=block, interpret=interpret
                )

        out = np.asarray(run())
        if reference is None:
            reference = out
        else:
            np.testing.assert_array_equal(out, reference)
        timings.append(BlockTiming(block, timer(run, warmup=warmup, iters=iters)))
    return sorted(timings, key=lambda t: t.us_per_call)


def autotune_phase_blocks(
    spec: PackedDotSpec,
    shapes: dict[str, tuple[int, int, int]],
    **kwargs,
) -> dict[str, BlockTiming]:
    """Best block PER SERVING PHASE: ``shapes`` maps a phase name
    ("prefill"/"decode") to its (m, k, n) probe — a chunked-prefill M and a
    slot-batch GEMV M tune very differently, so each phase sweeps its own
    candidate grid and the tuned plan carries one block per phase.

    Times the prepacked serving profile (the entry decode actually runs).
    """
    return {
        phase: autotune_block(
            spec, shape, phase=phase, prepacked=True, **kwargs
        )[0]
        for phase, shape in shapes.items()
    }
