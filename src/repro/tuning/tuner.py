"""Plan selection: the fastest packing plan inside an error budget.

Mirrors how the related work (wide-datapath arithmetic packing, near-precise
DSP approximation) treats packing-shape choice: not a fixed scheme but a
search over an accuracy/throughput frontier.  The pipeline is

    enumerate (plans.enumerate_specs)
      → score error (score.spec_error_stats, Eqns. 10-12)
      → filter by the caller's MAE-per-extraction budget
      → rank by measured kernel time (autotune.autotune_block) or, when
        measurement is off (engine build time), by an arithmetic cost proxy
      → select per layer (plan_linear_layers)

The cost proxy (``score.plan_cost_proxy``) counts int32 dot-general work
per K element: one packed multiply per ``chunk`` K elements — times the
plan's ``n_columns`` (a multi-DSP column plan spends one word per column
per pair position) — plus half a multiply for the mr contamination dot.
Fewer extractions per K is the whole throughput story of longer
accumulation chains, so the proxy ranks exactly like wall-clock on every
shape we have measured; wall-clock (``autotune=True``) remains the source
of truth for the benchmark harness.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from ..kernels.ref import INT4_EXACT, PackedDotSpec
from .autotune import autotune_block, autotune_phase_blocks
from .plans import enumerate_specs
from .score import SpecScore, plan_cost_proxy, spec_error_stats

__all__ = [
    "PlanReport",
    "DEFAULT_ERROR_BUDGET",
    "rank_plans",
    "select_plan",
    "plan_linear_layers",
]

# MAE per extraction (paper-table normalization).  0.5 admits every scheme
# whose mean error stays below half a quantization step of the *packed*
# arithmetic — the regime where packed-vs-float logit drift is dominated by
# the 4-bit quantization itself, not the packing (tests/test_serving.py).
DEFAULT_ERROR_BUDGET = 0.5


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """One scored (and optionally timed) packing plan."""

    spec: PackedDotSpec
    mae: float
    mae_per_extraction: float
    ep: float
    wce: int
    cost_proxy: float
    exhaustive: bool
    block: tuple[int, int, int] | None = None
    us_per_call: float | None = None
    # per-phase tuning: decode GEMVs (M = slot count) and chunked prefill
    # (M = slots × chunk) want different blocks — each phase is swept on its
    # own grid (autotune.PHASE_BLOCKS) and recorded separately
    decode_block: tuple[int, int, int] | None = None
    decode_us_per_call: float | None = None

    @property
    def name(self) -> str:
        return self.spec.name()

    @property
    def certificate(self):
        """Static :class:`~repro.analysis.verify.PlanCertificate` for the
        plan (memoized at the verifier — cheap to re-read)."""
        from ..analysis.verify import certify_spec

        return certify_spec(self.spec)

    def to_json(self) -> dict:
        return {
            "plan": self.name,
            "bits_a": self.spec.bits_a,
            "bits_w": self.spec.bits_w,
            "p": self.spec.p,
            "delta": self.spec.delta,
            "n_pairs": self.spec.n_pairs,
            "correction": self.spec.correction,
            "mr_bits": self.spec.mr_bits,
            "n_columns": self.spec.n_columns,
            "provably_exact": self.spec.provably_exact,
            # self-describing error pedigree for BENCH_tuning.json rows
            "certificate": self.certificate.to_json_summary(),
            "mae_per_extraction": self.mae_per_extraction,
            "ep_percent": self.ep,
            "wce": self.wce,
            "cost_proxy": self.cost_proxy,
            "exhaustive_grid": self.exhaustive,
            "block": list(self.block) if self.block else None,
            "us_per_call": self.us_per_call,
            "decode_block": list(self.decode_block) if self.decode_block
            else None,
            "decode_us_per_call": self.decode_us_per_call,
        }


def _report(score: SpecScore) -> PlanReport:
    return PlanReport(
        spec=score.spec,
        mae=score.mae,
        mae_per_extraction=score.mae_per_extraction,
        ep=score.ep,
        wce=score.wce,
        cost_proxy=plan_cost_proxy(score.spec),
        exhaustive=score.exhaustive,
    )


# Error scoring is deterministic per (spec, probe) and specs recur across
# layers and engine builds — memoize.
_SCORE_CACHE: dict[tuple, PlanReport] = {}


def _scored(spec: PackedDotSpec, n_extractions: int, samples: int, seed: int):
    key = (spec, n_extractions, samples, seed)
    if key not in _SCORE_CACHE:
        _SCORE_CACHE[key] = _report(
            spec_error_stats(spec, n_extractions=n_extractions,
                             samples=samples, seed=seed)
        )
    return _SCORE_CACHE[key]


def rank_plans(
    a_bits: int,
    w_bits: int,
    error_budget: float = DEFAULT_ERROR_BUDGET,
    shape: tuple[int, int, int] | None = None,
    autotune: bool = False,
    specs: Sequence[PackedDotSpec] | None = None,
    timer: Callable[..., float] | None = None,
    interpret: bool | None = None,
    n_extractions: int = 4,
    samples: int = 4096,
    seed: int = 0,
    decode_shape: tuple[int, int, int] | None = None,
    exact_first: bool = False,
    shard_groups: int = 1,
) -> list[PlanReport]:
    """Score every enumerated plan, keep those inside the error budget and
    return them fastest-first.

    ``autotune=True`` measures wall-clock per candidate on ``shape``
    (required then) with the best block from the sweep; otherwise ranking
    uses the arithmetic cost proxy.  ``decode_shape`` additionally sweeps
    the decode-phase grid (small-M GEMV blocks) on that shape, so prefill
    and decode tune independently — the report carries one block per phase.
    ``exact_first`` prefers PROVEN-exact plans at equal-or-worse cost proxy:
    on backends whose integer dots lower to scalar loops (every non-TPU
    jnp path), proven-exact plans run through the f32-GEMM shortcut
    (``DspTunedLeaf.w_f32``) at dense-float speed, so they are faster in
    wall-clock than the proxy's multiply count suggests — the serving
    engine switches this on whenever it serves the non-kernel path.
    Ties break toward lower error, then wider spacing (cheaper restore).

    ``shard_groups > 1`` plans for tensor-parallel row sharding
    (``runtime.tp_packed``): the cross-device psum accumulates
    ``shard_groups`` shards' pair products in one packed word before
    extraction, so the arithmetic that actually runs is the WIDENED spec
    (``n_pairs`` multiplied by the shard count — ``ref.widen_for_shards``).
    The enumerator emits minimal-spacing plans, so no enumerated spec
    widens legally; instead each enumerated spec is treated as the
    widened (post-reduce) spec — it is scored and budget-filtered as
    such — and the report returned carries the LOCAL per-shard spec
    (``n_pairs / shard_groups``) that each device executes.  Column
    counts up to 8 are searched (a8w8 admits no 2-way-shardable plan on
    the default column grid)."""
    local_of: dict[PackedDotSpec, PackedDotSpec] = {}
    if shard_groups > 1:
        if specs is None:
            specs = enumerate_specs(a_bits, w_bits,
                                    n_columns_choices=(1, 2, 4, 8))
        shardable = []
        for s in specs:
            if s.n_pairs % shard_groups:
                continue
            try:
                local = dataclasses.replace(
                    s, n_pairs=s.n_pairs // shard_groups
                )
            except ValueError:  # pragma: no cover - narrowing is always legal
                continue
            shardable.append(s)
            local_of[s] = local
        specs = shardable
    elif specs is None:
        specs = enumerate_specs(a_bits, w_bits)
    reports = [_scored(s, n_extractions, samples, seed) for s in specs]
    within = [r for r in reports if r.mae_per_extraction <= error_budget]

    def _proven(r):
        # the certificate is the proof; an exhaustively-enumerated zero is
        # an equally valid finite proof (and cross-checks the certificate)
        return r.certificate.exact or (r.mae == 0 and r.exhaustive)

    def _localize(ranked):
        # shard_groups: scored as the widened (post-psum) spec, served as
        # the local per-shard spec — swap specs on the way out
        if not local_of:
            return ranked
        return [dataclasses.replace(r, spec=local_of[r.spec]) for r in ranked]

    if autotune:
        if shape is None:
            raise ValueError("autotune=True needs a probe shape (m, k, n)")
        timed = []
        for r in within:
            # time the serving profile: weights packed once outside the
            # timed region, the prepacked kernel entry inside it — the code
            # path apply_linear actually runs
            timings = autotune_block(
                r.spec, shape, interpret=interpret, timer=timer, seed=seed,
                prepacked=True,
            )
            best = timings[0]
            timed.append(
                dataclasses.replace(
                    r, block=best.block, us_per_call=best.us_per_call
                )
            )
        # exact_first outranks wall-clock here too: off-TPU these timings
        # run the Pallas interpreter, which never sees the f32-GEMM
        # shortcut that makes proven-exact plans the fastest real path
        timed.sort(
            key=(lambda r: (not _proven(r), r.us_per_call,
                            r.mae_per_extraction))
            if exact_first
            else (lambda r: (r.us_per_call, r.mae_per_extraction))
        )
        if decode_shape is not None:
            # decode-phase sweep only for the prefill-ranked head: off-TPU
            # these timings run the Pallas interpreter, and probing every
            # in-budget plan on a second grid turns engine build from
            # seconds into tens of minutes for no ranking benefit (plans
            # outside the head fall back to default_block_for at runtime)
            head = []
            for r in timed[:3]:
                phased = autotune_phase_blocks(
                    r.spec, {"decode": decode_shape},
                    interpret=interpret, timer=timer, seed=seed,
                )
                head.append(dataclasses.replace(
                    r, decode_block=phased["decode"].block,
                    decode_us_per_call=phased["decode"].us_per_call,
                ))
            timed = head + timed[3:]
        return _localize(timed)
    if exact_first:
        return _localize(sorted(
            within,
            key=lambda r: (not _proven(r), r.cost_proxy,
                           r.mae_per_extraction, -r.spec.p),
        ))
    return _localize(sorted(
        within,
        key=lambda r: (r.cost_proxy, r.mae_per_extraction, -r.spec.p),
    ))


def select_plan(
    a_bits: int = 4,
    w_bits: int = 4,
    error_budget: float = DEFAULT_ERROR_BUDGET,
    **kwargs,
) -> PlanReport:
    """The fastest plan inside the budget; falls back to the exact int4
    preset when the budget admits nothing (e.g. budget 0 with widths that
    have no exact plan raises — there is nothing correct to run).

    The INT4_EXACT fallback is gated on ``shard_groups == 1``: the preset
    packs at minimal spacing, so its widened form overflows the middle
    field — serving it row-sharded would be exactly the illegal layout
    the certificate clauses reject.  A shard count no plan supports
    (a8w8 8-way exceeds the int32 budget outright) raises instead."""
    ranked = rank_plans(a_bits, w_bits, error_budget=error_budget, **kwargs)
    if ranked:
        return ranked[0]
    shard_groups = kwargs.get("shard_groups", 1)
    if a_bits == 4 and w_bits == 4 and shard_groups == 1:
        return _scored(INT4_EXACT, 4, 4096, 0)
    sharded = (
        f" with the contraction sharded {shard_groups} ways (the psum'd "
        "packed word must absorb every shard's products before extraction)"
        if shard_groups > 1 else ""
    )
    raise ValueError(
        f"no packing plan for a{a_bits}w{w_bits} fits error budget "
        f"{error_budget} (MAE per extraction){sharded}; raise the budget, "
        "change the operand widths or lower the tensor-parallel degree"
    )


def plan_linear_layers(
    params,
    a_bits: int = 4,
    w_bits: int = 4,
    error_budget: float = DEFAULT_ERROR_BUDGET,
    min_dim: int | None = None,
    shard_groups: int = 1,
    **kwargs,
) -> dict[str, PlanReport]:
    """Per-layer plan table for every packable matmul weight in ``params``.

    Keys are the same ``/``-joined tree paths ``quantize_for_serving`` uses,
    so the table routes straight into the serving conversion.  Plans are
    selected per distinct weight shape (layers sharing a shape share the
    ranking work); with the cost proxy the winner is shape-independent, with
    ``autotune=True`` each shape is measured at its own (m, k, n).

    ``shard_groups`` is the tensor-parallel degree of the engine the table
    is built for.  Only ROW-partitioned linears (``runtime.sharding.
    linear_partition``) accumulate across shards — their plans are selected
    with the widened-word constraint (see :func:`rank_plans`); column-
    partitioned and replicated linears run unmodified single-device
    arithmetic per shard and plan at ``shard_groups=1``."""
    from ..core.packed_params import MIN_DIM, iter_packable_weights
    from ..runtime.sharding import linear_partition

    if min_dim is None:
        min_dim = MIN_DIM
    table: dict[str, PlanReport] = {}
    by_shape: dict[tuple, PlanReport] = {}
    autotune = kwargs.get("autotune", False)
    for path, leaf in iter_packable_weights(params, min_dim=min_dim):
        d_in, d_out = leaf.shape[-2:]
        groups = (
            shard_groups if linear_partition(path) == "row" else 1
        )
        shape_key = (d_in, d_out, groups)
        if shape_key not in by_shape:
            call_kwargs = kwargs
            if autotune and "shape" not in kwargs:
                # probe each distinct weight shape per serving phase: a
                # prefill-like M (chunked grid) and a decode-like GEMV M —
                # the two phases tune to different blocks; a caller-supplied
                # shape overrides the prefill probe for all layers
                call_kwargs = dict(
                    kwargs,
                    shape=(128, d_in, d_out),
                    decode_shape=(8, d_in, d_out),
                )
            by_shape[shape_key] = select_plan(
                a_bits, w_bits, error_budget=error_budget,
                shard_groups=groups, **call_kwargs
            )
        table[path] = by_shape[shape_key]
    return table
