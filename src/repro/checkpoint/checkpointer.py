"""Fault-tolerant checkpointing.

Design for 1000+ nodes (see DESIGN.md §4):
  * **atomic**: write to ``step_XXXX.tmp/`` then ``os.rename`` — a crash
    mid-write can never corrupt the latest-complete checkpoint;
  * **async**: ``save_async`` snapshots device arrays to host (cheap,
    blocking only on the D2H copy) and writes in a background thread so the
    train loop keeps stepping;
  * **elastic restore**: arrays are saved whole (per-host shard files would
    be the multi-host extension) and ``restore`` re-``device_put``s them
    under ANY target sharding/mesh, so a job can restart on a different
    device count (elastic scaling) — exercised by the resharding tests;
  * **manifest**: step, pytree structure, mesh shape and data-pipeline
    state live in ``manifest.json``; ``latest_step`` scans for the newest
    complete checkpoint (restart-from-latest policy).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._async_error: BaseException | None = None

    # ---- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        host_tree = jax.device_get(tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()  # one outstanding write at a time
        host_tree = jax.device_get(tree)  # snapshot before returning
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host_tree, extra or {}),
            daemon=True,
        )
        self._thread.start()

    def _write_guarded(self, step: int, host_tree: Any, extra: dict) -> None:
        try:
            self._write(step, host_tree, extra)
        except BaseException as e:  # surfaced at the next wait()
            self._async_error = e

    def wait(self) -> None:
        """Join the in-flight async write.  A background write that died
        (disk full, torn process state) re-raises HERE instead of
        disappearing with the daemon thread — a caller that believes its
        save landed when it didn't would later "restore" an older step
        and silently lose work."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _write(self, step: int, host_tree: Any, extra: dict) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        treedef = jax.tree.structure(host_tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(flat),
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    # ---- restore ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        """Steps with a COMPLETE checkpoint.  ``.tmp`` directories (a
        writer died mid-write before the atomic rename) and stray
        non-checkpoint names are ignored — a torn write must never be
        offered for restore."""
        out = []
        for name in os.listdir(self.directory):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            suffix = name.split("_", 1)[1]
            if suffix.isdigit():
                out.append(int(suffix))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, step: int, like: Any, shardings: Any | None = None
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; optionally re-shard.

        ``shardings`` may be a pytree of ``jax.sharding.Sharding`` matching
        ``like`` — arrays are placed under the *target* sharding regardless
        of the mesh they were saved from (elastic restart).
        """
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pth, leaf in flat_like[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            arr = data[key]
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
        tree = jax.tree.unflatten(flat_like[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return tree, manifest["extra"]
