"""Serving subsystem: engines, paged KV cache, sampling, scheduler.

Two engines share the scheduler, sampler and quantized-weight build:
``Engine`` (fixed-slot FIFO over dense per-slot cache windows) and
``ContinuousEngine`` (continuous batching over a paged KV cache with
preemption and prefix sharing).  See their docstrings for the
architecture overviews.

**The recurrent-state chunking invariant.**  Every registry family —
recurrent state (SSM/hybrid) included — prefills through the shared
``(n_slots, prefill_chunk)`` grid, and chunked prefill must leave the
engine in the same state as chunk-1 prefill.  The pieces that make that
hold, and that changes to prefill or the mixers must preserve:

* The prefill forward receives a per-row ``valid`` length mask, and the
  recurrent mixers run **sequential** scan math under it — a masked-out
  position carries the previous state forward bitwise unchanged, so a
  padded chunk advances each row's state by exactly its real tokens
  (``tests/test_ssm.py`` pins chunked-masked == per-token bitwise at the
  mixer level).
* MoE dispatch is **dropless** on the serving path (``valid`` given):
  expert capacity covers every valid assignment, so a token's expert
  output is a pure function of its own hidden state, never of the static
  batch shape or of the other lanes.  Training (``valid=None``) keeps
  capacity-factor drop semantics.
* The engine-level guarantee is therefore: chunked prefill reproduces
  chunk-1 tokens and decode state — bitwise for ssm; for hybrid within
  ulp-level tolerance, because XLA fuses the chunk-C and chunk-1
  compiled programs differently around mamba's exp/softplus chains (the
  mixer math itself is bit-exact; only program fusion differs).
  ``tests/test_family_serving.py`` holds the fixed-case and property
  forms, plus staggered joins and preemption/resume of recurrent state.

The one family-shaped restriction left is prefix sharing: recurrent and
sliding-window families reject ``register_shared_prefix`` with an error
naming the blocking feature (their decode state is not shareable KV
pages).
"""

from .engine import ContinuousEngine, Engine, ServeConfig
from .governor import Governor, GovernorConfig, Tier, build_tiers
from .paged_cache import OutOfPages, PageAllocator
from .replica import ReplicaFront
from .sampling import GREEDY, SamplingParams
from .scheduler import CANCEL_REASONS, Request, Scheduler, percentile

__all__ = [
    "Engine",
    "ContinuousEngine",
    "ServeConfig",
    "Governor",
    "GovernorConfig",
    "Tier",
    "build_tiers",
    "PageAllocator",
    "OutOfPages",
    "ReplicaFront",
    "SamplingParams",
    "GREEDY",
    "Request",
    "Scheduler",
    "percentile",
    "CANCEL_REASONS",
]
