"""Serving subsystem: slot engine, sampling, request scheduler.

See ``engine.Engine`` for the architecture overview.
"""

from .engine import Engine, ServeConfig
from .sampling import GREEDY, SamplingParams
from .scheduler import Request, Scheduler

__all__ = [
    "Engine",
    "ServeConfig",
    "SamplingParams",
    "GREEDY",
    "Request",
    "Scheduler",
]
