"""Serving subsystem: engines, paged KV cache, sampling, scheduler.

Two engines share the scheduler, sampler and quantized-weight build:
``Engine`` (fixed-slot FIFO over dense per-slot cache windows) and
``ContinuousEngine`` (continuous batching over a paged KV cache with
preemption and prefix sharing).  See their docstrings for the
architecture overviews.
"""

from .engine import ContinuousEngine, Engine, ServeConfig
from .paged_cache import OutOfPages, PageAllocator
from .sampling import GREEDY, SamplingParams
from .scheduler import Request, Scheduler, percentile

__all__ = [
    "Engine",
    "ContinuousEngine",
    "ServeConfig",
    "PageAllocator",
    "OutOfPages",
    "SamplingParams",
    "GREEDY",
    "Request",
    "Scheduler",
    "percentile",
]
