"""Slot-based continuous-batching serving engine.

A fixed pool of ``n_slots`` sequences shares one stacked decode cache; the
scheduler admits queued requests into free slots, finished sequences free
them.  The engine has exactly three jitted programs, all with static
shapes, so steady-state serving never retraces:

* **batched chunked prefill** — admitted prompts are padded onto a shared
  ``(n_slots, prefill_chunk)`` grid and every chunk is ONE ``T.forward``
  call.  A prompt of length L costs ``ceil(L / chunk)`` forward calls
  instead of L (the seed engine scanned one token at a time *and* retraced
  per prompt length).  Rows not being prefilled are masked out of the cache
  merge, so admission can overlap slots that are mid-decode.
* **decode step** — advances every active slot one token per call (the
  standard TPU serving shape), with per-slot positions so slots sit at
  different depths.
* **sampling** — temperature/top-k/top-p with per-slot PRNG keys
  (``serving.sampling``), one batched draw for prefill and decode alike.

With ``ServeConfig.quant_mode = "int4_packed"`` the engine calls
``quantize_for_serving`` once at build time: every large matmul weight is
stored as packed int4 nibbles and ``decode_step`` runs the paper's packed
matmul kernel straight off the stored nibbles — the serving-side payoff of
DSP-packing (decode is weight-bandwidth-bound).  ``int8``/``dsp_packed``
select the corresponding per-call arithmetic paths.

``quant_mode = "dsp_tuned"`` goes further: the ``repro.tuning`` planner
enumerates every legal packing plan for ``plan_bits`` — including
multi-DSP *column-packed* plans (``n_columns > 1``), which spread one dot
product across several packed int32 words and are the only legal plans for
``plan_bits=(8, 8)`` — scores each by simulated error, and picks per layer
the fastest plan whose MAE fits ``error_budget``; weights are quantized
once onto each layer's plan and decode runs per-layer pair-packed
arithmetic.  The chosen table is exposed as ``engine.plan_table`` (path →
``tuning.PlanReport``).

``quant_mode = "dsp_mixed"`` (or ``plan_bits="auto"``) adds the width axis
to that search: a sensitivity pass (``tuning.mixed``) measures, per
packable weight path, the logit damage of quantizing that layer alone at
each candidate ``(a_bits, w_bits)`` on seeded calibration activations,
and a greedy allocator assigns each layer its own width pair — narrow
widths (more packed multiplications per int32 word, cheaper plans) for
tolerant layers, wide plans for sensitive ones — under the model-level
``mixed_budget``.  The allocation is exposed as
``engine.mixed_allocation`` (a ``tuning.MixedAllocation``).

Termination goes through a single code path (``_finish_slot``): EOS,
per-request ``max_new`` and the cache-capacity bound all free the slot,
record the finish reason and report the rid to the caller.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.packed_params import (
    SERVING_MODES,
    fuse_projection_weights,
    quantize_for_serving,
)
from ..models import transformer as T
from ..models.config import ModelConfig
from .sampling import SamplingParams, sample_tokens, slot_key
from .scheduler import Scheduler

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 8
    max_len: int = 512
    prefill_chunk: int = 16
    max_new: int = 64          # default per-request budget (submit can override)
    eos_token: int = 1
    # weight path: native | int8 | int4_packed | dsp_packed | dsp_tuned |
    # dsp_mixed (see core.packed_params.quantize_for_serving)
    quant_mode: str = "native"
    use_kernel: bool = False   # Pallas kernels vs jnp refs (CPU tests use ref)
    # engine-build weight preprocessing for the packed decode fast path:
    # prepack builds device-resident packed operands once (words / zp rows /
    # f32-exact grids); fuse_projections concatenates same-input projections
    # so a decode step runs one GEMV where it ran several (bit-identical per
    # output column — quantization is per-channel).  "mlp" fuses up|gate,
    # "all" also fuses q|k|v.  Off by default: inside the scanned decode
    # step on CPU XLA the post-fusion splits cost more than the saved GEMV
    # dispatches (isolated layers DO win — this is a backend-specific call;
    # flip it on for TPU runs).
    prepack: bool = True
    fuse_projections: bool | str = "none"
    # dsp_tuned plan search: operand widths, MAE-per-extraction budget and
    # whether to wall-clock-autotune block sizes (off by default: the cost
    # proxy ranks identically and engine build stays fast).  plan_bits may
    # be the string "auto" instead of a width pair: widths are then chosen
    # PER LAYER by the sensitivity allocator (quant_mode "dsp_mixed" —
    # a dsp_tuned-mode config with plan_bits="auto" is promoted to it).
    plan_bits: tuple[int, int] | str = (4, 4)
    error_budget: float = 0.5
    autotune_plans: bool = False
    # dsp_mixed: the model-level error budget (total added mean logit-KL on
    # the calibration forward vs the uniform widest-candidate plan) the
    # greedy width allocator may spend, the candidate width pairs it
    # chooses from (None = tuning.mixed.DEFAULT_WIDTH_CANDIDATES), and the
    # calibration volume (tokens per sequence; seeded from ``seed``)
    mixed_budget: float = 0.05
    width_candidates: tuple[tuple[int, int], ...] | None = None
    calib_tokens: int = 32
    # default sampling (submit can override per request)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self) -> None:
        if self.quant_mode not in SERVING_MODES:
            raise ValueError(
                f"quant_mode {self.quant_mode!r} not in {SERVING_MODES}"
            )
        if self.fuse_projections not in (True, False, "none", "mlp", "all"):
            raise ValueError(
                f"fuse_projections {self.fuse_projections!r} not in "
                "(True, False, 'none', 'mlp', 'all')"
            )
        if self.plan_bits == "auto":
            # "auto" means per-layer width allocation — that IS dsp_mixed
            if self.quant_mode == "dsp_tuned":
                object.__setattr__(self, "quant_mode", "dsp_mixed")
            elif self.quant_mode != "dsp_mixed":
                raise ValueError(
                    'plan_bits="auto" needs quant_mode "dsp_tuned" or '
                    f'"dsp_mixed", got {self.quant_mode!r}'
                )
        elif isinstance(self.plan_bits, str):
            raise ValueError(
                f"plan_bits {self.plan_bits!r} must be a (a_bits, w_bits) "
                'pair or "auto"'
            )
        if self.mixed_budget < 0:
            raise ValueError(
                f"mixed_budget must be >= 0, got {self.mixed_budget}"
            )
        if self.quant_mode == "dsp_mixed" and self.autotune_plans:
            # the width allocator selects plans by cost proxy only; a
            # silent no-op here would let the flag lie about what ran
            raise ValueError(
                "autotune_plans is not supported with dsp_mixed: per-layer "
                "width allocation ranks plans by the cost proxy (use "
                "dsp_tuned for wall-clock block sweeps)"
            )


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 mixed_allocation=None):
        """``mixed_allocation`` (a ``tuning.MixedAllocation``) skips the
        dsp_mixed engine-build sensitivity pass and serves the given
        per-layer plan table instead — for callers that already measured
        (the serving benchmark probes budgets before building).  Its paths
        must match this engine's param tree (same fusion settings)."""
        self.plan_table = {}
        self.mixed_allocation = None
        if mixed_allocation is not None and serve_cfg.quant_mode != "dsp_mixed":
            # dropping a caller-measured allocation would silently serve
            # different plans than the caller benchmarked
            raise ValueError(
                "mixed_allocation was given but quant_mode is "
                f"{serve_cfg.quant_mode!r}; it is only served under "
                '"dsp_mixed"'
            )
        if serve_cfg.quant_mode not in ("native", "none"):
            # switch the arithmetic mode but preserve the caller's other
            # LinearSpec choices (dsp_spec correction scheme, act_bits).
            # dsp_mixed leaves route through the dsp_tuned arithmetic —
            # each DspTunedLeaf carries its own (per-layer) plan.
            linear_mode = (
                "dsp_tuned" if serve_cfg.quant_mode == "dsp_mixed"
                else serve_cfg.quant_mode
            )
            cfg = dataclasses.replace(
                cfg,
                quant=dataclasses.replace(
                    cfg.quant, mode=linear_mode,
                    use_kernel=serve_cfg.use_kernel,
                ),
            )
            fuse = serve_cfg.fuse_projections
            if fuse not in (False, "none"):
                # fused same-input GEMVs — bit-identical per output column
                # under per-channel quantization
                # (core.packed_params.fuse_projection_weights)
                params = fuse_projection_weights(
                    params, fuse_attn=fuse in (True, "all"), fuse_mlp=True
                )
            if serve_cfg.quant_mode == "dsp_mixed":
                if mixed_allocation is None:
                    from ..tuning.mixed import (
                        DEFAULT_WIDTH_CANDIDATES,
                        mixed_precision_plan,
                    )

                    # sensitivity pass + greedy width allocation on
                    # calibration activations (tuning.mixed): per-layer
                    # (a_bits, w_bits) under the model-level mixed_budget;
                    # the per-width plan search keeps plans provably exact
                    # so the only error the model sees is the quantization
                    # the pass measured
                    mixed_allocation = mixed_precision_plan(
                        params, cfg,
                        mixed_budget=serve_cfg.mixed_budget,
                        widths=(serve_cfg.width_candidates
                                or DEFAULT_WIDTH_CANDIDATES),
                        n_calib_tokens=serve_cfg.calib_tokens,
                        seed=serve_cfg.seed,
                        exact_first=not serve_cfg.use_kernel,
                    )
                self.mixed_allocation = mixed_allocation
                self.plan_table = mixed_allocation.plans
                params = quantize_for_serving(
                    params, "dsp_mixed", plans=self.plan_table,
                    prepack=serve_cfg.prepack,
                )
            elif serve_cfg.quant_mode == "dsp_tuned":
                from ..tuning import plan_linear_layers

                a_bits, w_bits = serve_cfg.plan_bits
                self.plan_table = plan_linear_layers(
                    params, a_bits=a_bits, w_bits=w_bits,
                    error_budget=serve_cfg.error_budget,
                    autotune=serve_cfg.autotune_plans,
                    # non-kernel serving runs proven-exact plans through the
                    # f32-GEMM shortcut — rank those first (see rank_plans)
                    exact_first=not serve_cfg.use_kernel,
                )
                params = quantize_for_serving(
                    params, "dsp_tuned", plans=self.plan_table,
                    prepack=serve_cfg.prepack,
                )
            else:
                params = quantize_for_serving(
                    params, serve_cfg.quant_mode, prepack=serve_cfg.prepack
                )
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        b = serve_cfg.n_slots
        # Chunked prefill needs (a) contiguous full-attention cache writes —
        # ring-buffer (sliding-window) caches only support single-position
        # writes — and (b) per-position masking, which recurrent state
        # (ssm/hybrid) doesn't have: a padded chunk would advance the
        # recurrent state past the prompt.  Both fall back to chunk=1.
        recurrent = cfg.family in ("ssm", "hybrid")
        self._chunk = 1 if (cfg.sliding_window or recurrent) else max(
            1, min(serve_cfg.prefill_chunk, serve_cfg.max_len)
        )
        # the prefill grid is padded to whole chunks, so allocate the cache
        # on the same grid — otherwise the last chunk's writes would clamp
        # at max_len and shift K/V backwards over earlier positions
        window = -(-serve_cfg.max_len // self._chunk) * self._chunk
        self.cache = T.init_cache(cfg, b, window)
        # per-leaf batch axis: attention KV leaves carry the slot axis at 1,
        # stacked recurrent state (mlstm/mamba) at 2 — locate it by shape
        # difference between a b-slot and a (b+1)-slot cache
        s_b = jax.eval_shape(lambda: T.init_cache(cfg, b, window))
        s_b1 = jax.eval_shape(lambda: T.init_cache(cfg, b + 1, window))
        self._batch_axes = jax.tree.map(
            lambda x, y: next(
                i for i, (p, q) in enumerate(zip(x.shape, y.shape)) if p != q
            ),
            s_b, s_b1,
        )
        self.positions = np.zeros(b, np.int32)
        self.active = np.zeros(b, bool)
        self.last_token = np.zeros(b, np.int32)
        self._slot_rid = np.full(b, -1, np.int64)
        # per-slot sampling state (set at admission from the request)
        self._temperature = np.zeros(b, np.float32)
        self._top_k = np.zeros(b, np.int32)
        self._top_p = np.ones(b, np.float32)
        self._keys = np.zeros((b, 2), np.uint32)
        self._base_key = jax.random.PRNGKey(serve_cfg.seed)
        self.scheduler = Scheduler()
        self._sample = jax.jit(sample_tokens)
        # Device-resident decode state: steady-state decode advances tokens/
        # positions ON DEVICE and only syncs the sampled token back, so a
        # step does ONE host->device transfer worth of dispatch instead of
        # seven (~0.5 ms/step of device_put on CPU).  The numpy arrays above
        # stay authoritative for scheduling logic; ``_dev_dirty`` marks host
        # -side mutations (admission, finishes) that must be re-pushed.
        self._dev_state = None
        self._dev_dirty = True

    # ---- jitted steps ---------------------------------------------------
    @staticmethod
    def _row_select(mask, leaf, axis):
        """Broadcast a (n_slots,) bool mask against ``leaf`` along its
        batch ``axis``."""
        shape = [1] * leaf.ndim
        shape[axis] = mask.shape[0]
        return mask.reshape(shape)

    @partial(jax.jit, static_argnums=(0,))
    def _reset_slots(self, cache, row_mask):
        """Zero the cache state of the slots in ``row_mask`` — a freshly
        admitted request must not continue from the previous occupant's
        recurrent state or stale KV."""
        return jax.tree.map(
            lambda leaf, ax: jnp.where(
                self._row_select(row_mask, leaf, ax),
                jnp.zeros((), leaf.dtype), leaf,
            ),
            cache, self._batch_axes,
        )

    @partial(jax.jit, static_argnums=(0,))
    def _prefill_chunk(self, params, cache, tokens, base, row_mask, last_idx,
                       last_hidden):
        """One chunk of batched prefill.

        ``tokens``: (n_slots, C) — rows selected by ``row_mask`` carry
        prompt tokens for positions ``[base, base + C)``; other rows are
        ignored (their cache updates are masked out of the merge).
        Collects each admitted row's last-prompt-token *hidden state* into
        ``last_hidden`` when that position falls inside this chunk; the
        lm_head runs once on the gathered rows (``_lm_head``), not on every
        position of every chunk.
        """
        b, c = tokens.shape
        positions = jnp.broadcast_to(base + jnp.arange(c)[None], (b, c))
        hidden, new_cache, _ = T.forward(
            params, self.cfg, tokens, positions=positions, cache=cache,
            return_hidden=True,
        )
        cache = jax.tree.map(
            lambda old, new, ax: jnp.where(
                self._row_select(row_mask, old, ax), new, old
            ),
            cache, new_cache, self._batch_axes,
        )
        idx = jnp.clip(last_idx - base, 0, c - 1)
        row_hidden = jnp.take_along_axis(
            hidden, idx[:, None, None], axis=1
        )[:, 0]
        in_chunk = row_mask & (last_idx >= base) & (last_idx < base + c)
        last_hidden = jnp.where(
            in_chunk[:, None], row_hidden.astype(last_hidden.dtype), last_hidden
        )
        return cache, last_hidden

    @partial(jax.jit, static_argnums=(0,))
    def _lm_head(self, params, hidden):
        """(n_slots, d) hidden → (n_slots, V) f32 logits (mirrors
        ``T.forward``'s head)."""
        if self.cfg.tie_embeddings:
            return hidden.astype(jnp.float32) @ params["embed"]["w"].T.astype(
                jnp.float32
            )
        from ..core.packed_linear import apply_linear

        return apply_linear(
            params["lm_head"], hidden, self.cfg.quant
        ).astype(jnp.float32)

    def _push_state(self) -> None:
        """Host → device refresh of the decode state (admission/finish)."""
        self._dev_state = jax.device_put({
            "tokens": self.last_token,
            "positions": self.positions,
            "active": self.active,
            "keys": self._keys,
            "temperature": self._temperature,
            "top_k": self._top_k,
            "top_p": self._top_p,
        })
        self._dev_dirty = False

    @partial(jax.jit, static_argnums=(0,))
    def _decode_step(self, params, cache, state):
        """One decode step off the device-resident state; tokens/positions
        advance on device (active rows only — mirroring the host loop), so
        steady-state decode does no host→device transfers at all."""
        tokens, positions = state["tokens"], state["positions"]
        logits, new_cache, _ = T.forward(
            params, self.cfg, tokens[:, None], positions=positions[:, None],
            cache=cache,
        )
        nxt = sample_tokens(
            logits[:, -1], state["keys"], positions, state["temperature"],
            state["top_k"], state["top_p"],
        )
        active = state["active"]
        new_state = dict(
            state,
            tokens=jnp.where(active, nxt, tokens),
            positions=positions + active.astype(positions.dtype),
        )
        return new_cache, new_state, nxt

    # ---- request lifecycle ----------------------------------------------
    def submit(self, prompt: list[int], max_new: int | None = None,
               sampling: SamplingParams | None = None,
               admit: bool = True) -> int:
        """Enqueue a request; it is admitted as soon as a slot frees up.

        ``admit=False`` defers admission to the next ``step()`` so that a
        burst of submissions shares one batched prefill pass.
        Returns the request id (outputs appear in ``outputs[rid]``).
        """
        if len(prompt) >= self.scfg.max_len - 1:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_len-1 ({self.scfg.max_len - 1})"
            )
        if max_new is None:
            max_new = self.scfg.max_new
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if sampling is None:
            sampling = SamplingParams(
                self.scfg.temperature, self.scfg.top_k, self.scfg.top_p
            )
        rid = self.scheduler.submit(prompt, max_new, sampling)
        if admit:
            self._admit()
        return rid

    def _admit(self) -> list[int]:
        """Move queued requests into free slots: batched chunked prefill +
        first-token sample.  Returns rids finished during admission (a
        first token can already hit EOS or a 1-token budget)."""
        free = np.flatnonzero(~self.active)
        admitted = self.scheduler.admit(len(free))
        if not admitted:
            return []
        t0 = time.monotonic()
        b, c = self.scfg.n_slots, self._chunk
        lmax = max(len(r.prompt) for r in admitted)
        n_chunks = -(-lmax // c)
        tokens = np.zeros((b, n_chunks * c), np.int32)
        row_mask = np.zeros(b, bool)
        last_idx = np.zeros(b, np.int32)
        for slot, req in zip(free, admitted):
            ln = len(req.prompt)
            tokens[slot, :ln] = req.prompt
            row_mask[slot] = True
            last_idx[slot] = ln - 1
            self.positions[slot] = ln
            self.active[slot] = True
            self._slot_rid[slot] = req.rid
            self._temperature[slot] = req.sampling.temperature
            self._top_k[slot] = req.sampling.top_k
            self._top_p[slot] = req.sampling.top_p
            self._keys[slot] = np.asarray(slot_key(self._base_key, req.rid))

        cache = self._reset_slots(self.cache, jnp.asarray(row_mask))
        last_hidden = jnp.zeros((b, self.cfg.d_model), T._dtype(self.cfg))
        last_idx_j = jnp.asarray(last_idx)
        for ci in range(n_chunks):
            base = ci * c
            # rows whose prompt is already fully written skip later chunks
            mask_c = jnp.asarray(row_mask & (last_idx >= base))
            cache, last_hidden = self._prefill_chunk(
                self.params, cache,
                jnp.asarray(tokens[:, base:base + c]), jnp.int32(base),
                mask_c, last_idx_j, last_hidden,
            )
        self.cache = cache

        first = np.asarray(self._sample(
            self._lm_head(self.params, last_hidden),
            jnp.asarray(self._keys), last_idx_j,
            jnp.asarray(self._temperature), jnp.asarray(self._top_k),
            jnp.asarray(self._top_p),
        ))
        n_prompt_tokens = sum(len(r.prompt) for r in admitted)
        self.scheduler.note_prefill(
            n_prompt_tokens, time.monotonic() - t0, admitted
        )
        finished = []
        for slot, req in zip(free, admitted):
            tok = int(first[slot])
            req.tokens.append(tok)
            self.last_token[slot] = tok
            rid = self._maybe_finish(slot, tok)
            if rid is not None:
                finished.append(rid)
        self._dev_dirty = True  # admission rewrote slot state on the host
        return finished

    def _maybe_finish(self, slot: int, tok: int) -> int | None:
        """Single termination path: EOS, per-request budget and cache
        capacity all land here."""
        req = self.scheduler.requests[int(self._slot_rid[slot])]
        if tok == self.scfg.eos_token:
            return self._finish_slot(slot, "eos")
        if len(req.tokens) >= req.max_new:
            return self._finish_slot(slot, "length")
        if self.positions[slot] >= self.scfg.max_len - 1:
            return self._finish_slot(slot, "length")
        return None

    def _finish_slot(self, slot: int, reason: str) -> int:
        rid = int(self._slot_rid[slot])
        self.active[slot] = False
        self._slot_rid[slot] = -1
        self.scheduler.finish(rid, reason)
        return rid

    def step(self) -> list[int]:
        """Admit what fits, then advance every active slot one token.
        Returns the rids that finished this step."""
        finished = self._admit()
        if not self.active.any():
            return finished
        t0 = time.monotonic()
        if self._dev_dirty:
            self._push_state()
        self.cache, self._dev_state, nxt = self._decode_step(
            self.params, self.cache, self._dev_state
        )
        nxt = np.asarray(nxt)
        active_slots = np.flatnonzero(self.active)
        self.scheduler.note_decode(len(active_slots), time.monotonic() - t0)
        n_finished = len(finished)
        for slot in active_slots:
            # numpy mirrors advance exactly like the device state did
            self.positions[slot] += 1
            tok = int(nxt[slot])
            self.scheduler.requests[int(self._slot_rid[slot])].tokens.append(tok)
            self.last_token[slot] = tok
            rid = self._maybe_finish(slot, tok)
            if rid is not None:
                finished.append(rid)
        if len(finished) > n_finished:
            self._dev_dirty = True  # freed slots changed the active mask
        return finished

    def generate(self, prompts: list[list[int]], max_new: int | None = None,
                 sampling: SamplingParams | None = None) -> dict[int, list[int]]:
        """Drive a batch of prompts to completion (reference loop)."""
        rids = [self.submit(p, max_new=max_new, sampling=sampling, admit=False)
                for p in prompts]
        per_req = max_new if max_new is not None else self.scfg.max_new
        budget = per_req * len(prompts) + len(prompts) + 1
        for _ in range(budget):
            if not (self.active.any() or self.scheduler.n_queued):
                break
            self.step()
        assert not (self.active.any() or self.scheduler.n_queued), \
            "generate() exceeded its step budget"
        return {r: list(self.scheduler.requests[r].tokens) for r in rids}

    # ---- introspection --------------------------------------------------
    @property
    def outputs(self) -> dict[int, list[int]]:
        return {r.rid: r.tokens for r in self.scheduler.requests.values()
                if r.tokens}

    def peek_logits(self) -> np.ndarray:
        """(n_slots, V) next-token logits for the current state, without
        advancing it — used by the packed-vs-float tolerance tests."""
        logits, _, _ = T.forward(
            self.params, self.cfg, jnp.asarray(self.last_token)[:, None],
            positions=jnp.asarray(self.positions)[:, None], cache=self.cache,
        )
        return np.asarray(logits[:, -1].astype(jnp.float32))

    def stats(self) -> dict:
        return self.scheduler.stats()
