"""Slot-based continuous-batching serving engine.

A fixed pool of ``n_slots`` sequences shares one stacked decode cache; the
scheduler admits queued requests into free slots, finished sequences free
them.  The engine has exactly three jitted programs, all with static
shapes, so steady-state serving never retraces:

* **batched chunked prefill** — admitted prompts are padded onto a shared
  ``(n_slots, prefill_chunk)`` grid and every chunk is ONE ``T.forward``
  call.  A prompt of length L costs ``ceil(L / chunk)`` forward calls
  instead of L (the seed engine scanned one token at a time *and* retraced
  per prompt length).  Rows not being prefilled are masked out of the cache
  merge, so admission can overlap slots that are mid-decode.
* **decode step** — advances every active slot one token per call (the
  standard TPU serving shape), with per-slot positions so slots sit at
  different depths.
* **sampling** — temperature/top-k/top-p with per-slot PRNG keys
  (``serving.sampling``), one batched draw for prefill and decode alike.

With ``ServeConfig.quant_mode = "int4_packed"`` the engine calls
``quantize_for_serving`` once at build time: every large matmul weight is
stored as packed int4 nibbles and ``decode_step`` runs the paper's packed
matmul kernel straight off the stored nibbles — the serving-side payoff of
DSP-packing (decode is weight-bandwidth-bound).  ``int8``/``dsp_packed``
select the corresponding per-call arithmetic paths.

``quant_mode = "dsp_tuned"`` goes further: the ``repro.tuning`` planner
enumerates every legal packing plan for ``plan_bits`` — including
multi-DSP *column-packed* plans (``n_columns > 1``), which spread one dot
product across several packed int32 words and are the only legal plans for
``plan_bits=(8, 8)`` — scores each by simulated error, and picks per layer
the fastest plan whose MAE fits ``error_budget``; weights are quantized
once onto each layer's plan and decode runs per-layer pair-packed
arithmetic.  The chosen table is exposed as ``engine.plan_table`` (path →
``tuning.PlanReport``).

``quant_mode = "dsp_mixed"`` (or ``plan_bits="auto"``) adds the width axis
to that search: a sensitivity pass (``tuning.mixed``) measures, per
packable weight path, the logit damage of quantizing that layer alone at
each candidate ``(a_bits, w_bits)`` on seeded calibration activations,
and a greedy allocator assigns each layer its own width pair — narrow
widths (more packed multiplications per int32 word, cheaper plans) for
tolerant layers, wide plans for sensitive ones — under the model-level
``mixed_budget``.  The allocation is exposed as
``engine.mixed_allocation`` (a ``tuning.MixedAllocation``).

Termination goes through a single code path (``_finish_slot``): EOS,
per-request ``max_new`` and the cache-capacity bound all free the slot,
record the finish reason and report the rid to the caller.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.packed_params import (
    SERVING_MODES,
    fuse_projection_weights,
    quantize_for_serving,
)
from ..models import transformer as T
from ..models.config import ModelConfig
from .paged_cache import OutOfPages, PageAllocator
from .sampling import SamplingParams, sample_tokens, slot_key
from .scheduler import Scheduler

__all__ = ["ServeConfig", "Engine", "ContinuousEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything the engines decide at build time, in one frozen record.

    The knobs split into capacity (``n_slots`` / ``max_len`` /
    ``prefill_chunk`` / the paged-cache group), arithmetic
    (``quant_mode`` and the plan-search group — these select which packed
    representation ``core.packed_params.quantize_for_serving`` builds),
    placement (``tp`` — tensor parallelism over the serving mesh,
    DESIGN.md §4) and policy (deadlines, the governor, default sampling).
    ``__post_init__`` rejects contradictory combinations at construction
    so an engine never has to re-validate; the one mutation it performs
    is promoting ``plan_bits="auto"`` + dsp_tuned to ``dsp_mixed``
    (per-layer width allocation IS the mixed mode).
    """

    n_slots: int = 8
    max_len: int = 512
    prefill_chunk: int = 16
    max_new: int = 64          # default per-request budget (submit can override)
    eos_token: int = 1
    # weight path: native | int8 | int4_packed | dsp_packed | dsp_tuned |
    # dsp_mixed (see core.packed_params.quantize_for_serving)
    quant_mode: str = "native"
    use_kernel: bool = False   # Pallas kernels vs jnp refs (CPU tests use ref)
    # engine-build weight preprocessing for the packed decode fast path:
    # prepack builds device-resident packed operands once (words / zp rows /
    # f32-exact grids); fuse_projections concatenates same-input projections
    # so a decode step runs one GEMV where it ran several (bit-identical per
    # output column — quantization is per-channel).  "mlp" fuses up|gate,
    # "all" also fuses q|k|v.  Off by default: inside the scanned decode
    # step on CPU XLA the post-fusion splits cost more than the saved GEMV
    # dispatches (isolated layers DO win — this is a backend-specific call;
    # flip it on for TPU runs).
    prepack: bool = True
    fuse_projections: bool | str = "none"
    # dsp_tuned plan search: operand widths, MAE-per-extraction budget and
    # whether to wall-clock-autotune block sizes (off by default: the cost
    # proxy ranks identically and engine build stays fast).  plan_bits may
    # be the string "auto" instead of a width pair: widths are then chosen
    # PER LAYER by the sensitivity allocator (quant_mode "dsp_mixed" —
    # a dsp_tuned-mode config with plan_bits="auto" is promoted to it).
    plan_bits: tuple[int, int] | str = (4, 4)
    error_budget: float = 0.5
    autotune_plans: bool = False
    # dsp_mixed: the model-level error budget (total added mean logit-KL on
    # the calibration forward vs the uniform widest-candidate plan) the
    # greedy width allocator may spend, the candidate width pairs it
    # chooses from (None = tuning.mixed.DEFAULT_WIDTH_CANDIDATES), and the
    # calibration volume (tokens per sequence; seeded from ``seed``)
    mixed_budget: float = 0.05
    width_candidates: tuple[tuple[int, int], ...] | None = None
    calib_tokens: int = 32
    # paged KV cache (ContinuousEngine only; the fixed-slot Engine ignores
    # these).  page_size is the KV tokens per physical page; n_pages sizes
    # the shared pool (None = n_slots * ceil(grid / page_size) — memory
    # parity with the dense engine's per-slot windows); watermark_pages is
    # the free-page floor admission must not dip below (None = n_slots:
    # every decoding lane can grow one page before the pool runs dry)
    page_size: int = 16
    n_pages: int | None = None
    watermark_pages: int | None = None
    # persisted plan database (tuning.plandb): a Checkpointer directory the
    # build consults before running the dsp_tuned/dsp_mixed plan searches
    # and writes back to after a cold search, so restarted engines build in
    # seconds.  None = always search.  Keyed by plan_key(model, backend,
    # shapes, search settings) — anything that would change the search
    # result misses instead of serving stale plans.
    plan_db: str | None = None
    # per-request wall-clock deadline (milliseconds from submit).  A
    # request past its deadline is SHED — cancelled with finish_reason
    # "deadline" — at the next admission/step boundary instead of
    # occupying a lane; queued requests are shed without ever admitting.
    # None = no deadlines.
    deadline_ms: float | None = None
    # load-adaptive precision governor (serving.governor): hold prebuilt
    # degraded weight tiers and swap under load.  False = off; True =
    # default GovernorConfig; or a GovernorConfig instance.
    governor: Any = False
    # tensor-parallel degree: shard packed weights over the first ``tp``
    # devices' "model" mesh axis (launch.mesh.make_serving_mesh) and run
    # the shard_map'd packed arithmetic (runtime.tp_packed) — decode is
    # bit-identical to tp=1 by construction.  Plan searches and the plan-
    # DB key are tp-aware: row-partitioned layers plan against the
    # widened (post-psum) packed word.  Only the jnp reference paths are
    # shard_map'd, so tp > 1 rejects use_kernel.
    tp: int = 1
    # default sampling (submit can override per request)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self) -> None:
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.n_pages is not None and self.n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {self.n_pages}")
        if self.watermark_pages is not None and self.watermark_pages < 0:
            raise ValueError(
                f"watermark_pages must be >= 0, got {self.watermark_pages}"
            )
        if self.quant_mode not in SERVING_MODES:
            raise ValueError(
                f"quant_mode {self.quant_mode!r} not in {SERVING_MODES}"
            )
        if self.fuse_projections not in (True, False, "none", "mlp", "all"):
            raise ValueError(
                f"fuse_projections {self.fuse_projections!r} not in "
                "(True, False, 'none', 'mlp', 'all')"
            )
        if self.plan_bits == "auto":
            # "auto" means per-layer width allocation — that IS dsp_mixed
            if self.quant_mode == "dsp_tuned":
                object.__setattr__(self, "quant_mode", "dsp_mixed")
            elif self.quant_mode != "dsp_mixed":
                raise ValueError(
                    'plan_bits="auto" needs quant_mode "dsp_tuned" or '
                    f'"dsp_mixed", got {self.quant_mode!r}'
                )
        elif isinstance(self.plan_bits, str):
            raise ValueError(
                f"plan_bits {self.plan_bits!r} must be a (a_bits, w_bits) "
                'pair or "auto"'
            )
        if self.mixed_budget < 0:
            raise ValueError(
                f"mixed_budget must be >= 0, got {self.mixed_budget}"
            )
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0, got {self.deadline_ms}"
            )
        if self.governor and self.quant_mode not in ("dsp_tuned", "dsp_mixed"):
            # governor tiers are per-layer DspTunedLeaf plan tables; the
            # other modes have no plan machinery to re-tier through
            raise ValueError(
                "governor needs quant_mode dsp_tuned or dsp_mixed, got "
                f"{self.quant_mode!r}"
            )
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.tp > 1 and self.use_kernel:
            raise ValueError(
                "tp > 1 runs the shard_map'd jnp reference paths; "
                "use_kernel=True is not supported under tensor parallelism"
            )
        if self.quant_mode == "dsp_mixed" and self.autotune_plans:
            # the width allocator selects plans by cost proxy only; a
            # silent no-op here would let the flag lie about what ran
            raise ValueError(
                "autotune_plans is not supported with dsp_mixed: per-layer "
                "width allocation ranks plans by the cost proxy (use "
                "dsp_tuned for wall-clock block sweeps)"
            )


def _prepare_serving_params(cfg: ModelConfig, params, serve_cfg: ServeConfig,
                            mixed_allocation=None):
    """Engine-build weight preparation shared by ``Engine`` and
    ``ContinuousEngine``: switch the arithmetic mode, optionally fuse
    same-input projections, run the dsp_tuned/dsp_mixed plan searches and
    quantize the weights onto the chosen plans.

    Returns ``(cfg, params, plan_table, mixed_allocation, float_params,
    plan_db_stats)`` where ``float_params`` is the post-fusion float tree
    the quantized ``params`` were built from — the governor builds its
    degraded weight tiers from it so every tier's leaf paths line up with
    the primary's — and ``plan_db_stats`` records the DB consultation
    (hits/misses/stale + the key), or None when no DB was configured.

    When ``serve_cfg.plan_db`` names a plan-database directory, the
    dsp_tuned/dsp_mixed plan searches consult it first (keyed by
    ``tuning.plan_key`` over the post-fusion tree — the tree actually
    quantized) and fall back to search-and-store on a miss, so a warm
    build runs no measurement at all.  A caller-supplied
    ``mixed_allocation`` bypasses the DB in both directions: it is served
    as given and never written back (its paths may not match this key).
    """
    plan_table: dict = {}
    resolved_mixed = None
    float_params = params
    db = db_key = None
    if mixed_allocation is not None and serve_cfg.quant_mode != "dsp_mixed":
        # dropping a caller-measured allocation would silently serve
        # different plans than the caller benchmarked
        raise ValueError(
            "mixed_allocation was given but quant_mode is "
            f"{serve_cfg.quant_mode!r}; it is only served under "
            '"dsp_mixed"'
        )
    if serve_cfg.quant_mode not in ("native", "none"):
        # switch the arithmetic mode but preserve the caller's other
        # LinearSpec choices (dsp_spec correction scheme, act_bits).
        # dsp_mixed leaves route through the dsp_tuned arithmetic —
        # each DspTunedLeaf carries its own (per-layer) plan.
        linear_mode = (
            "dsp_tuned" if serve_cfg.quant_mode == "dsp_mixed"
            else serve_cfg.quant_mode
        )
        cfg = dataclasses.replace(
            cfg,
            quant=dataclasses.replace(
                cfg.quant, mode=linear_mode,
                use_kernel=serve_cfg.use_kernel,
            ),
        )
        fuse = serve_cfg.fuse_projections
        if fuse not in (False, "none"):
            # fused same-input GEMVs — bit-identical per output column
            # under per-channel quantization
            # (core.packed_params.fuse_projection_weights)
            params = fuse_projection_weights(
                params, fuse_attn=fuse in (True, "all"), fuse_mlp=True
            )
        float_params = params  # post-fusion, pre-quantization
        if (serve_cfg.plan_db
                and serve_cfg.quant_mode in ("dsp_tuned", "dsp_mixed")):
            from ..tuning.plandb import PlanDB, plan_key

            db = PlanDB(serve_cfg.plan_db)
            db_key = plan_key(cfg, serve_cfg, params)
        if serve_cfg.quant_mode == "dsp_mixed":
            if mixed_allocation is None and db is not None:
                entry = db.get(db_key)
                if entry is not None and entry.get("kind") == "mixed":
                    from ..tuning.plandb import allocation_from_json

                    mixed_allocation = allocation_from_json(
                        entry["allocation"]
                    )
            if mixed_allocation is None:
                from ..tuning.mixed import (
                    DEFAULT_WIDTH_CANDIDATES,
                    mixed_precision_plan,
                )

                # sensitivity pass + greedy width allocation on
                # calibration activations (tuning.mixed): per-layer
                # (a_bits, w_bits) under the model-level mixed_budget;
                # the per-width plan search keeps plans provably exact
                # so the only error the model sees is the quantization
                # the pass measured
                mixed_allocation = mixed_precision_plan(
                    params, cfg,
                    mixed_budget=serve_cfg.mixed_budget,
                    widths=(serve_cfg.width_candidates
                            or DEFAULT_WIDTH_CANDIDATES),
                    n_calib_tokens=serve_cfg.calib_tokens,
                    seed=serve_cfg.seed,
                    exact_first=not serve_cfg.use_kernel,
                    shard_groups=serve_cfg.tp,
                )
                if db is not None:
                    from ..tuning.plandb import allocation_to_json

                    db.put(db_key, {
                        "kind": "mixed",
                        "allocation": allocation_to_json(mixed_allocation),
                    })
            resolved_mixed = mixed_allocation
            plan_table = mixed_allocation.plans
            params = quantize_for_serving(
                params, "dsp_mixed", plans=plan_table,
                prepack=serve_cfg.prepack,
            )
        elif serve_cfg.quant_mode == "dsp_tuned":
            plan_table = None
            if db is not None:
                entry = db.get(db_key)
                if entry is not None and entry.get("kind") == "tuned":
                    from ..tuning.plandb import report_from_json

                    plan_table = {
                        p: report_from_json(r)
                        for p, r in entry["plans"].items()
                    }
            if plan_table is None:
                from ..tuning import plan_linear_layers

                a_bits, w_bits = serve_cfg.plan_bits
                plan_table = plan_linear_layers(
                    params, a_bits=a_bits, w_bits=w_bits,
                    error_budget=serve_cfg.error_budget,
                    autotune=serve_cfg.autotune_plans,
                    # non-kernel serving runs proven-exact plans through
                    # the f32-GEMM shortcut — rank those first (see
                    # rank_plans)
                    exact_first=not serve_cfg.use_kernel,
                    # row-partitioned layers plan against the widened
                    # (post-psum) packed word (see tuner.rank_plans)
                    shard_groups=serve_cfg.tp,
                )
                if db is not None:
                    from ..tuning.plandb import report_to_json

                    db.put(db_key, {
                        "kind": "tuned",
                        "plans": {p: report_to_json(r)
                                  for p, r in plan_table.items()},
                    })
            params = quantize_for_serving(
                params, "dsp_tuned", plans=plan_table,
                prepack=serve_cfg.prepack,
            )
        else:
            params = quantize_for_serving(
                params, serve_cfg.quant_mode, prepack=serve_cfg.prepack
            )
    db_stats = None if db is None else {
        "directory": db.directory, "key": db_key,
        "hits": db.n_hits, "misses": db.n_misses, "stale": db.n_stale,
    }
    return cfg, params, plan_table, resolved_mixed, float_params, db_stats


def _shard_for_tp(params, serve_cfg: ServeConfig):
    """Mesh-partition a quantized serving tree when ``serve_cfg.tp > 1``.

    Returns ``(mesh, params)`` — ``(None, params)`` untouched at tp=1.
    The wrap happens AFTER quantization (the packed operands are what
    shards) and raises the certificate-clause-citing error for a row
    sharding whose widened accumulation would overflow
    (``runtime.tp_packed.shard_params_tp``)."""
    if serve_cfg.tp <= 1:
        return None, params
    from ..launch.mesh import make_serving_mesh
    from ..runtime.tp_packed import shard_params_tp

    mesh = make_serving_mesh(serve_cfg.tp)
    return mesh, shard_params_tp(
        params, mesh, use_kernel=serve_cfg.use_kernel
    )


def _setup_governor(engine, cfg, float_params, serve_cfg) -> None:
    """Attach the load-adaptive precision governor (shared by both
    engines): build the tier ladder from the post-fusion float weights
    and hold it prequantized, ready to swap at a step boundary.

    When a plan database is configured, the tier ladders' plan tables are
    persisted under the engine's ``plan_key`` entry (``"tiers"`` record,
    fingerprinted by the governor knobs that shape them) so a warm
    governed build runs ZERO tier plan searches — the PR-9 follow-up.
    Weight payloads are never persisted; quantization always re-runs."""
    engine.governor = None
    engine.tiers = None
    engine.active_tier = 0
    if not serve_cfg.governor:
        return
    from .governor import Governor, GovernorConfig, build_tiers

    gcfg = (serve_cfg.governor
            if isinstance(serve_cfg.governor, GovernorConfig)
            else GovernorConfig())
    # consult the plan DB for persisted tier ladders; the fingerprint pins
    # every knob the tier searches read, so a changed ladder shape misses
    # instead of serving the wrong tiers
    fingerprint = {
        "narrow_bits": list(gcfg.narrow_bits),
        "emergency_tier": gcfg.emergency_tier,
        "emergency_max_mae": gcfg.emergency_max_mae,
        "use_kernel": serve_cfg.use_kernel,
    }
    db = entry = tables = None
    if serve_cfg.plan_db and engine.plan_db_stats:
        from ..tuning.plandb import PlanDB, report_from_json

        db = PlanDB(serve_cfg.plan_db)
        entry = db.get(engine.plan_db_stats["key"])
        stored = (entry or {}).get("tiers")
        if stored and stored.get("fingerprint") == fingerprint:
            tables = {
                name: {p: report_from_json(r) for p, r in tbl.items()}
                for name, tbl in stored["tables"].items()
            }
    engine.tiers = build_tiers(
        cfg, float_params, serve_cfg, engine.params, engine.plan_table, gcfg,
        tables=tables, shard_groups=serve_cfg.tp,
    )
    if db is not None and tables is None:
        # merge-write the fresh ladders next to the plan entry (never
        # clobber the "kind"/"plans" record _prepare_serving_params wrote)
        from ..tuning.plandb import report_to_json

        payload = dict(entry or {})
        payload["tiers"] = {
            "fingerprint": fingerprint,
            "tables": {
                t.name: {p: report_to_json(r)
                         for p, r in t.plan_table.items()}
                for t in engine.tiers if t.name != "primary"
            },
        }
        db.put(engine.plan_db_stats["key"], payload)
    if getattr(engine, "mesh", None) is not None:
        # non-primary tiers were quantized single-device: partition them
        # onto the engine's mesh so a swap stays a pointer repoint
        from ..runtime.tp_packed import shard_params_tp

        engine.tiers = tuple(
            t if t.params is engine.params else dataclasses.replace(
                t, params=shard_params_tp(
                    t.params, engine.mesh, use_kernel=serve_cfg.use_kernel
                )
            )
            for t in engine.tiers
        )
    engine.governor = Governor(gcfg, len(engine.tiers))


class Engine:
    """Fixed-slot batched serving engine (DESIGN.md §3).

    Each admitted request owns one of ``n_slots`` lanes and that lane's
    dense cache window for its whole lifetime; capacity is a slot count,
    nothing is paged or preempted.  The request lifecycle:

    * :meth:`submit` queues a prompt (returns its rid; ``admit=True``
      pulls it into a free slot immediately);
    * :meth:`step` advances the whole batch one phase — shed expired
      deadlines, let the governor re-tier, admit into free slots, then
      either prefill one chunk (while any slot is still prefilling) or
      decode one token per active slot — and returns the rids finished
      this step;
    * finished tokens are read back via :attr:`outputs` /
      :meth:`drain_stream`, counters via :meth:`stats`;
    * :meth:`cancel` aborts a queued or running request with a
      ``CANCEL_REASONS`` finish reason (its slot frees at the next step
      boundary); :meth:`generate` wraps the loop for batch callers.

    The quantization mode never changes this surface: every
    ``quant_mode`` (and every tensor-parallel degree — the weights are
    sharded at build by ``runtime.tp_packed``) serves bit-identical
    tokens through the same step loop, which is what lets the
    conformance suites drive all modes through one engine API.
    """

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 mixed_allocation=None):
        """``mixed_allocation`` (a ``tuning.MixedAllocation``) skips the
        dsp_mixed engine-build sensitivity pass and serves the given
        per-layer plan table instead — for callers that already measured
        (the serving benchmark probes budgets before building).  Its paths
        must match this engine's param tree (same fusion settings)."""
        (cfg, params, self.plan_table, self.mixed_allocation, float_params,
         self.plan_db_stats) = _prepare_serving_params(
            cfg, params, serve_cfg, mixed_allocation
        )
        self.mesh, params = _shard_for_tp(params, serve_cfg)
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        _setup_governor(self, cfg, float_params, serve_cfg)
        b = serve_cfg.n_slots
        # Chunked prefill needs contiguous cache writes, and a ring-buffer
        # (sliding-window) cache only supports single-position writes — a
        # chunk landing in the ring would overwrite slots that earlier
        # in-chunk queries still need — so sliding windows keep chunk=1.
        # Recurrent families (ssm/hybrid) prefill in full chunks: the
        # forward's ``valid`` mask advances each row's state by exactly its
        # own prompt tokens, and the mixers' masked scan re-applies the
        # single-token chunk math so a chunk of C tokens is bit-identical
        # to C single-token calls (the invariant — see ``models.ssm``).
        self._chunk = 1 if cfg.sliding_window else max(
            1, min(serve_cfg.prefill_chunk, serve_cfg.max_len)
        )
        # the prefill grid is padded to whole chunks, so allocate the cache
        # on the same grid — otherwise the last chunk's writes would clamp
        # at max_len and shift K/V backwards over earlier positions
        window = -(-serve_cfg.max_len // self._chunk) * self._chunk
        self.cache = T.init_cache(cfg, b, window)
        # per-leaf batch axis: attention KV leaves carry the slot axis at 1,
        # stacked recurrent state (mlstm/mamba) at 2 — locate it by shape
        # difference between a b-slot and a (b+1)-slot cache
        s_b = jax.eval_shape(lambda: T.init_cache(cfg, b, window))
        s_b1 = jax.eval_shape(lambda: T.init_cache(cfg, b + 1, window))
        self._batch_axes = jax.tree.map(
            lambda x, y: next(
                i for i, (p, q) in enumerate(zip(x.shape, y.shape)) if p != q
            ),
            s_b, s_b1,
        )
        self.positions = np.zeros(b, np.int32)
        self.active = np.zeros(b, bool)
        self.last_token = np.zeros(b, np.int32)
        self._slot_rid = np.full(b, -1, np.int64)
        # per-slot sampling state (set at admission from the request)
        self._temperature = np.zeros(b, np.float32)
        self._top_k = np.zeros(b, np.int32)
        self._top_p = np.ones(b, np.float32)
        self._keys = np.zeros((b, 2), np.uint32)
        self._base_key = jax.random.PRNGKey(serve_cfg.seed)
        self.scheduler = Scheduler()
        self._stream: deque[tuple[int, int]] = deque()
        self._sample = jax.jit(sample_tokens)
        # Device-resident decode state: steady-state decode advances tokens/
        # positions ON DEVICE and only syncs the sampled token back, so a
        # step does ONE host->device transfer worth of dispatch instead of
        # seven (~0.5 ms/step of device_put on CPU).  The numpy arrays above
        # stay authoritative for scheduling logic; ``_dev_dirty`` marks host
        # -side mutations (admission, finishes) that must be re-pushed.
        self._dev_state = None
        self._dev_dirty = True

    # ---- jitted steps ---------------------------------------------------
    @staticmethod
    def _row_select(mask, leaf, axis):
        """Broadcast a (n_slots,) bool mask against ``leaf`` along its
        batch ``axis``."""
        shape = [1] * leaf.ndim
        shape[axis] = mask.shape[0]
        return mask.reshape(shape)

    @partial(jax.jit, static_argnums=(0,))
    def _reset_slots(self, cache, row_mask):
        """Zero the cache state of the slots in ``row_mask`` — a freshly
        admitted request must not continue from the previous occupant's
        recurrent state or stale KV."""
        return jax.tree.map(
            lambda leaf, ax: jnp.where(
                self._row_select(row_mask, leaf, ax),
                jnp.zeros((), leaf.dtype), leaf,
            ),
            cache, self._batch_axes,
        )

    @partial(jax.jit, static_argnums=(0,))
    def _prefill_chunk(self, params, cache, tokens, base, row_mask, last_idx,
                       last_hidden):
        """One chunk of batched prefill.

        ``tokens``: (n_slots, C) — rows selected by ``row_mask`` carry
        prompt tokens for positions ``[base, base + C)``; other rows are
        ignored (their cache updates are masked out of the merge).
        Collects each admitted row's last-prompt-token *hidden state* into
        ``last_hidden`` when that position falls inside this chunk; the
        lm_head runs once on the gathered rows (``_lm_head``), not on every
        position of every chunk.
        """
        b, c = tokens.shape
        positions = jnp.broadcast_to(base + jnp.arange(c)[None], (b, c))
        # per-row prefix mask: recurrent state advances only over each
        # row's real prompt tokens; MoE capacity ignores everything else
        valid = row_mask[:, None] & (positions <= last_idx[:, None])
        hidden, new_cache, _ = T.forward(
            params, self.cfg, tokens, positions=positions, cache=cache,
            return_hidden=True, valid=valid,
        )
        cache = jax.tree.map(
            lambda old, new, ax: jnp.where(
                self._row_select(row_mask, old, ax), new, old
            ),
            cache, new_cache, self._batch_axes,
        )
        idx = jnp.clip(last_idx - base, 0, c - 1)
        row_hidden = jnp.take_along_axis(
            hidden, idx[:, None, None], axis=1
        )[:, 0]
        in_chunk = row_mask & (last_idx >= base) & (last_idx < base + c)
        last_hidden = jnp.where(
            in_chunk[:, None], row_hidden.astype(last_hidden.dtype), last_hidden
        )
        return cache, last_hidden

    @partial(jax.jit, static_argnums=(0,))
    def _lm_head(self, params, hidden):
        """(n_slots, d) hidden → (n_slots, V) f32 logits (mirrors
        ``T.forward``'s head)."""
        if self.cfg.tie_embeddings:
            return hidden.astype(jnp.float32) @ params["embed"]["w"].T.astype(
                jnp.float32
            )
        from ..core.packed_linear import apply_linear

        return apply_linear(
            params["lm_head"], hidden, self.cfg.quant
        ).astype(jnp.float32)

    def _push_state(self) -> None:
        """Host → device refresh of the decode state (admission/finish)."""
        self._dev_state = jax.device_put({
            "tokens": self.last_token,
            "positions": self.positions,
            "active": self.active,
            "keys": self._keys,
            "temperature": self._temperature,
            "top_k": self._top_k,
            "top_p": self._top_p,
        })
        self._dev_dirty = False

    @partial(jax.jit, static_argnums=(0,))
    def _decode_step(self, params, cache, state):
        """One decode step off the device-resident state; tokens/positions
        advance on device (active rows only — mirroring the host loop), so
        steady-state decode does no host→device transfers at all."""
        tokens, positions = state["tokens"], state["positions"]
        active = state["active"]
        # valid=active: inactive rows neither advance recurrent state nor
        # compete for MoE expert capacity
        logits, new_cache, _ = T.forward(
            params, self.cfg, tokens[:, None], positions=positions[:, None],
            cache=cache, valid=active[:, None],
        )
        nxt = sample_tokens(
            logits[:, -1], state["keys"], positions, state["temperature"],
            state["top_k"], state["top_p"],
        )
        new_state = dict(
            state,
            tokens=jnp.where(active, nxt, tokens),
            positions=positions + active.astype(positions.dtype),
        )
        return new_cache, new_state, nxt

    # ---- request lifecycle ----------------------------------------------
    def submit(self, prompt: list[int], max_new: int | None = None,
               sampling: SamplingParams | None = None,
               admit: bool = True, deadline_ms: float | None = None) -> int:
        """Enqueue a request; it is admitted as soon as a slot frees up.

        ``admit=False`` defers admission to the next ``step()`` so that a
        burst of submissions shares one batched prefill pass.
        ``deadline_ms`` overrides the engine-wide ``ServeConfig.deadline_ms``
        for this request (wall-clock budget from submission; a request
        still unfinished past it is shed with finish_reason "deadline").
        Returns the request id (outputs appear in ``outputs[rid]``).
        """
        # exact capacity bound: the cache holds max_len token positions (its
        # chunk-padded window is >= max_len), a prompt of exactly max_len
        # fills them all and still yields one sampled token before the
        # ``positions >= max_len`` termination fires — so only longer
        # prompts are impossible
        if len(prompt) > self.scfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} > max_len ({self.scfg.max_len})"
            )
        if max_new is None:
            max_new = self.scfg.max_new
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if sampling is None:
            sampling = SamplingParams(
                self.scfg.temperature, self.scfg.top_k, self.scfg.top_p
            )
        if deadline_ms is None:
            deadline_ms = self.scfg.deadline_ms
        rid = self.scheduler.submit(
            prompt, max_new, sampling,
            deadline_s=None if deadline_ms is None else deadline_ms / 1e3,
        )
        if admit:
            self._admit()
        return rid

    def _admit(self) -> list[int]:
        """Move queued requests into free slots: batched chunked prefill +
        first-token sample.  Returns rids finished during admission (a
        first token can already hit EOS or a 1-token budget)."""
        free = np.flatnonzero(~self.active)
        admitted = self.scheduler.admit(len(free))
        if not admitted:
            return []
        t0 = time.monotonic()
        b, c = self.scfg.n_slots, self._chunk
        lmax = max(len(r.prompt) for r in admitted)
        n_chunks = -(-lmax // c)
        tokens = np.zeros((b, n_chunks * c), np.int32)
        row_mask = np.zeros(b, bool)
        last_idx = np.zeros(b, np.int32)
        for slot, req in zip(free, admitted):
            ln = len(req.prompt)
            tokens[slot, :ln] = req.prompt
            row_mask[slot] = True
            last_idx[slot] = ln - 1
            self.positions[slot] = ln
            self.active[slot] = True
            self._slot_rid[slot] = req.rid
            self._temperature[slot] = req.sampling.temperature
            self._top_k[slot] = req.sampling.top_k
            self._top_p[slot] = req.sampling.top_p
            self._keys[slot] = np.asarray(slot_key(self._base_key, req.rid))

        cache = self._reset_slots(self.cache, jnp.asarray(row_mask))
        last_hidden = jnp.zeros((b, self.cfg.d_model), T._dtype(self.cfg))
        last_idx_j = jnp.asarray(last_idx)
        for ci in range(n_chunks):
            base = ci * c
            # rows whose prompt is already fully written skip later chunks
            mask_c = jnp.asarray(row_mask & (last_idx >= base))
            cache, last_hidden = self._prefill_chunk(
                self.params, cache,
                jnp.asarray(tokens[:, base:base + c]), jnp.int32(base),
                mask_c, last_idx_j, last_hidden,
            )
            # TTFT is per request: stamp each request when ITS last chunk
            # lands, not when the whole mixed batch drains — otherwise a
            # 4-token prompt admitted next to a 500-token one is charged
            # the long prompt's chunk time.  The sync makes the stamp
            # honest (dispatch alone would timestamp unfinished work).
            own_done = [r for r in admitted if (len(r.prompt) - 1) // c == ci]
            if own_done:
                jax.block_until_ready(cache)
                self.scheduler.note_prefill_done(own_done)
        self.cache = cache

        first = np.asarray(self._sample(
            self._lm_head(self.params, last_hidden),
            jnp.asarray(self._keys), last_idx_j,
            jnp.asarray(self._temperature), jnp.asarray(self._top_k),
            jnp.asarray(self._top_p),
        ))
        n_prompt_tokens = sum(len(r.prompt) for r in admitted)
        self.scheduler.note_prefill(n_prompt_tokens, time.monotonic() - t0)
        finished = []
        for slot, req in zip(free, admitted):
            tok = int(first[slot])
            req.tokens.append(tok)
            self._stream.append((req.rid, tok))
            self.last_token[slot] = tok
            rid = self._maybe_finish(slot, tok)
            if rid is not None:
                finished.append(rid)
        self._dev_dirty = True  # admission rewrote slot state on the host
        return finished

    def _maybe_finish(self, slot: int, tok: int) -> int | None:
        """Single termination path: EOS, per-request budget and cache
        capacity all land here."""
        req = self.scheduler.requests[int(self._slot_rid[slot])]
        if tok == self.scfg.eos_token:
            return self._finish_slot(slot, "eos")
        if len(req.tokens) >= req.max_new:
            return self._finish_slot(slot, "length")
        # positions[slot] is the next cache write index; decode at position
        # max_len or beyond would write outside the max_len contract, so
        # the last admissible decode reads position max_len - 1
        if self.positions[slot] >= self.scfg.max_len:
            return self._finish_slot(slot, "length")
        return None

    def _finish_slot(self, slot: int, reason: str) -> int:
        rid = int(self._slot_rid[slot])
        self.active[slot] = False
        self._slot_rid[slot] = -1
        self.scheduler.finish(rid, reason)
        return rid

    def _release_rid(self, rid: int) -> None:
        """Free the slot of a cancelled *running* request (scheduler
        accounting already done by ``Scheduler.cancel``)."""
        for slot in np.flatnonzero(self._slot_rid == rid):
            self.active[slot] = False
            self._slot_rid[slot] = -1
            self._dev_dirty = True

    def cancel(self, rid: int, reason: str = "cancelled") -> None:
        """Abort an unfinished request immediately: a queued rid is
        dequeued without admission, a running rid's slot frees for the
        next admission.  Emitted tokens stay in ``outputs[rid]``."""
        if not self.scheduler.cancel(rid, reason):
            self._release_rid(rid)

    def _shed_expired(self) -> list[int]:
        """Cancel every deadline-expired request (finish_reason
        "deadline") — queued ones never occupy a slot, running ones free
        theirs at this step boundary."""
        shed = []
        for rid in self.scheduler.expired():
            if not self.scheduler.cancel(rid, "deadline"):
                self._release_rid(rid)
            shed.append(rid)
        return shed

    def set_tier(self, tier: int) -> None:
        """Swap the active precision tier at a step boundary.  Weights and
        plan table repoint; KV cache, positions and sampling state are
        untouched — the jitted steps specialize per plan table, so the
        next step simply runs the other arithmetic."""
        if self.tiers is None:
            raise RuntimeError(
                "engine was built without a governor (ServeConfig.governor)"
            )
        if not 0 <= tier < len(self.tiers):
            raise ValueError(
                f"tier {tier} out of range [0, {len(self.tiers)})"
            )
        if tier == self.active_tier:
            return
        t = self.tiers[tier]
        self.params = t.params
        self.plan_table = t.plan_table
        self.active_tier = tier

    def _govern(self, slow_step_ms: float | None = None) -> None:
        if self.governor is None:
            return
        target = self.governor.observe(
            self.scheduler.n_queued, slow_step_ms=slow_step_ms
        )
        if target != self.active_tier:
            self.set_tier(target)

    def step(self) -> list[int]:
        """Shed expired requests, let the governor re-tier, admit what
        fits, then advance every active slot one token.  Returns the rids
        that finished this step."""
        self._shed_expired()
        self._govern()
        finished = self._admit()
        if not self.active.any():
            return finished
        t0 = time.monotonic()
        if self._dev_dirty:
            self._push_state()
        self.cache, self._dev_state, nxt = self._decode_step(
            self.params, self.cache, self._dev_state
        )
        nxt = np.asarray(nxt)
        active_slots = np.flatnonzero(self.active)
        self.scheduler.note_decode(len(active_slots), time.monotonic() - t0)
        n_finished = len(finished)
        for slot in active_slots:
            # numpy mirrors advance exactly like the device state did
            self.positions[slot] += 1
            tok = int(nxt[slot])
            rid_s = int(self._slot_rid[slot])
            self.scheduler.requests[rid_s].tokens.append(tok)
            self._stream.append((rid_s, tok))
            self.last_token[slot] = tok
            rid = self._maybe_finish(slot, tok)
            if rid is not None:
                finished.append(rid)
        if len(finished) > n_finished:
            self._dev_dirty = True  # freed slots changed the active mask
        return finished

    def generate(self, prompts: list[list[int]], max_new: int | None = None,
                 sampling: SamplingParams | None = None) -> dict[int, list[int]]:
        """Drive a batch of prompts to completion (reference loop)."""
        rids = [self.submit(p, max_new=max_new, sampling=sampling, admit=False)
                for p in prompts]
        per_req = max_new if max_new is not None else self.scfg.max_new
        budget = per_req * len(prompts) + len(prompts) + 1
        for _ in range(budget):
            if not (self.active.any() or self.scheduler.n_queued):
                break
            self.step()
        assert not (self.active.any() or self.scheduler.n_queued), \
            "generate() exceeded its step budget"
        return {r: list(self.scheduler.requests[r].tokens) for r in rids}

    # ---- introspection --------------------------------------------------
    def drain_stream(self) -> list[tuple[int, int]]:
        """Pop every ``(rid, token)`` emitted since the last drain, in
        emission order — the streaming-output hook for callers that relay
        tokens as they land instead of waiting for the request to finish."""
        out = list(self._stream)
        self._stream.clear()
        return out

    @property
    def outputs(self) -> dict[int, list[int]]:
        """rid -> tokens emitted so far, for every request that produced
        any (finished or not); cancelled requests keep what they emitted
        before the cancel."""
        return {r.rid: r.tokens for r in self.scheduler.requests.values()
                if r.tokens}

    def peek_logits(self) -> np.ndarray:
        """(n_slots, V) next-token logits for the current state, without
        advancing it — used by the packed-vs-float tolerance tests."""
        logits, _, _ = T.forward(
            self.params, self.cfg, jnp.asarray(self.last_token)[:, None],
            positions=jnp.asarray(self.positions)[:, None], cache=self.cache,
        )
        return np.asarray(logits[:, -1].astype(jnp.float32))

    def stats(self) -> dict:
        """Scheduler counters (queue depth, per-phase tok/s, TTFT/latency
        percentiles) plus, when attached, the governor's swap history and
        active tier name and the plan database's hit/miss counts."""
        s = self.scheduler.stats()
        if self.governor is not None:
            s["governor"] = dict(
                self.governor.stats(),
                tier_name=self.tiers[self.active_tier].name,
            )
        if self.plan_db_stats is not None:
            s["plan_db"] = dict(self.plan_db_stats)
        return s


class ContinuousEngine:
    """Continuous-batching engine over a paged KV cache.

    Where ``Engine`` pins a request to a slot-sized dense cache window for
    its whole lifetime, this engine decouples *lanes* (rows of the batched
    forward, ``n_slots`` of them) from *memory* (a shared pool of
    ``n_pages`` fixed-size KV pages, ``serving.paged_cache``).  The three
    consequences the traffic bench measures:

    * **continuous admission** — a request is admitted the moment a lane
      AND its pages are free; it prefills one chunk per engine step
      alongside the lanes that are already decoding, and joins the decode
      batch the step after its own last chunk lands.  Short requests no
      longer queue behind a long request that is merely *decoding*.
    * **memory by need, not by worst case** — a request holds
      ``ceil(len/page_size)`` pages for its actual length, growing one
      page per ``page_size`` decode steps; admission is gated by a
      free-page ``watermark`` instead of a slot count.  When decode growth
      still runs dry the youngest request is preempted (pages freed,
      requeued at the *front*); the (rid, position)-keyed sampler makes
      the resume bit-identical to the uninterrupted stream.
    * **prefix sharing** — ``register_shared_prefix`` marks a common
      system prompt; its pages are prefilled once and adopted by every
      later request that starts with it (refcounted, copy-on-write when a
      write lands in a shared page).

    Token-identity contract: for the same single-request workload this
    engine emits exactly the tokens ``Engine`` emits, in every quant mode
    — the paged attention branch masks to the same valid positions and
    the sampler draws from the same (rid, position) streams.

    Every registry family serves here.  Recurrent state (ssm / hybrid
    mamba) is O(1) per lane, so it lives as per-lane arrays beside the KV
    pools (``init_paged_cache(batch=...)``); the forward's ``valid`` mask
    keeps each lane's state advancing only over its own real tokens, and
    because the mixers' masked scan is bit-identical to single-token calls
    (``models.ssm``), chunked prefill — and the re-prefill that resumes a
    preempted request — reproduce the uninterrupted state exactly.
    Sliding-window KV pages the ring buffer (chunk-1 prefill, as in
    ``Engine``); a pure-ssm model needs no pages at all.
    """

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 mixed_allocation=None):
        (cfg, params, self.plan_table, self.mixed_allocation, float_params,
         self.plan_db_stats) = _prepare_serving_params(
            cfg, params, serve_cfg, mixed_allocation
        )
        self.mesh, params = _shard_for_tp(params, serve_cfg)
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        _setup_governor(self, cfg, float_params, serve_cfg)
        # per-step decode wall times feed the governor's slow-step signal
        # (rolling median over the retained window); recorded whether or
        # not a governor is attached — stats() surfaces the median either
        # way (runtime.fault_tolerance.StragglerDetector, host id 0)
        from ..runtime.fault_tolerance import StragglerDetector

        self.straggler = StragglerDetector(
            window=(self.governor.config.window
                    if self.governor is not None else 16)
        )
        b = serve_cfg.n_slots
        # sliding windows keep chunk-1 prefill (ring writes are single-
        # position); recurrent families chunk via the ``valid`` mask
        self._chunk = 1 if cfg.sliding_window else max(
            1, min(serve_cfg.prefill_chunk, serve_cfg.max_len)
        )
        # the per-lane logical window is the chunk-padded grid, exactly like
        # the dense engine's cache window — identical attention windows are
        # what make the two engines token-identical
        grid = -(-serve_cfg.max_len // self._chunk) * self._chunk
        ps = serve_cfg.page_size
        if cfg.family == "ssm":
            # pure recurrent state: O(1) per lane, nothing to page
            self._max_blocks = 0
        elif cfg.sliding_window:
            # ring pages: a lane never addresses more than the window
            self._max_blocks = -(-min(grid, cfg.sliding_window) // ps)
        else:
            self._max_blocks = -(-grid // ps)
        n_pages = (serve_cfg.n_pages if serve_cfg.n_pages is not None
                   else max(1, b * self._max_blocks))
        if n_pages < self._max_blocks:
            raise ValueError(
                f"n_pages={n_pages} cannot hold one max-length request "
                f"({self._max_blocks} blocks of {ps})"
            )
        wm = (serve_cfg.watermark_pages
              if serve_cfg.watermark_pages is not None else b)
        self.alloc = PageAllocator(n_pages, ps, min(wm, n_pages - 1))
        self.cache = T.init_paged_cache(cfg, n_pages, ps, batch=b)
        # Per-leaf lane axis: recurrent-state leaves carry the lane (batch)
        # axis, page-pool leaves don't — locate it by shape difference
        # between a b-lane and a (b+1)-lane cache, sentinel -1 for pool
        # leaves.  Drives admission state resets and restricts CoW page
        # copies to pool leaves.
        s_b = jax.eval_shape(
            lambda: T.init_paged_cache(cfg, n_pages, ps, batch=b)
        )
        s_b1 = jax.eval_shape(
            lambda: T.init_paged_cache(cfg, n_pages, ps, batch=b + 1)
        )
        self._lane_axes = jax.tree.map(
            lambda x, y: next(
                (i for i, (p, q) in enumerate(zip(x.shape, y.shape))
                 if p != q),
                -1,
            ),
            s_b, s_b1,
        )
        self._has_state = cfg.family in ("ssm", "hybrid")
        # host lane state (authoritative for scheduling; mirrored on device
        # for the decode loop, _push_state)
        self.positions = np.zeros(b, np.int32)   # next cache write index
        self.active = np.zeros(b, bool)          # lane holds a request
        self._prefilling = np.zeros(b, bool)     # ...still prefilling it
        self._n_seq = np.zeros(b, np.int32)      # tokens to prefill
        self._last_idx = np.zeros(b, np.int32)   # n_seq - 1
        self.last_token = np.zeros(b, np.int32)
        self._lane_rid = np.full(b, -1, np.int64)
        self._seq: dict[int, np.ndarray] = {}    # lane -> prefill tokens
        self._temperature = np.zeros(b, np.float32)
        self._top_k = np.zeros(b, np.int32)
        self._top_p = np.ones(b, np.float32)
        self._keys = np.zeros((b, 2), np.uint32)
        self._base_key = jax.random.PRNGKey(serve_cfg.seed)
        self._last_hidden = jnp.zeros((b, cfg.d_model), T._dtype(cfg))
        self.scheduler = Scheduler()
        self._stream: deque[tuple[int, int]] = deque()
        self._sample = jax.jit(sample_tokens)
        self._dev_state = None
        self._dev_dirty = True
        self._pushed_mask = None  # decode mask the device state was built for
        # shared system-prompt prefix (register_shared_prefix)
        self._shared_prefix: list[int] | None = None
        self._shared_key: tuple | None = None
        self._shared_ready = False
        self._shared_pending_rid = -1

    # ---- jitted steps ---------------------------------------------------
    @partial(jax.jit, static_argnums=(0,))
    def _reset_lanes(self, cache, lane_mask):
        """Zero the recurrent-state leaves of the lanes in ``lane_mask`` —
        a freshly admitted request must not continue from the previous
        occupant's state.  Pool leaves (lane axis -1) are untouched: page
        recycling already isolates them."""
        return jax.tree.map(
            lambda leaf, ax: leaf if ax < 0 else jnp.where(
                Engine._row_select(lane_mask, leaf, ax),
                jnp.zeros((), leaf.dtype), leaf,
            ),
            cache, self._lane_axes,
        )

    @partial(jax.jit, static_argnums=(0,))
    def _prefill_chunk(self, params, cache, tokens, base, page_table,
                       row_mask, last_idx, last_hidden):
        """One prefill chunk for every prefilling lane at once; lanes sit
        at *different* depths (per-row ``base``).  Rows outside
        ``row_mask`` get the invalid page sentinel, so their KV writes
        drop, and ``valid`` is False across their whole chunk, so the
        masked recurrent scan returns their state bit-unchanged — no cache
        merge pass needed (unlike the dense engine)."""
        b, c = tokens.shape
        positions = base[:, None] + jnp.arange(c)[None]
        pt_eff = jnp.where(row_mask[:, None], page_table, self.alloc.invalid)
        valid = row_mask[:, None] & (positions <= last_idx[:, None])
        hidden, new_cache, _ = T.forward(
            params, self.cfg, tokens, positions=positions, cache=cache,
            return_hidden=True, page_table=pt_eff, valid=valid,
        )
        idx = jnp.clip(last_idx - base, 0, c - 1)
        row_hidden = jnp.take_along_axis(
            hidden, idx[:, None, None], axis=1
        )[:, 0]
        in_chunk = row_mask & (last_idx >= base) & (last_idx < base + c)
        last_hidden = jnp.where(
            in_chunk[:, None], row_hidden.astype(last_hidden.dtype),
            last_hidden,
        )
        return new_cache, last_hidden

    @partial(jax.jit, static_argnums=(0,))
    def _lm_head(self, params, hidden):
        if self.cfg.tie_embeddings:
            return hidden.astype(jnp.float32) @ params["embed"]["w"].T.astype(
                jnp.float32
            )
        from ..core.packed_linear import apply_linear

        return apply_linear(
            params["lm_head"], hidden, self.cfg.quant
        ).astype(jnp.float32)

    @partial(jax.jit, static_argnums=(0,))
    def _decode_step(self, params, cache, state):
        """Advance every decoding lane one token (device-resident state,
        as in ``Engine``); non-decoding lanes get the invalid page
        sentinel so their KV writes drop, and ``valid=active`` so their
        recurrent state stays bit-unchanged (a still-prefilling lane must
        not advance on a junk decode token)."""
        tokens, positions = state["tokens"], state["positions"]
        active = state["active"]
        pt_eff = jnp.where(
            active[:, None], state["page_table"], self.alloc.invalid
        )
        logits, new_cache, _ = T.forward(
            params, self.cfg, tokens[:, None], positions=positions[:, None],
            cache=cache, page_table=pt_eff, valid=active[:, None],
        )
        nxt = sample_tokens(
            logits[:, -1], state["keys"], positions, state["temperature"],
            state["top_k"], state["top_p"],
        )
        new_state = dict(
            state,
            tokens=jnp.where(active, nxt, tokens),
            positions=positions + active.astype(positions.dtype),
        )
        return new_cache, new_state, nxt

    @partial(jax.jit, static_argnums=(0,))
    def _copy_page(self, cache, src, dst):
        """Copy-on-write device copy: physical page ``src`` -> ``dst``
        across every layer's K and V pool (recurrent-state leaves have no
        pages — untouched)."""
        return jax.tree.map(
            lambda leaf, ax: (
                leaf if ax >= 0 else leaf.at[:, dst].set(leaf[:, src])
            ),
            cache, self._lane_axes,
        )

    def _push_state(self, decode_mask) -> None:
        self._dev_state = jax.device_put({
            "tokens": self.last_token,
            "positions": self.positions,
            "active": decode_mask,
            "keys": self._keys,
            "temperature": self._temperature,
            "top_k": self._top_k,
            "top_p": self._top_p,
            "page_table": self.alloc.table_array(
                self._lane_rid, self._max_blocks
            ),
        })
        self._pushed_mask = np.asarray(decode_mask).copy()
        self._dev_dirty = False

    # ---- shared prefix ---------------------------------------------------
    def register_shared_prefix(self, tokens: list[int]) -> None:
        """Declare a common system prompt.  The first admitted request
        that starts with it prefills it once; every later request that
        starts with it adopts those pages (refcounted, CoW on write)
        and prefills only its own suffix."""
        # Shared prefixes are the one feature that still excludes some
        # families — each guard names the exact blocking feature.
        if self.cfg.family == "ssm":
            raise ValueError(
                f"register_shared_prefix: unsupported for {self.cfg.name!r} "
                "(family 'ssm'); blocking feature: recurrent state — the "
                "prefix's decode state is a per-lane array, not shareable "
                "KV pages"
            )
        if self.cfg.family == "hybrid":
            raise ValueError(
                f"register_shared_prefix: unsupported for {self.cfg.name!r} "
                "(family 'hybrid'); blocking feature: mamba recurrent "
                "state — shared KV pages capture only the attention "
                "layers' prefix state, so an adopting request would resume "
                "from a zero mamba state"
            )
        if self.cfg.sliding_window:
            raise ValueError(
                f"register_shared_prefix: unsupported for {self.cfg.name!r}"
                f"; blocking feature: sliding_window={self.cfg.sliding_window}"
                " — ring slots are position-ambiguous across requests "
                "(slot = pos % window), so prefix pages cannot be adopted"
            )
        if self._shared_prefix is not None:
            raise ValueError("shared prefix already registered")
        if not tokens:
            raise ValueError("empty shared prefix")
        if self.scheduler.requests:
            raise ValueError(
                "register the shared prefix before submitting requests"
            )
        blocks = self.alloc.blocks_for(len(tokens))
        if self.alloc.n_pages < self._max_blocks + blocks:
            raise ValueError(
                f"n_pages={self.alloc.n_pages} cannot pin a {blocks}-block "
                f"shared prefix and still hold one max-length request "
                f"({self._max_blocks} blocks)"
            )
        self._shared_prefix = list(tokens)
        self._shared_key = ("prefix", tuple(tokens))

    def _matches_prefix(self, prompt: list[int]) -> bool:
        sp = self._shared_prefix
        return (sp is not None and len(prompt) >= len(sp)
                and list(prompt[: len(sp)]) == sp)

    # ---- request lifecycle ----------------------------------------------
    def submit(self, prompt: list[int], max_new: int | None = None,
               sampling: SamplingParams | None = None,
               admit: bool = True, deadline_ms: float | None = None) -> int:
        """Enqueue a request (same contract as ``Engine.submit``); it is
        admitted as soon as a lane and its pages are free."""
        if len(prompt) > self.scfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} > max_len ({self.scfg.max_len})"
            )
        if max_new is None:
            max_new = self.scfg.max_new
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if sampling is None:
            sampling = SamplingParams(
                self.scfg.temperature, self.scfg.top_k, self.scfg.top_p
            )
        if deadline_ms is None:
            deadline_ms = self.scfg.deadline_ms
        rid = self.scheduler.submit(
            prompt, max_new, sampling,
            deadline_s=None if deadline_ms is None else deadline_ms / 1e3,
        )
        if admit:
            self._admit_new()
        return rid

    def _admission_plan(self, req) -> tuple[list[int], int, int, int]:
        """(seq, start, blocks_total, pages_needed) for admitting ``req``.

        ``seq`` is prompt + already-emitted tokens (a preempted request
        re-prefills its own output; the position-keyed sampler then
        resumes the identical stream).  ``start`` skips adopted shared-
        prefix tokens.  ``pages_needed`` counts fresh pages: the blocks
        beyond the adopted ones, plus one CoW page when the first written
        block is shared."""
        seq = list(req.prompt) + list(req.tokens)
        n_seq = len(seq)
        start = 0
        adopted_blocks = 0
        if self._shared_ready and self._matches_prefix(seq):
            # token-granular resume: re-prefill at least the last token so
            # there is a hidden state to sample from (prompt == prefix)
            start = min(len(self._shared_prefix), n_seq - 1)
            adopted_blocks = self.alloc.shared_blocks(self._shared_key)
        n_chunks = -(-(n_seq - start) // self._chunk)
        padded_end = start + n_chunks * self._chunk
        blocks_total = min(
            self.alloc.blocks_for(padded_end), self._max_blocks
        )
        need = max(0, blocks_total - adopted_blocks)
        if adopted_blocks and start // self.alloc.page_size < adopted_blocks:
            need += 1  # CoW of the partial shared page the prefill writes
        return seq, start, blocks_total, need

    def _admit_new(self) -> None:
        """Admit queued requests into free lanes, strictly FIFO: if the
        front request's pages would dip the free list below the watermark,
        nobody skips ahead of it.  An idle engine ignores the watermark —
        it exists to protect running lanes, and there are none."""
        while True:
            free = np.flatnonzero(~self.active)
            if len(free) == 0:
                break
            req = self.scheduler.peek()
            if req is None:
                break
            seq, start, blocks_total, need = self._admission_plan(req)
            if not (self.alloc.can_admit(need)
                    or (not self.active.any() and need <= self.alloc.n_free)):
                break
            req = self.scheduler.admit_front()
            lane = int(free[0])
            self.alloc.open_table(req.rid)
            adopting = start > 0
            if adopting:
                self.alloc.adopt_shared(self._shared_key, req.rid)
            self.alloc.grow(req.rid, blocks_total)
            # CoW every block the prefill will write into (only a shared
            # partial page ever actually copies)
            for blk in range(start // self.alloc.page_size, blocks_total):
                page, src = self.alloc.make_writable(req.rid, blk)
                if src is not None:
                    self.cache = self._copy_page(
                        self.cache, jnp.int32(src), jnp.int32(page)
                    )
            if self._has_state:
                # the new occupant must start from zero recurrent state
                lane_mask = np.zeros(self.scfg.n_slots, bool)
                lane_mask[lane] = True
                self.cache = self._reset_lanes(
                    self.cache, jnp.asarray(lane_mask)
                )
            self._lane_rid[lane] = req.rid
            self.active[lane] = True
            self._prefilling[lane] = True
            self._seq[lane] = np.asarray(seq, np.int32)
            self._n_seq[lane] = len(seq)
            self._last_idx[lane] = len(seq) - 1
            self.positions[lane] = start
            self._temperature[lane] = req.sampling.temperature
            self._top_k[lane] = req.sampling.top_k
            self._top_p[lane] = req.sampling.top_p
            self._keys[lane] = np.asarray(slot_key(self._base_key, req.rid))
            if (self._shared_prefix is not None and not self._shared_ready
                    and self._shared_pending_rid < 0
                    and self._matches_prefix(req.prompt)):
                # first matching request prefills the prefix for everyone;
                # its pages are pinned once its prefill completes
                self._shared_pending_rid = req.rid
            self._dev_dirty = True

    def _prefill_step(self) -> list[int]:
        """One chunk of prefill for every prefilling lane.  Lanes whose
        last chunk landed sample their first token, get their TTFT stamp,
        and join the decode batch next step."""
        lanes = np.flatnonzero(self._prefilling)
        if len(lanes) == 0:
            return []
        t0 = time.monotonic()
        b, c = self.scfg.n_slots, self._chunk
        tokens = np.zeros((b, c), np.int32)
        base = np.zeros(b, np.int32)
        row_mask = np.zeros(b, bool)
        n_tok = 0
        for lane in lanes:
            pos = int(self.positions[lane])
            chunk = self._seq[lane][pos:pos + c]
            tokens[lane, : len(chunk)] = chunk
            base[lane] = pos
            row_mask[lane] = True
            n_tok += len(chunk)
        self.cache, self._last_hidden = self._prefill_chunk(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(base),
            jnp.asarray(self.alloc.table_array(
                self._lane_rid, self._max_blocks
            )),
            jnp.asarray(row_mask), jnp.asarray(self._last_idx),
            self._last_hidden,
        )
        done_lanes = []
        for lane in lanes:
            if self.positions[lane] + c >= self._n_seq[lane]:
                self.positions[lane] = self._n_seq[lane]
                done_lanes.append(int(lane))
            else:
                self.positions[lane] += c
        finished: list[int] = []
        if done_lanes:
            # honest per-request TTFT: sync before stamping, and sample the
            # completed lanes' first tokens right now
            first = np.asarray(self._sample(
                self._lm_head(self.params, self._last_hidden),
                jnp.asarray(self._keys), jnp.asarray(self._last_idx),
                jnp.asarray(self._temperature), jnp.asarray(self._top_k),
                jnp.asarray(self._top_p),
            ))
            done_reqs = []
            for lane in done_lanes:
                rid = int(self._lane_rid[lane])
                req = self.scheduler.requests[rid]
                done_reqs.append(req)
                self._prefilling[lane] = False
                self._seq.pop(lane, None)
                tok = int(first[lane])
                req.tokens.append(tok)
                self._stream.append((rid, tok))
                self.last_token[lane] = tok
                if rid == self._shared_pending_rid:
                    # prefix pages now hold real KV — pin and publish them
                    self.alloc.register_shared(
                        self._shared_key, rid,
                        self.alloc.blocks_for(len(self._shared_prefix)),
                    )
                    self._shared_ready = True
                    self._shared_pending_rid = -1
                fin = self._maybe_finish(lane, tok)
                if fin is not None:
                    finished.append(fin)
            self.scheduler.note_prefill_done(done_reqs)
        self.scheduler.note_prefill(n_tok, time.monotonic() - t0)
        # no _dev_dirty here: prefill touches no decode-lane state — lanes
        # that just completed join the decode mask next step, and that
        # membership change itself forces the state push (_decode_once)
        return finished

    def _youngest_lane(self, exclude: int | None = None) -> int | None:
        """Lane holding the newest request (preemption victim)."""
        best, best_rid = None, -1
        for lane in np.flatnonzero(self.active):
            if exclude is not None and int(lane) == exclude:
                continue
            rid = int(self._lane_rid[lane])
            if rid > best_rid:
                best, best_rid = int(lane), rid
        return best

    def _preempt(self, lane: int) -> None:
        """Evict a lane: free its pages and requeue it at the FRONT of
        the queue.  Its emitted tokens are kept — re-admission re-prefills
        prompt+tokens and the (rid, position)-keyed sampler continues the
        identical stream."""
        rid = int(self._lane_rid[lane])
        if rid == self._shared_pending_rid:
            self._shared_pending_rid = -1  # its prefix pages never landed
        self.alloc.free(rid)
        self.scheduler.requeue(rid)
        self.active[lane] = False
        self._prefilling[lane] = False
        self._lane_rid[lane] = -1
        self._seq.pop(lane, None)
        self._dev_dirty = True

    def _ensure_decode_pages(self, lanes: list[int]) -> None:
        """Grow each decoding lane's table to cover its next write; on
        ``OutOfPages`` preempt the youngest active request and retry
        (oldest lanes are served first, so pressure evicts the newest)."""
        for lane in sorted(lanes, key=lambda l: int(self._lane_rid[l])):
            if not self.active[lane]:
                continue  # preempted by an earlier lane's growth
            rid = int(self._lane_rid[lane])
            # a lane never addresses more than _max_blocks blocks: the ring
            # (sliding window) wraps, and a pure-ssm lane has no pages
            needed = min(
                int(self.positions[lane]) // self.alloc.page_size + 1,
                self._max_blocks,
            )
            if needed == 0:
                continue
            while True:
                try:
                    if self.alloc.grow(rid, needed):
                        # the device page table must see the new block or
                        # this step's KV write silently drops
                        self._dev_dirty = True
                    break
                except OutOfPages:
                    victim = self._youngest_lane()
                    if victim is None or (victim == lane
                                          and self.active.sum() <= 1):
                        raise  # one lone request outgrew the pool: config
                    self._preempt(victim)
                    if victim == lane:
                        break
            if not self.active[lane]:
                continue
            if self.cfg.sliding_window:
                # the next write lands at ring slot pos % window
                wblk = (
                    int(self.positions[lane]) % self.cfg.sliding_window
                ) // self.alloc.page_size
            else:
                wblk = needed - 1
            page, src = self.alloc.make_writable(rid, wblk)
            if src is not None:
                self.cache = self._copy_page(
                    self.cache, jnp.int32(src), jnp.int32(page)
                )
                self._dev_dirty = True

    def _decode_once(self, decode_mask: np.ndarray) -> list[int]:
        """Advance the decode batch one token (lanes in ``decode_mask``
        that are still active — preemption may have evicted some)."""
        lanes = np.flatnonzero(decode_mask & self.active)
        if len(lanes) == 0:
            return []
        t0 = time.monotonic()
        self._ensure_decode_pages([int(l) for l in lanes])
        lanes = np.flatnonzero(decode_mask & self.active)
        if len(lanes) == 0:
            return []
        # push on explicit dirt OR a decode-membership change: a lane that
        # finished its prefill in a step whose push preceded it (the decode
        # batch is snapshotted before the prefill phase) would otherwise be
        # frozen out of the cached device mask and decode garbage
        mask = decode_mask & self.active
        if (self._dev_dirty or self._pushed_mask is None
                or not np.array_equal(mask, self._pushed_mask)):
            self._push_state(mask)
        self.cache, self._dev_state, nxt = self._decode_step(
            self.params, self.cache, self._dev_state
        )
        nxt = np.asarray(nxt)
        dt = time.monotonic() - t0
        self.scheduler.note_decode(len(lanes), dt)
        self.straggler.record(0, dt)
        finished = []
        for lane in lanes:
            self.positions[lane] += 1
            tok = int(nxt[lane])
            rid = int(self._lane_rid[lane])
            self.scheduler.requests[rid].tokens.append(tok)
            self._stream.append((rid, tok))
            self.last_token[lane] = tok
            fin = self._maybe_finish(int(lane), tok)
            if fin is not None:
                finished.append(fin)
        if finished:
            self._dev_dirty = True
        return finished

    def _maybe_finish(self, lane: int, tok: int) -> int | None:
        """Single termination path (EOS / budget / capacity), mirroring
        ``Engine._maybe_finish`` exactly — same bounds, same reasons."""
        rid = int(self._lane_rid[lane])
        req = self.scheduler.requests[rid]
        if tok == self.scfg.eos_token:
            return self._finish_lane(lane, "eos")
        if len(req.tokens) >= req.max_new:
            return self._finish_lane(lane, "length")
        if self.positions[lane] >= self.scfg.max_len:
            return self._finish_lane(lane, "length")
        return None

    def _finish_lane(self, lane: int, reason: str) -> int:
        rid = int(self._lane_rid[lane])
        self.active[lane] = False
        self._prefilling[lane] = False
        self._lane_rid[lane] = -1
        self._seq.pop(lane, None)
        self.alloc.free(rid)  # shared pins survive via their permanent ref
        self.scheduler.finish(rid, reason)
        self._dev_dirty = True
        return rid

    def _release_rid(self, rid: int) -> None:
        """Free the lane and pages of a cancelled *running* request
        (scheduler accounting already done by ``Scheduler.cancel``)."""
        for lane in np.flatnonzero(self._lane_rid == rid):
            self.active[lane] = False
            self._prefilling[lane] = False
            self._lane_rid[lane] = -1
            self._seq.pop(int(lane), None)
            self._dev_dirty = True
        if rid == self._shared_pending_rid:
            self._shared_pending_rid = -1  # its prefix pages never landed
        self.alloc.free(rid)

    def cancel(self, rid: int, reason: str = "cancelled") -> None:
        """Abort an unfinished request immediately: a queued rid is
        dequeued without admission, a running rid's lane and pages free
        for the next admission.  Emitted tokens stay in ``outputs``."""
        if not self.scheduler.cancel(rid, reason):
            self._release_rid(rid)

    def _shed_expired(self) -> list[int]:
        """Cancel every deadline-expired request (finish_reason
        "deadline") at this step boundary — see ``Engine._shed_expired``."""
        shed = []
        for rid in self.scheduler.expired():
            if not self.scheduler.cancel(rid, "deadline"):
                self._release_rid(rid)
            shed.append(rid)
        return shed

    def set_tier(self, tier: int) -> None:
        """Swap the active precision tier at a step boundary (same
        contract as ``Engine.set_tier``: weights and plan table repoint,
        KV pages and lane state untouched)."""
        if self.tiers is None:
            raise RuntimeError(
                "engine was built without a governor (ServeConfig.governor)"
            )
        if not 0 <= tier < len(self.tiers):
            raise ValueError(
                f"tier {tier} out of range [0, {len(self.tiers)})"
            )
        if tier == self.active_tier:
            return
        t = self.tiers[tier]
        self.params = t.params
        self.plan_table = t.plan_table
        self.active_tier = tier

    def _govern(self) -> None:
        if self.governor is None:
            return
        target = self.governor.observe(
            self.scheduler.n_queued,
            slow_step_ms=1e3 * self.straggler.rolling_median(),
        )
        if target != self.active_tier:
            self.set_tier(target)

    def step(self) -> list[int]:
        """Shed expired requests, let the governor re-tier, admit what
        fits, prefill one chunk per prefilling lane, advance the decode
        batch one token.  A lane that completed its prefill this step
        decodes from the NEXT step (the decode batch is snapshotted
        before the prefill phase).  Returns finished rids."""
        self._shed_expired()
        self._govern()
        self._admit_new()
        decode_mask = (self.active & ~self._prefilling).copy()
        finished = self._prefill_step()
        finished += self._decode_once(decode_mask)
        return finished

    def generate(self, prompts: list[list[int]], max_new: int | None = None,
                 sampling: SamplingParams | None = None) -> dict[int, list[int]]:
        """Drive a batch of prompts to completion (reference loop)."""
        rids = [self.submit(p, max_new=max_new, sampling=sampling, admit=False)
                for p in prompts]
        per_req = max_new if max_new is not None else self.scfg.max_new
        # prefill costs ceil(L/chunk) steps per request; double for
        # preemption re-prefills under page pressure
        budget = 2 * (
            per_req * len(prompts)
            + sum(-(-len(p) // self._chunk) for p in prompts)
            + len(prompts)
        ) + 8
        for _ in range(budget):
            if not (self.active.any() or self.scheduler.n_queued):
                break
            self.step()
        assert not (self.active.any() or self.scheduler.n_queued), \
            "generate() exceeded its step budget"
        return {r: list(self.scheduler.requests[r].tokens) for r in rids}

    # ---- introspection --------------------------------------------------
    def drain_stream(self) -> list[tuple[int, int]]:
        """Pop every ``(rid, token)`` emitted since the last drain, in
        emission order — the streaming-output hook."""
        out = list(self._stream)
        self._stream.clear()
        return out

    @property
    def outputs(self) -> dict[int, list[int]]:
        """rid -> tokens emitted so far (see ``Engine.outputs``);
        preempted requests keep their pre-preemption tokens — resume
        appends to the same list."""
        return {r.rid: r.tokens for r in self.scheduler.requests.values()
                if r.tokens}

    def stats(self) -> dict:
        """``Engine.stats`` plus the page-pool gauges (total/free pages,
        page size, admission watermark) and the straggler detector's
        rolling-median decode step time."""
        s = self.scheduler.stats()
        s.update(
            n_pages=self.alloc.n_pages,
            free_pages=self.alloc.n_free,
            page_size=self.alloc.page_size,
            watermark_pages=self.alloc.watermark,
            decode_median_step_s=self.straggler.rolling_median(),
        )
        if self.governor is not None:
            s["governor"] = dict(
                self.governor.stats(),
                tier_name=self.tiers[self.active_tier].name,
            )
        if self.plan_db_stats is not None:
            s["plan_db"] = dict(self.plan_db_stats)
        return s
