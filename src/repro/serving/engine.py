"""Slot-based batched serving engine (continuous-batching-lite).

A fixed pool of ``n_slots`` sequences shares one stacked decode cache; new
requests claim free slots (their prompt is prefilled into the slot),
finished sequences free them.  One jitted ``decode_step`` advances every
active slot by a token per call — the standard TPU serving shape
(decode is batch-synchronous; per-slot positions are tracked so slots can
be at different depths).

With ``quant mode`` set to one of the packed modes the weights used for
decode are the paper's packed low-precision weights — the serving-side
payoff of DSP-packing (decode is weight-bandwidth-bound).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T
from ..models.config import ModelConfig

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    eos_token: int = 1


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.cache = T.init_cache(cfg, serve_cfg.n_slots, serve_cfg.max_len)
        self.positions = np.zeros(serve_cfg.n_slots, np.int32)
        self.active = np.zeros(serve_cfg.n_slots, bool)
        self.last_token = np.zeros(serve_cfg.n_slots, np.int32)
        self.outputs: dict[int, list[int]] = {}
        self._next_rid = 0
        self._rid_of_slot: dict[int, int] = {}

    # ---- jitted steps ---------------------------------------------------
    @partial(jax.jit, static_argnums=(0,))
    def _prefill(self, params, cache, tokens, slot):
        """Prefill one prompt into ``slot`` of the batched cache."""
        cfg = self.cfg
        one_cache = jax.tree.map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1), cache
        )
        # feed tokens one position at a time to reuse the decode path
        def body(carry, tok_pos):
            cache_s, _ = carry
            tok, pos = tok_pos
            logits, new_c, _ = T.forward(
                params, cfg, tok[None, None], positions=pos[None, None], cache=cache_s
            )
            return (new_c, logits[0, -1]), None

        pos = jnp.arange(tokens.shape[0])
        (one_cache, last_logits), _ = jax.lax.scan(body, (one_cache, jnp.zeros((cfg.vocab_size,))), (tokens, pos))
        cache = jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(full, one.astype(full.dtype), slot, axis=1),
            cache,
            one_cache,
        )
        return cache, jnp.argmax(last_logits).astype(jnp.int32)

    @partial(jax.jit, static_argnums=(0,))
    def _decode(self, params, cache, tokens, positions):
        cfg = self.cfg
        logits, new_cache, _ = T.forward(
            params, cfg, tokens[:, None], positions=positions[:, None], cache=cache
        )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return new_cache, nxt

    # ---- request lifecycle ----------------------------------------------
    def submit(self, prompt: list[int]) -> int | None:
        free = np.flatnonzero(~self.active)
        if len(free) == 0:
            return None
        slot = int(free[0])
        rid = self._next_rid
        self._next_rid += 1
        toks = jnp.asarray(prompt, jnp.int32)
        self.cache, last = self._prefill(self.params, self.cache, toks, slot)
        self.positions[slot] = len(prompt)
        self.last_token[slot] = int(last)
        self.active[slot] = True
        self._rid_of_slot[slot] = rid
        self.outputs[rid] = [int(last)]
        return rid

    def step(self) -> list[int]:
        """Advance every active slot one token; returns finished rids."""
        if not self.active.any():
            return []
        self.cache, nxt = self._decode(
            self.params,
            self.cache,
            jnp.asarray(self.last_token),
            jnp.asarray(self.positions),
        )
        nxt = np.asarray(nxt)
        finished = []
        for slot in np.flatnonzero(self.active):
            self.positions[slot] += 1
            tok = int(nxt[slot])
            rid = self._rid_of_slot[slot]
            self.outputs[rid].append(tok)
            self.last_token[slot] = tok
            done = tok == self.scfg.eos_token or self.positions[slot] >= self.scfg.max_len - 1
            if done:
                self.active[slot] = False
                finished.append(rid)
        return finished

    def generate(self, prompts: list[list[int]], max_new: int = 32) -> dict[int, list[int]]:
        """Drive a full batch to completion (simple reference loop)."""
        pending = list(prompts)
        rids = []
        for _ in range(max_new * max(1, len(prompts))):
            while pending:
                rid = self.submit(pending[0])
                if rid is None:
                    break
                rids.append(rid)
                pending.pop(0)
            if not self.active.any() and not pending:
                break
            self.step()
            for slot in np.flatnonzero(self.active):
                if len(self.outputs[self._rid_of_slot[slot]]) >= max_new:
                    self.active[slot] = False
        return {r: self.outputs[r] for r in rids}
