"""Load-adaptive precision governor: graceful degradation under load.

The paper's central trade is a quality/throughput dial — exact plans vs
Overpacking (more multiplications per DSP word at a bounded, certified
MAE).  This module turns that dial into a *runtime* mechanism, following
the dynamic-reconfiguration approximate-multiplier work (switch
multiplier accuracy modes under load) and DeepBurning-MixQ's per-layer
width allocation (PAPERS.md): the engine holds two or three fully
prebuilt weight allocations — **tiers** — and a hysteresis controller
swaps the active one at a step boundary when scheduler signals say the
engine is drowning (or has recovered).

Tiers (built once at engine construction, from the same post-fusion
float weights the primary build quantized):

* ``primary`` — the allocation the engine was configured for (the
  dsp_mixed sensitivity-allocated table, or the dsp_tuned uniform table).
* ``narrow`` — every layer on the narrowest candidate's provably-exact
  plan: cheapest *certified-exact* serving point (packs the most
  multiplications per word without adding arithmetic error beyond the
  narrow quantization grid).
* ``emergency`` (optional) — every layer on an *overpacked* plan with a
  certified MAE bound: the paper's MAE 0.37→0.47 regime, more
  multiplications per DSP than any exact layout permits.  Quality is
  bounded by the plan certificate, not hoped for.

Swap mechanics ride the proven bit-identical plan-swap machinery:
``DspTunedLeaf`` weights are immutable pytrees, the KV cache is plain
arrays independent of the weight representation, and the jitted step
functions specialize per plan table (the leaves' specs are static pytree
aux data) — so ``Engine.set_tier`` just repoints ``engine.params`` and
the next step runs the other arithmetic.  Tokens sampled *before* the
swap are bit-identical to the unswapped engine's; requests admitted
*after* a swap match an engine built directly on the target tier (both
proven in ``tests/test_governor.py``).

The controller is deliberately boring: a tier is a big hammer, so
swaps need ``hold_steps`` consecutive over-threshold observations to
fire (and the counters reset on every swap, so the dwell time between
swaps is at least ``hold_steps`` — no flapping at a noisy threshold).
"""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["GovernorConfig", "Governor", "Tier", "build_tiers"]


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    # degrade when the queue holds at least this many waiting requests...
    queue_high: int = 8
    # ...and recover only when it has drained to at most this many
    # (the gap is the hysteresis band: in between, hold the current tier)
    queue_low: int = 1
    # escalate to the emergency tier (when built) at this queue depth
    emergency_queue_high: int = 24
    # optional extra degrade signals, each ignored when None: decode-step
    # rolling median (the StragglerDetector slow-step signal), request
    # arrival rate, and p99 time-per-output-token
    slow_step_ms: float | None = None
    arrival_rate_hz: float | None = None
    p99_tpot_ms: float | None = None
    # consecutive out-of-band observations required before any swap, and
    # the minimum dwell (in observations) between swaps
    hold_steps: int = 4
    # tier construction: the uniformly-narrow fallback's width pair, and
    # whether to also build the overpacked emergency tier with its
    # certified-MAE ceiling (MAE per extraction, paper-table units)
    narrow_bits: tuple[int, int] = (4, 4)
    emergency_tier: bool = False
    emergency_max_mae: float = 0.5
    # StragglerDetector window for the slow-step signal
    window: int = 16

    def __post_init__(self) -> None:
        if self.queue_low >= self.queue_high:
            raise ValueError(
                f"queue_low ({self.queue_low}) must be < queue_high "
                f"({self.queue_high}) — the gap IS the hysteresis band"
            )
        if self.emergency_queue_high <= self.queue_high:
            raise ValueError(
                f"emergency_queue_high ({self.emergency_queue_high}) must "
                f"be > queue_high ({self.queue_high})"
            )
        if self.hold_steps < 1:
            raise ValueError(f"hold_steps must be >= 1, got {self.hold_steps}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")


@dataclasses.dataclass(frozen=True)
class Tier:
    """One prebuilt serving allocation: quantized weights + plan table."""

    name: str
    params: Any                  # fully prequantized weight tree
    plan_table: dict             # path -> PlanReport (what the tier serves)
    # worst certified per-extraction MAE over the tier's plans: 0.0 for a
    # fully exact tier; the emergency tier's quality contract otherwise
    max_certified_mae: float

    def summary(self) -> dict:
        return {
            "name": self.name,
            "n_planned_layers": len(self.plan_table),
            "max_certified_mae": self.max_certified_mae,
            "exact": self.max_certified_mae == 0.0,
        }


def _table_mae(plan_table: dict) -> float:
    out = 0.0
    for r in plan_table.values():
        cert = r.certificate
        out = max(out, 0.0 if cert.exact else float(cert.mae_per_extraction))
    return out


def build_tiers(cfg, float_params, serve_cfg, primary_params,
                primary_table: dict, gcfg: GovernorConfig) -> tuple[Tier, ...]:
    """Build the degradation ladder from the post-fusion float weights.

    ``float_params`` must be the tree ``primary_params`` was quantized
    FROM (same fusion, same expert splitting applies inside
    ``quantize_for_serving``) so every tier's leaf paths line up and a
    swap changes arithmetic only, never tree shape semantics.
    """
    from ..core.packed_params import quantize_for_serving
    from ..tuning import plan_linear_layers, rank_plans

    tiers = [Tier("primary", primary_params, dict(primary_table),
                  _table_mae(primary_table))]

    a, w = gcfg.narrow_bits
    narrow_table = plan_linear_layers(
        float_params, a_bits=a, w_bits=w, error_budget=0.0,
        exact_first=not serve_cfg.use_kernel,
    )
    narrow_params = quantize_for_serving(
        float_params, "dsp_tuned", plans=narrow_table,
        prepack=serve_cfg.prepack,
    )
    tiers.append(Tier("narrow", narrow_params, narrow_table,
                      _table_mae(narrow_table)))

    if gcfg.emergency_tier:
        # the cheapest overpacked plan whose CERTIFIED MAE fits the
        # ceiling: packing density beyond what exactness permits, quality
        # bounded by the certificate (never by sampling luck)
        ranked = rank_plans(a, w, error_budget=gcfg.emergency_max_mae,
                            exact_first=False)
        # gate on the CERTIFIED bound, not the sampled MAE rank_plans
        # filtered on — a lucky zero-measured sample must not admit a plan
        # whose certificate can't honour the ceiling
        over = [
            r for r in ranked
            if not r.certificate.exact
            and float(r.certificate.mae_per_extraction) <= gcfg.emergency_max_mae
        ]
        if not over:
            raise ValueError(
                f"no overpacked a{a}w{w} plan has certified MAE <= "
                f"{gcfg.emergency_max_mae}; raise emergency_max_mae or "
                "disable emergency_tier"
            )
        pick = min(over, key=lambda r: (r.cost_proxy,
                                        r.mae_per_extraction))
        emergency_table = {p: pick for p in narrow_table}
        emergency_params = quantize_for_serving(
            float_params, "dsp_tuned", plans=emergency_table,
            prepack=serve_cfg.prepack,
        )
        tiers.append(Tier("emergency", emergency_params, emergency_table,
                          _table_mae(emergency_table)))
    return tuple(tiers)


class Governor:
    """Hysteresis controller over the tier ladder.

    Call :meth:`observe` once per engine step with the current scheduler
    signals; it returns the tier index the engine should serve.  A swap
    fires only after ``hold_steps`` consecutive observations agree, and
    the counters reset on every swap — bounded flapping by construction.
    """

    def __init__(self, config: GovernorConfig, n_tiers: int):
        if n_tiers < 2:
            raise ValueError(f"governor needs >= 2 tiers, got {n_tiers}")
        self.config = config
        self.n_tiers = n_tiers
        self.active = 0
        self.n_swaps = 0
        self.steps = 0
        self._up = 0    # consecutive observations wanting a worse tier
        self._down = 0  # ... wanting a better tier
        # (step, from_tier, to_tier) — the faultinject harness reads this
        self.history: list[tuple[int, int, int]] = []

    def _desired(self, queue_depth: int, slow_step_ms, arrival_rate_hz,
                 p99_tpot_ms) -> int:
        c = self.config
        hot = queue_depth >= c.queue_high
        if c.slow_step_ms is not None and slow_step_ms:
            hot = hot or slow_step_ms >= c.slow_step_ms
        if c.arrival_rate_hz is not None and arrival_rate_hz:
            hot = hot or arrival_rate_hz >= c.arrival_rate_hz
        if c.p99_tpot_ms is not None and p99_tpot_ms:
            hot = hot or p99_tpot_ms >= c.p99_tpot_ms
        if self.n_tiers > 2 and queue_depth >= c.emergency_queue_high:
            return self.n_tiers - 1
        if hot:
            return max(1, min(self.active, self.n_tiers - 1))
        if queue_depth <= c.queue_low:
            return 0
        return self.active  # hysteresis band: hold

    def observe(self, queue_depth: int, slow_step_ms: float | None = None,
                arrival_rate_hz: float | None = None,
                p99_tpot_ms: float | None = None) -> int:
        self.steps += 1
        desired = self._desired(
            queue_depth, slow_step_ms, arrival_rate_hz, p99_tpot_ms
        )
        if desired > self.active:
            self._up += 1
            self._down = 0
            if self._up >= self.config.hold_steps:
                self._swap(desired)
        elif desired < self.active:
            self._down += 1
            self._up = 0
            if self._down >= self.config.hold_steps:
                # recover one rung at a time: each step back toward full
                # quality re-earns its own hold_steps of calm
                self._swap(self.active - 1)
        else:
            self._up = self._down = 0
        return self.active

    def _swap(self, target: int) -> None:
        self.history.append((self.steps, self.active, target))
        self.active = target
        self.n_swaps += 1
        self._up = self._down = 0

    def stats(self) -> dict:
        return {
            "tier": self.active,
            "swaps": self.n_swaps,
            "observations": self.steps,
            "history": list(self.history),
        }
