"""Load-adaptive precision governor: graceful degradation under load.

The paper's central trade is a quality/throughput dial — exact plans vs
Overpacking (more multiplications per DSP word at a bounded, certified
MAE).  This module turns that dial into a *runtime* mechanism, following
the dynamic-reconfiguration approximate-multiplier work (switch
multiplier accuracy modes under load) and DeepBurning-MixQ's per-layer
width allocation (PAPERS.md): the engine holds two or three fully
prebuilt weight allocations — **tiers** — and a hysteresis controller
swaps the active one at a step boundary when scheduler signals say the
engine is drowning (or has recovered).

Tiers (built once at engine construction, from the same post-fusion
float weights the primary build quantized):

* ``primary`` — the allocation the engine was configured for (the
  dsp_mixed sensitivity-allocated table, or the dsp_tuned uniform table).
* ``narrow`` — every layer on the narrowest candidate's provably-exact
  plan: cheapest *certified-exact* serving point (packs the most
  multiplications per word without adding arithmetic error beyond the
  narrow quantization grid).
* ``emergency`` (optional) — every layer on an *overpacked* plan with a
  certified MAE bound: the paper's MAE 0.37→0.47 regime, more
  multiplications per DSP than any exact layout permits.  Quality is
  bounded by the plan certificate, not hoped for.

Swap mechanics ride the proven bit-identical plan-swap machinery:
``DspTunedLeaf`` weights are immutable pytrees, the KV cache is plain
arrays independent of the weight representation, and the jitted step
functions specialize per plan table (the leaves' specs are static pytree
aux data) — so ``Engine.set_tier`` just repoints ``engine.params`` and
the next step runs the other arithmetic.  Tokens sampled *before* the
swap are bit-identical to the unswapped engine's; requests admitted
*after* a swap match an engine built directly on the target tier (both
proven in ``tests/test_governor.py``).

The controller is deliberately boring: a tier is a big hammer, so
swaps need ``hold_steps`` consecutive over-threshold observations to
fire (and the counters reset on every swap, so the dwell time between
swaps is at least ``hold_steps`` — no flapping at a noisy threshold).
"""

from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["GovernorConfig", "Governor", "Tier", "TIER_SEARCHES",
           "build_tiers"]


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    # degrade when the queue holds at least this many waiting requests...
    queue_high: int = 8
    # ...and recover only when it has drained to at most this many
    # (the gap is the hysteresis band: in between, hold the current tier)
    queue_low: int = 1
    # escalate to the emergency tier (when built) at this queue depth
    emergency_queue_high: int = 24
    # optional extra degrade signals, each ignored when None: decode-step
    # rolling median (the StragglerDetector slow-step signal), request
    # arrival rate, and p99 time-per-output-token
    slow_step_ms: float | None = None
    arrival_rate_hz: float | None = None
    p99_tpot_ms: float | None = None
    # consecutive out-of-band observations required before any swap, and
    # the minimum dwell (in observations) between swaps
    hold_steps: int = 4
    # tier construction: the uniformly-narrow fallback's width pair, and
    # whether to also build the overpacked emergency tier with its
    # certified-MAE ceiling (MAE per extraction, paper-table units)
    narrow_bits: tuple[int, int] = (4, 4)
    emergency_tier: bool = False
    emergency_max_mae: float = 0.5
    # StragglerDetector window for the slow-step signal
    window: int = 16

    def __post_init__(self) -> None:
        if self.queue_low >= self.queue_high:
            raise ValueError(
                f"queue_low ({self.queue_low}) must be < queue_high "
                f"({self.queue_high}) — the gap IS the hysteresis band"
            )
        if self.emergency_queue_high <= self.queue_high:
            raise ValueError(
                f"emergency_queue_high ({self.emergency_queue_high}) must "
                f"be > queue_high ({self.queue_high})"
            )
        if self.hold_steps < 1:
            raise ValueError(f"hold_steps must be >= 1, got {self.hold_steps}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")


@dataclasses.dataclass(frozen=True)
class Tier:
    """One prebuilt serving allocation: quantized weights + plan table."""

    name: str
    params: Any                  # fully prequantized weight tree
    plan_table: dict             # path -> PlanReport (what the tier serves)
    # worst certified per-extraction MAE over the tier's plans: 0.0 for a
    # fully exact tier; the emergency tier's quality contract otherwise
    max_certified_mae: float

    def summary(self) -> dict:
        return {
            "name": self.name,
            "n_planned_layers": len(self.plan_table),
            "max_certified_mae": self.max_certified_mae,
            "exact": self.max_certified_mae == 0.0,
        }


class _SearchCounter:
    """Counts tier plan searches (the expensive part of a governed build).
    The plan database's warm-build tests assert this stays at zero across
    a cache-hit governed build — the proof that persisted tier ladders
    skipped the search rather than re-running it and discarding the
    result (mirrors ``tuning.mixed.PROBES``)."""

    def __init__(self) -> None:
        self.count = 0

    def reset(self) -> int:
        """Zero the counter, returning the value it held."""
        prev, self.count = self.count, 0
        return prev


TIER_SEARCHES = _SearchCounter()


def _served_spec(report, path: str, shard_groups: int):
    """The spec whose arithmetic actually runs for this layer: the plan's
    own spec, widened for tensor-parallel ROW layers (the cross-device
    psum accumulates every shard's products in one word — see
    ``runtime.tp_packed``)."""
    from ..runtime.sharding import linear_partition

    spec = report.spec
    if shard_groups > 1 and linear_partition(path) == "row":
        from ..kernels.ref import widen_for_shards

        spec = widen_for_shards(spec, shard_groups)
    return spec


def _table_mae(plan_table: dict, shard_groups: int = 1) -> float:
    from ..analysis.verify import certify_spec

    out = 0.0
    for path, r in plan_table.items():
        cert = certify_spec(_served_spec(r, path, shard_groups))
        out = max(out, 0.0 if cert.exact else float(cert.mae_per_extraction))
    return out


def build_tiers(cfg, float_params, serve_cfg, primary_params,
                primary_table: dict, gcfg: GovernorConfig,
                tables: dict | None = None,
                shard_groups: int = 1) -> tuple[Tier, ...]:
    """Build the degradation ladder from the post-fusion float weights.

    ``float_params`` must be the tree ``primary_params`` was quantized
    FROM (same fusion, same expert splitting applies inside
    ``quantize_for_serving``) so every tier's leaf paths line up and a
    swap changes arithmetic only, never tree shape semantics.

    ``tables`` short-circuits the tier plan searches with previously
    persisted tables — ``{"narrow": {path: PlanReport}, "emergency":
    {...}}`` as deserialized by ``_setup_governor`` from the plan
    database's ``"tiers"`` record.  Quantization still runs (the weight
    payloads are never persisted), but no search does:
    ``TIER_SEARCHES.count`` stays flat.

    ``shard_groups`` is the engine's tensor-parallel degree; tier plan
    searches select shard-legal plans for row-partitioned layers the same
    way the primary build does (``tuner.plan_linear_layers``)."""
    from ..core.packed_params import quantize_for_serving
    from ..tuning import plan_linear_layers

    tables = tables or {}
    tiers = [Tier("primary", primary_params, dict(primary_table),
                  _table_mae(primary_table, shard_groups))]

    a, w = gcfg.narrow_bits
    narrow_table = tables.get("narrow")
    if narrow_table is None:
        TIER_SEARCHES.count += 1
        narrow_table = plan_linear_layers(
            float_params, a_bits=a, w_bits=w, error_budget=0.0,
            exact_first=not serve_cfg.use_kernel,
            shard_groups=shard_groups,
        )
    narrow_params = quantize_for_serving(
        float_params, "dsp_tuned", plans=narrow_table,
        prepack=serve_cfg.prepack,
    )
    tiers.append(Tier("narrow", narrow_params, narrow_table,
                      _table_mae(narrow_table, shard_groups)))

    if gcfg.emergency_tier:
        emergency_table = tables.get("emergency")
        if emergency_table is None:
            TIER_SEARCHES.count += 1
            emergency_table = _emergency_table(
                a, w, gcfg, narrow_table, shard_groups
            )
        emergency_params = quantize_for_serving(
            float_params, "dsp_tuned", plans=emergency_table,
            prepack=serve_cfg.prepack,
        )
        tiers.append(Tier("emergency", emergency_params, emergency_table,
                          _table_mae(emergency_table, shard_groups)))
    return tuple(tiers)


def _emergency_table(a: int, w: int, gcfg: GovernorConfig,
                     narrow_table: dict, shard_groups: int) -> dict:
    """The cheapest overpacked plan whose CERTIFIED MAE fits the ceiling:
    packing density beyond what exactness permits, quality bounded by the
    certificate (never by sampling luck).  Under tensor parallelism the
    pick is made per partition kind — a row layer's certificate is the
    WIDENED spec's (that is the arithmetic the psum realizes)."""
    from ..analysis.verify import certify_spec
    from ..tuning import rank_plans

    groups_needed = sorted(
        {_served_spec_groups(p, shard_groups) for p in narrow_table} or {1}
    )
    picks = {}
    for groups in groups_needed:
        ranked = rank_plans(a, w, error_budget=gcfg.emergency_max_mae,
                            exact_first=False, shard_groups=groups)
        # gate on the CERTIFIED bound of the SERVED spec, not the sampled
        # MAE rank_plans filtered on — a lucky zero-measured sample must
        # not admit a plan whose certificate can't honour the ceiling
        over = []
        for r in ranked:
            from ..kernels.ref import widen_for_shards

            spec = widen_for_shards(r.spec, groups) if groups > 1 else r.spec
            cert = certify_spec(spec)
            if (not cert.exact
                    and float(cert.mae_per_extraction)
                    <= gcfg.emergency_max_mae):
                over.append(r)
        if not over:
            sharded = (f" at shard_groups={groups}" if groups > 1 else "")
            raise ValueError(
                f"no overpacked a{a}w{w} plan has certified MAE <= "
                f"{gcfg.emergency_max_mae}{sharded}; raise "
                "emergency_max_mae or disable emergency_tier"
            )
        picks[groups] = min(over, key=lambda r: (r.cost_proxy,
                                                 r.mae_per_extraction))
    return {
        p: picks[_served_spec_groups(p, shard_groups)] for p in narrow_table
    }


def _served_spec_groups(path: str, shard_groups: int) -> int:
    from ..runtime.sharding import linear_partition

    if shard_groups > 1 and linear_partition(path) == "row":
        return shard_groups
    return 1


class Governor:
    """Hysteresis controller over the tier ladder.

    Call :meth:`observe` once per engine step with the current scheduler
    signals; it returns the tier index the engine should serve.  A swap
    fires only after ``hold_steps`` consecutive observations agree, and
    the counters reset on every swap — bounded flapping by construction.
    """

    def __init__(self, config: GovernorConfig, n_tiers: int):
        if n_tiers < 2:
            raise ValueError(f"governor needs >= 2 tiers, got {n_tiers}")
        self.config = config
        self.n_tiers = n_tiers
        self.active = 0
        self.n_swaps = 0
        self.steps = 0
        self._up = 0    # consecutive observations wanting a worse tier
        self._down = 0  # ... wanting a better tier
        # (step, from_tier, to_tier) — the faultinject harness reads this
        self.history: list[tuple[int, int, int]] = []

    def _desired(self, queue_depth: int, slow_step_ms, arrival_rate_hz,
                 p99_tpot_ms) -> int:
        c = self.config
        hot = queue_depth >= c.queue_high
        if c.slow_step_ms is not None and slow_step_ms:
            hot = hot or slow_step_ms >= c.slow_step_ms
        if c.arrival_rate_hz is not None and arrival_rate_hz:
            hot = hot or arrival_rate_hz >= c.arrival_rate_hz
        if c.p99_tpot_ms is not None and p99_tpot_ms:
            hot = hot or p99_tpot_ms >= c.p99_tpot_ms
        if self.n_tiers > 2 and queue_depth >= c.emergency_queue_high:
            return self.n_tiers - 1
        if hot:
            return max(1, min(self.active, self.n_tiers - 1))
        if queue_depth <= c.queue_low:
            return 0
        return self.active  # hysteresis band: hold

    def observe(self, queue_depth: int, slow_step_ms: float | None = None,
                arrival_rate_hz: float | None = None,
                p99_tpot_ms: float | None = None) -> int:
        self.steps += 1
        desired = self._desired(
            queue_depth, slow_step_ms, arrival_rate_hz, p99_tpot_ms
        )
        if desired > self.active:
            self._up += 1
            self._down = 0
            if self._up >= self.config.hold_steps:
                self._swap(desired)
        elif desired < self.active:
            self._down += 1
            self._up = 0
            if self._down >= self.config.hold_steps:
                # recover one rung at a time: each step back toward full
                # quality re-earns its own hold_steps of calm
                self._swap(self.active - 1)
        else:
            self._up = self._down = 0
        return self.active

    def _swap(self, target: int) -> None:
        self.history.append((self.steps, self.active, target))
        self.active = target
        self.n_swaps += 1
        self._up = self._down = 0

    def stats(self) -> dict:
        return {
            "tier": self.active,
            "swaps": self.n_swaps,
            "observations": self.steps,
            "history": list(self.history),
        }
