"""Token sampling for the serving engine.

One jit-friendly primitive, ``sample_tokens``, drives both the prefill
first-token draw and every decode step: temperature, top-k and top-p are
per-slot *arrays* so a single batched call serves heterogeneous requests
(one slot greedy, the neighbour at temperature 0.9/top-p 0.95).

Randomness is stateless: each slot gets a base PRNG key derived from its
request id (``slot_key``), and every step folds in the slot's current
position — the (request, position) pair fully determines the draw, so a
replayed request reproduces its tokens bit-for-bit regardless of what the
other slots were doing.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "GREEDY", "slot_key", "sample_tokens"]

NEG_INF = -1e30  # mask value; dominates any temperature-scaled logit


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls.  ``temperature == 0`` means greedy;
    ``top_k == 0`` and ``top_p == 1.0`` disable the respective filters."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self) -> None:
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


GREEDY = SamplingParams()


def slot_key(base_key, rid: int):
    """Per-request PRNG key: fold the request id into the engine's seed."""
    return jax.random.fold_in(base_key, rid)


def sample_tokens(
    logits: jax.Array,      # (B, V) float
    keys: jax.Array,        # (B, 2) uint32 per-slot base keys
    positions: jax.Array,   # (B,) int32 — folded in for per-step streams
    temperature: jax.Array,  # (B,) float32
    top_k: jax.Array,        # (B,) int32, 0 = off
    top_p: jax.Array,        # (B,) float32, 1.0 = off
) -> jax.Array:
    """Draw one token per row.  Rows with ``temperature == 0`` take argmax."""
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]

    # top-k: keep values >= the k-th largest (ties may keep a few extra)
    desc = -jnp.sort(-scaled, axis=-1)
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_k - 1, 0, v - 1)[:, None], axis=-1
    )
    keep = jnp.where((top_k > 0)[:, None], scaled >= kth, True)

    # top-p (nucleus): smallest prefix of the sorted distribution whose
    # mass reaches top_p; the first token always survives
    probs = jax.nn.softmax(scaled, axis=-1)
    sp = -jnp.sort(-probs, axis=-1)
    csum = jnp.cumsum(sp, axis=-1)
    n_keep = jnp.maximum(jnp.sum(csum - sp < top_p[:, None], axis=-1), 1)
    thr = jnp.take_along_axis(sp, (n_keep - 1)[:, None], axis=-1)
    keep &= probs >= thr

    masked = jnp.where(keep, scaled, NEG_INF)
    step_keys = jax.vmap(jax.random.fold_in)(keys, positions)
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, (v,), jnp.float32))(step_keys)
    sampled = jnp.argmax(masked + gumbel, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0, greedy, sampled)
