"""Data-parallel replica serving: N engines behind one front.

Tensor parallelism (``ServeConfig.tp``, ``runtime.tp_packed``) splits one
engine's weights across a mesh; this module scales the OTHER axis —
throughput — by running ``n_replicas`` complete engines side by side and
routing requests between them.  The two compose: each replica may itself
be a TP engine over its own mesh slice (DESIGN.md §4).

Routing is join-shortest-queue and fully deterministic: a request goes
to the replica with the least load (``queued + running`` from
:meth:`~repro.serving.scheduler.Scheduler.stats`), ties broken by lowest
replica index.  Determinism matters for the same reason the TP path is
bit-identical — a replayed trace of submissions must land every request
on the same replica, so replica serving adds no nondeterminism the
conformance suites would have to tolerate.

The front owns the request-id namespace: callers see *global* rids, the
front keeps the ``global rid -> (replica, local rid)`` mapping and
aggregates per-replica outputs and stats.  Replicas never see each
other — there is no cross-replica KV sharing or migration; a request
lives and dies on the replica it joined (the simplest model that is
also what the paper's packing results need: packing density is a
per-engine property, so replicas scale it linearly).
"""

from __future__ import annotations

from .engine import Engine, ServeConfig
from .sampling import SamplingParams

__all__ = ["ReplicaFront"]


class ReplicaFront:
    """Join-shortest-queue front over ``n_replicas`` serving engines.

    Each replica is built from the same ``(cfg, params, serve_cfg)``
    triple, so all replicas quantize to identical weights and any replica
    emits bit-identical tokens for a given prompt — routing affects
    latency, never content.

    ``engine_cls`` selects the replica engine (``Engine`` or
    ``ContinuousEngine``; both expose the same submit/step/outputs/stats
    surface).
    """

    def __init__(self, cfg, params, serve_cfg: ServeConfig,
                 n_replicas: int = 2, engine_cls=Engine):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.replicas = [
            engine_cls(cfg, params, serve_cfg) for _ in range(n_replicas)
        ]
        self._route: dict[int, tuple[int, int]] = {}  # grid -> (rep, lrid)
        self._next_rid = 0

    # ---- routing ---------------------------------------------------------
    def _pick(self) -> int:
        """Least-loaded replica index (queued + running), lowest index on
        ties — a pure function of current scheduler stats."""
        loads = [
            (r.scheduler.stats()["queued"] + r.scheduler.stats()["running"], i)
            for i, r in enumerate(self.replicas)
        ]
        return min(loads)[1]

    def submit(self, prompt: list[int], max_new: int | None = None,
               sampling: SamplingParams | None = None, **kw) -> int:
        """Route one request to the least-loaded replica; returns a
        GLOBAL rid (the replica's local rid stays internal)."""
        rep = self._pick()
        lrid = self.replicas[rep].submit(
            prompt, max_new=max_new, sampling=sampling, **kw
        )
        grid = self._next_rid
        self._next_rid += 1
        self._route[grid] = (rep, lrid)
        return grid

    # ---- serving loop ----------------------------------------------------
    def step(self) -> list[int]:
        """Advance every replica that has work; returns the global rids
        finished this step (ascending)."""
        done_local = []
        for i, r in enumerate(self.replicas):
            s = r.scheduler.stats()
            if s["queued"] or s["running"]:
                for lrid in r.step():
                    done_local.append((i, lrid))
        inv = {v: k for k, v in self._route.items()}
        return sorted(inv[t] for t in done_local if t in inv)

    def generate(self, prompts: list[list[int]],
                 max_new: int | None = None) -> dict[int, list[int]]:
        """Serve a batch to completion across all replicas; returns
        ``{global rid: tokens}`` in submission order."""
        grids = [self.submit(p, max_new=max_new) for p in prompts]
        pending = set(grids)
        while pending:
            for g in self.step():
                pending.discard(g)
        return {g: self.outputs[g] for g in grids}

    # ---- aggregation -----------------------------------------------------
    @property
    def outputs(self) -> dict[int, list[int]]:
        """Global-rid view over every replica's emitted tokens."""
        out = {}
        for grid, (rep, lrid) in self._route.items():
            toks = self.replicas[rep].outputs.get(lrid)
            if toks:
                out[grid] = toks
        return out

    def replica_of(self, grid: int) -> int:
        """Which replica a global rid was routed to (for tests/ops)."""
        return self._route[grid][0]

    def stats(self) -> dict:
        """Aggregate counters summed across replicas, plus the full
        per-replica stats under ``"replicas"``."""
        per = [r.stats() for r in self.replicas]
        agg = {
            k: sum(s[k] for s in per)
            for k in ("queued", "running", "finished", "cancelled", "shed",
                      "prefill_tokens", "decode_tokens")
            if all(k in s for s in per)
        }
        agg["n_replicas"] = len(self.replicas)
        agg["replicas"] = per
        return agg
