"""Request scheduler for the serving engine.

Owns everything that is *not* device compute: the admission queue (FIFO),
per-request bookkeeping (prompt, budget, sampling params, emitted tokens,
finish reason) and the engine-wide throughput/latency counters.  The engine
asks it which requests to admit when slots free up and reports every
prefill/decode batch back so ``stats()`` can answer the operator questions
— queue depth, tokens/s by phase, time-to-first-token, request latency.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

from .sampling import GREEDY, SamplingParams

__all__ = ["Request", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    sampling: SamplingParams = GREEDY
    submitted_at: float = 0.0
    prefill_done_at: float | None = None
    finished_at: float | None = None
    finish_reason: str | None = None  # "eos" | "length" | None while running
    tokens: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.finish_reason is not None


class Scheduler:
    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self._queue: deque[int] = deque()
        self._next_rid = 0
        self.requests: dict[int, Request] = {}
        # throughput/latency counters
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.prefill_time_s = 0.0
        self.decode_time_s = 0.0
        self.n_finished = 0

    # ---- queue ---------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int,
               sampling: SamplingParams = GREEDY) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(
            rid, list(prompt), max_new, sampling, submitted_at=self._clock()
        )
        self._queue.append(rid)
        return rid

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    def admit(self, n_free: int) -> list[Request]:
        """Pop up to ``n_free`` queued requests for prefill."""
        out = []
        while self._queue and len(out) < n_free:
            out.append(self.requests[self._queue.popleft()])
        return out

    # ---- accounting ----------------------------------------------------
    def note_prefill(self, n_tokens: int, dt_s: float,
                     admitted: list[Request]) -> None:
        self.prefill_tokens += n_tokens
        self.prefill_time_s += dt_s
        now = self._clock()
        for req in admitted:
            req.prefill_done_at = now

    def note_decode(self, n_tokens: int, dt_s: float) -> None:
        self.decode_tokens += n_tokens
        self.decode_time_s += dt_s

    def finish(self, rid: int, reason: str) -> None:
        req = self.requests[rid]
        if req.done:
            raise RuntimeError(f"request {rid} finished twice")
        req.finish_reason = reason
        req.finished_at = self._clock()
        self.n_finished += 1

    # ---- reporting -----------------------------------------------------
    def stats(self) -> dict:
        done = [r for r in self.requests.values() if r.done]
        ttft = [r.prefill_done_at - r.submitted_at for r in done
                if r.prefill_done_at is not None]
        lat = [r.finished_at - r.submitted_at for r in done]
        mean = lambda xs: sum(xs) / len(xs) if xs else 0.0
        return {
            "queued": self.n_queued,
            "running": len(self.requests) - self.n_finished - self.n_queued,
            "finished": self.n_finished,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_tok_s": self.prefill_tokens / max(self.prefill_time_s, 1e-9),
            "decode_tok_s": self.decode_tokens / max(self.decode_time_s, 1e-9),
            "mean_ttft_s": mean(ttft),
            "mean_latency_s": mean(lat),
        }
