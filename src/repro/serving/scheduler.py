"""Request scheduler for the serving engines.

Owns everything that is *not* device compute: the admission queue (FIFO),
per-request bookkeeping (prompt, budget, sampling params, emitted tokens,
finish reason) and the engine-wide throughput/latency counters.  The engine
asks it which requests to admit when capacity frees up and reports every
prefill/decode batch back so ``stats()`` can answer the operator questions
— queue depth, tokens/s by phase, time-to-first-token, request latency.

Two engines drive it: the fixed-slot ``Engine`` pops whole batches with
``admit``, while ``ContinuousEngine`` inspects the queue head with ``peek``
and pops one request at a time with ``admit_front`` (strict FIFO — if the
front request's pages don't fit, nobody skips ahead of it) and may push a
preempted request back to the *front* with ``requeue``.

Accounting rules learned the hard way:

* ``note_prefill_done`` stamps TTFT per request, when *that request's* last
  prefill chunk completes — not once for the whole admission batch, which
  charged short prompts in a mixed batch for the longest prompt's chunks.
* ``running`` is tracked explicitly (admit +1, finish/requeue -1), never
  derived by subtraction — preemption made the subtraction lie.
* rate/percentile helpers return 0.0 for empty phases instead of the
  ``tokens / max(t, 1e-9)`` ~1e9 tok/s artifact.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

from .sampling import GREEDY, SamplingParams

__all__ = ["Request", "Scheduler", "percentile", "CANCEL_REASONS"]

# Finish reasons that mean "the scheduler gave up on the request", not
# "the request completed": explicit caller cancellation and deadline
# shedding.  stats() counts these separately from completions and keeps
# them out of the latency metrics — a shed request has no latency, and
# folding its short life into p99 would make load-shedding look like a
# latency win.
CANCEL_REASONS = ("cancelled", "deadline")


def percentile(xs: list[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]); empty -> 0.0.

    Used by ``stats()`` and the traffic bench — matches numpy's default
    ("linear") method without pulling an array dependency into the hot
    serving path.
    """
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    rank = (q / 100.0) * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


def _rate(tokens: int, t: float) -> float:
    """tokens/s with an honest 0.0 when the phase never ran."""
    return tokens / t if tokens and t > 0.0 else 0.0


def _mean(xs: list[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0


@dataclasses.dataclass
class Request:
    """One request's whole life: prompt, budget, sampling params, the
    tokens emitted so far, and the timestamps ``stats()`` turns into
    TTFT/latency.  ``finish_reason`` is the state machine — ``None``
    while queued/running, then exactly one of "eos" | "length" |
    "cancelled" | "deadline" (the last two are ``CANCEL_REASONS``:
    the scheduler gave up, the request did not complete)."""

    rid: int
    prompt: list[int]
    max_new: int
    sampling: SamplingParams = GREEDY
    submitted_at: float = 0.0
    prefill_done_at: float | None = None
    finished_at: float | None = None
    # "eos" | "length" | "cancelled" | "deadline" | None while running
    finish_reason: str | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    # absolute clock time after which the request is shed (None = no
    # deadline); stamped at submit from the relative deadline_s budget
    deadline_at: float | None = None

    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    @property
    def cancelled(self) -> bool:
        return self.finish_reason in CANCEL_REASONS


class Scheduler:
    """FIFO admission queue + per-request bookkeeping + engine counters.

    Pure host-side state — no device arrays, no knowledge of slots or
    pages; the engines translate its decisions into lane/cache moves.
    ``clock`` is injectable so the traffic bench and the deadline tests
    can drive virtual time deterministically.
    """

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self._queue: deque[int] = deque()
        self._next_rid = 0
        self.requests: dict[int, Request] = {}
        # throughput/latency counters
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.prefill_time_s = 0.0
        self.decode_time_s = 0.0
        self.n_finished = 0
        self.n_running = 0
        self.n_preempted = 0
        self.n_cancelled = 0
        self.n_shed = 0  # the "deadline" subset of n_cancelled
        # unfinished rids carrying a deadline — expired() scans only these,
        # so engines without deadlines pay nothing per step
        self._deadlined: set[int] = set()

    # ---- queue ---------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int,
               sampling: SamplingParams = GREEDY,
               deadline_s: float | None = None) -> int:
        """``deadline_s`` is a relative wall-clock budget from submission;
        a request still unfinished ``deadline_s`` after submit is eligible
        for shedding (``expired`` → ``cancel(reason="deadline")``)."""
        if not prompt:
            raise ValueError("empty prompt")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        rid = self._next_rid
        self._next_rid += 1
        now = self._clock()
        self.requests[rid] = Request(
            rid, list(prompt), max_new, sampling, submitted_at=now,
            deadline_at=None if deadline_s is None else now + deadline_s,
        )
        if deadline_s is not None:
            self._deadlined.add(rid)
        self._queue.append(rid)
        return rid

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def next_rid(self) -> int:
        """The rid the next ``submit`` will assign (lets callers bracket a
        window of requests, e.g. to compute metrics over one replay)."""
        return self._next_rid

    def admit(self, n_free: int) -> list[Request]:
        """Pop up to ``n_free`` queued requests for prefill."""
        out = []
        while self._queue and len(out) < n_free:
            out.append(self.requests[self._queue.popleft()])
        self.n_running += len(out)
        return out

    def peek(self) -> Request | None:
        """Front of the queue without popping (continuous admission asks
        whether the front request's pages fit before committing)."""
        return self.requests[self._queue[0]] if self._queue else None

    def admit_front(self) -> Request:
        """Pop exactly the front request (strict FIFO admission)."""
        req = self.requests[self._queue.popleft()]
        self.n_running += 1
        return req

    def requeue(self, rid: int) -> None:
        """Push a preempted request back to the *front* of the queue.  Its
        emitted tokens are kept — re-admission re-prefills prompt+tokens and
        the (rid, position)-keyed sampler resumes the identical stream.
        ``prefill_done_at`` is kept too: TTFT measures the first token, and
        the request already produced it."""
        req = self.requests[rid]
        if req.done:
            raise RuntimeError(f"request {rid} is finished, cannot requeue")
        self._queue.appendleft(rid)
        self.n_running -= 1
        self.n_preempted += 1

    # ---- accounting ----------------------------------------------------
    def note_prefill(self, n_tokens: int, dt_s: float) -> None:
        """Throughput counters only — TTFT stamping is per-request via
        ``note_prefill_done`` (a mixed batch must not charge short prompts
        for the longest prompt's chunk time)."""
        self.prefill_tokens += n_tokens
        self.prefill_time_s += dt_s

    def note_prefill_done(self, reqs: list[Request]) -> None:
        """Stamp TTFT for requests whose own last prefill chunk just
        completed.  Idempotent per request — a preempted request keeps its
        original first-token stamp across re-prefill."""
        now = self._clock()
        for req in reqs:
            if req.prefill_done_at is None:
                req.prefill_done_at = now

    def note_decode(self, n_tokens: int, dt_s: float) -> None:
        self.decode_tokens += n_tokens
        self.decode_time_s += dt_s

    def finish(self, rid: int, reason: str) -> None:
        """Complete a request.  A still-queued rid (never admitted, or
        preempted back to the queue) is dequeued cleanly — it was not
        running, so ``n_running`` must not move for it (the old
        unconditional decrement corrupted the running count for every
        finish-from-queue path)."""
        req = self.requests[rid]
        if req.done:
            raise RuntimeError(f"request {rid} finished twice")
        if rid in self._queue:
            self._queue.remove(rid)
        else:
            self.n_running -= 1
        req.finish_reason = reason
        req.finished_at = self._clock()
        self.n_finished += 1
        self._deadlined.discard(rid)

    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Abort a request with a cancellation reason (``CANCEL_REASONS``).

        Queued requests are dequeued without ever being admitted; running
        requests are marked done here and the engine frees their
        lane/slot at its next step boundary.  Returns True when the
        request was still queued (the caller learns no device state needs
        releasing).  Counted under ``n_cancelled`` (and ``n_shed`` for
        deadline sheds) — never ``n_finished``.
        """
        if reason not in CANCEL_REASONS:
            raise ValueError(
                f"cancel reason {reason!r} not in {CANCEL_REASONS}"
            )
        req = self.requests[rid]
        if req.done:
            raise RuntimeError(f"request {rid} is finished, cannot cancel")
        was_queued = rid in self._queue
        if was_queued:
            self._queue.remove(rid)
        else:
            self.n_running -= 1
        req.finish_reason = reason
        req.finished_at = self._clock()
        self.n_cancelled += 1
        if reason == "deadline":
            self.n_shed += 1
        self._deadlined.discard(rid)
        return was_queued

    def expired(self, now: float | None = None) -> list[int]:
        """Unfinished rids past their deadline (queued and running alike),
        oldest first — the engine sheds these at step boundaries."""
        now = self._clock() if now is None else now
        return [
            rid for rid in sorted(self._deadlined)
            if now > self.requests[rid].deadline_at
        ]

    # ---- reporting -----------------------------------------------------
    def stats(self) -> dict:
        # completed only: a cancelled/shed request has no honest latency —
        # folding its short life into the percentiles would make shedding
        # itself look like a latency improvement
        done = [r for r in self.requests.values()
                if r.done and not r.cancelled]
        ttft = [r.prefill_done_at - r.submitted_at for r in done
                if r.prefill_done_at is not None]
        lat = [r.finished_at - r.submitted_at for r in done]
        # time-per-output-token over the decode phase (needs >= 2 tokens:
        # the first is charged to TTFT)
        tpot = [
            (r.finished_at - r.prefill_done_at) / (len(r.tokens) - 1)
            for r in done
            if r.prefill_done_at is not None and len(r.tokens) > 1
        ]
        return {
            "queued": self.n_queued,
            "running": self.n_running,
            "finished": self.n_finished,
            "cancelled": self.n_cancelled,
            "shed": self.n_shed,
            "preempted": self.n_preempted,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_tok_s": _rate(self.prefill_tokens, self.prefill_time_s),
            "decode_tok_s": _rate(self.decode_tokens, self.decode_time_s),
            "mean_ttft_s": _mean(ttft),
            "p50_ttft_s": percentile(ttft, 50.0),
            "p99_ttft_s": percentile(ttft, 99.0),
            "mean_latency_s": _mean(lat),
            "p50_latency_s": percentile(lat, 50.0),
            "p99_latency_s": percentile(lat, 99.0),
            "p50_tpot_s": percentile(tpot, 50.0),
            "p99_tpot_s": percentile(tpot, 99.0),
        }
