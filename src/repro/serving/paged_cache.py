"""Paged/block KV cache bookkeeping for the continuous-batching engine.

The device side of the paged cache is plain storage: every attention layer
holds ``(n_pages, page_size, n_kv, hd)`` K and V arrays
(``models.layers.init_paged_kv_cache``), and the attention layer
scatter-writes new K/V through a per-lane *page table* then gathers the
lane's logical view back for the attend
(``models.layers.attention``'s paged branch).

Everything stateful lives here, on the host, in ``PageAllocator``:

* **free list** — physical page ids not owned by any request.  ``grow``
  hands pages to a request's table atomically (it checks the free count
  first, so a failed grow never half-mutates state).
* **page tables** — per-request ``rid -> [page_id, ...]`` in logical block
  order.  ``table_array`` renders the per-lane device table; lanes without
  a request and table slots past a request's allocation are filled with
  the OOB sentinel ``invalid == n_pages`` so device scatters DROP writes
  to them and gathers clamp to junk that the attention mask discards.
* **refcounts + prefix sharing** — a registered shared prefix (a common
  system prompt) is prefilled once; its full pages are pinned and adopted
  by later requests (``adopt_shared``) with a refcount bump, so N
  requests with the same system prompt hold one physical copy.
* **copy-on-write** — ``make_writable`` is called by the engine for every
  block a write will touch: a block whose page is shared (refcount > 1)
  gets a fresh private page and the caller copies the device data over,
  so no request can corrupt a page another request is still reading.
* **admission watermark** — ``can_admit`` refuses a request whose pages
  would dip the free list below ``watermark``, keeping headroom for the
  already-decoding lanes to grow (each needs a fresh page every
  ``page_size`` tokens).  When decode growth still runs dry, the engine
  preempts the youngest request (``free`` + re-prefill on re-admission —
  bit-identical resume, see ``engine.ContinuousEngine``).

``free`` is the single teardown path (finish and preemption both land
here); a page can only return to the free list when its refcount hits
zero, and freeing an unknown rid raises — double frees are structural
errors, never silent.
"""

from __future__ import annotations

import numpy as np

__all__ = ["OutOfPages", "PageAllocator"]


class OutOfPages(RuntimeError):
    """The free list cannot satisfy an allocation (caller may preempt)."""


class PageAllocator:
    def __init__(self, n_pages: int, page_size: int, watermark: int = 0):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if not 0 <= watermark < n_pages:
            raise ValueError(
                f"watermark must be in [0, n_pages), got {watermark}"
            )
        self.n_pages = n_pages
        self.page_size = page_size
        self.watermark = watermark
        # pop() takes from the end: keep ids reversed so low pages go first
        self._free = list(range(n_pages - 1, -1, -1))
        self._refs = np.zeros(n_pages, np.int64)
        self._tables: dict[int, list[int]] = {}
        # shared-prefix registry: key -> pinned page ids (one permanent ref
        # each, so the prefix survives with zero active holders)
        self._shared: dict[tuple, list[int]] = {}

    # ---- capacity -------------------------------------------------------
    @property
    def invalid(self) -> int:
        """OOB page sentinel: device scatters drop, gathers clamp+mask."""
        return self.n_pages

    @property
    def n_free(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_blocks: int) -> bool:
        """Would allocating ``n_blocks`` keep ``watermark`` pages free?"""
        return self.n_free - n_blocks >= self.watermark

    # ---- request tables -------------------------------------------------
    def open_table(self, rid: int) -> None:
        if rid in self._tables:
            raise ValueError(f"request {rid} already holds a page table")
        self._tables[rid] = []

    def n_blocks(self, rid: int) -> int:
        return len(self._tables[rid])

    def grow(self, rid: int, n_blocks_total: int) -> list[int]:
        """Extend ``rid``'s table to ``n_blocks_total`` blocks; atomic —
        raises ``OutOfPages`` without mutating when the free list is
        short.  Returns the newly assigned page ids."""
        table = self._tables[rid]
        need = n_blocks_total - len(table)
        if need <= 0:
            return []
        if need > self.n_free:
            raise OutOfPages(
                f"request {rid} needs {need} page(s), {self.n_free} free"
            )
        fresh = [self._free.pop() for _ in range(need)]
        for p in fresh:
            self._refs[p] = 1
        table.extend(fresh)
        return fresh

    def make_writable(self, rid: int, block_idx: int) -> tuple[int, int | None]:
        """Copy-on-write: return ``(page, copy_src)`` for a block about to
        be written.  Exclusive pages return ``(page, None)``; shared pages
        get a fresh private page and the caller must copy the device data
        from ``copy_src`` into ``page`` before writing."""
        page = self._tables[rid][block_idx]
        if self._refs[page] <= 1:
            return page, None
        if not self._free:
            raise OutOfPages(
                f"copy-on-write for request {rid} block {block_idx}: "
                "no free page"
            )
        fresh = self._free.pop()
        self._refs[fresh] = 1
        self._refs[page] -= 1
        self._tables[rid][block_idx] = fresh
        return fresh, page

    def free(self, rid: int) -> None:
        """Release ``rid``'s pages (finish and preemption both land here).
        Unknown rids raise — a double free is a structural bug."""
        if rid not in self._tables:
            raise KeyError(f"request {rid} holds no page table (double free?)")
        for page in self._tables.pop(rid):
            self._refs[page] -= 1
            if self._refs[page] == 0:
                self._free.append(page)
            elif self._refs[page] < 0:
                raise AssertionError(f"page {page} refcount underflow")

    # ---- prefix sharing -------------------------------------------------
    def register_shared(self, key: tuple, rid: int, n_blocks: int) -> None:
        """Pin the first ``n_blocks`` pages of ``rid``'s table as the
        shared prefix for ``key`` (one permanent ref each, so the prefix
        outlives its prefiller)."""
        if key in self._shared:
            raise ValueError(f"shared prefix {key!r} already registered")
        pages = self._tables[rid][:n_blocks]
        if len(pages) < n_blocks:
            raise ValueError(
                f"request {rid} holds {len(pages)} block(s), "
                f"cannot share {n_blocks}"
            )
        for p in pages:
            self._refs[p] += 1
        self._shared[key] = list(pages)

    def shared_blocks(self, key: tuple) -> int:
        """Block count of a registered prefix (0 when unregistered)."""
        return len(self._shared.get(key, ()))

    def adopt_shared(self, key: tuple, rid: int) -> int:
        """Prepend the shared prefix's pages to ``rid``'s (empty) table
        with a refcount bump; returns the token count they cover."""
        pages = self._shared[key]
        table = self._tables[rid]
        if table:
            raise ValueError(
                f"request {rid} must adopt the shared prefix before "
                "allocating its own pages"
            )
        for p in pages:
            self._refs[p] += 1
        table.extend(pages)
        return len(pages) * self.page_size

    # ---- device view ----------------------------------------------------
    def table_array(self, lane_rids, max_blocks: int) -> np.ndarray:
        """(n_lanes, max_blocks) int32 device page table; empty lanes and
        unallocated blocks carry the ``invalid`` sentinel."""
        out = np.full((len(lane_rids), max_blocks), self.invalid, np.int32)
        for i, rid in enumerate(lane_rids):
            rid = int(rid)
            if rid >= 0 and rid in self._tables:
                t = self._tables[rid][:max_blocks]
                out[i, : len(t)] = t
        return out

    # ---- invariants -----------------------------------------------------
    def check(self) -> None:
        """Leak/double-free invariant: every page is free XOR referenced,
        and the books balance exactly."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("free list holds duplicate pages")
        held = (self._refs > 0).nonzero()[0]
        if free & set(held.tolist()):
            raise AssertionError("page is both free and referenced")
        if len(free) + len(held) != self.n_pages:
            raise AssertionError(
                f"page leak: {len(free)} free + {len(held)} held "
                f"!= {self.n_pages}"
            )

    def reset(self) -> None:
        """Drop every table, shared pin and ref — a fresh allocator."""
        self._free = list(range(self.n_pages - 1, -1, -1))
        self._refs[:] = 0
        self._tables.clear()
        self._shared.clear()
