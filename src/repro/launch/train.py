"""Training driver.

CPU-smoke example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-110b --smoke \
      --steps 50 --global-batch 8 --seq-len 128 --quant qat4

On a real slice the same driver runs under the production mesh
(``--mesh single|multi``); the loop is identical: sharded state, jitted
train_step, async checkpoints every ``--ckpt-every``, heartbeat every step,
restart-from-latest on relaunch.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint.checkpointer import Checkpointer
from ..core.packed_linear import LinearSpec
from ..data.pipeline import DataConfig, SyntheticStream
from ..models import transformer as T
from ..models.registry import get_config
from ..optim.adamw import AdamWConfig, adamw_init
from ..optim.schedule import cosine_with_warmup
from ..runtime.fault_tolerance import Heartbeat
from ..runtime.sharding import param_shardings
from .mesh import make_local_mesh, make_production_mesh
from .steps import make_train_step


def build_state(cfg, mesh, opt_cfg, seed=0):
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(seed), cfg, jnp.float32)
    )
    p_shard = param_shardings(params_shape, mesh)
    init = jax.jit(
        lambda k: T.init_params(k, cfg, jnp.float32), out_shardings=p_shard
    )
    params = init(jax.random.PRNGKey(seed))
    opt = jax.jit(adamw_init, out_shardings={"m": p_shard, "v": p_shard, "step": NamedSharding(mesh, P())})(params)
    return {"params": params, "opt": opt}, p_shard


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quant", default="native",
                    choices=["native", "qat4", "qat8", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="local", choices=["local", "single", "multi"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, quant=LinearSpec(mode=args.quant))
    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    opt_cfg = AdamWConfig(lr=args.lr)
    sched = cosine_with_warmup(args.lr, warmup=max(args.steps // 10, 1), total=args.steps)
    state, p_shard = build_state(cfg, mesh, opt_cfg)
    if args.compress_grads:
        from ..runtime.compression import init_error_feedback

        state["error_buf"] = init_error_feedback(state["params"])

    data = SyntheticStream(
        DataConfig(cfg.vocab_size, args.seq_len + 1, args.global_batch)
    ).start()
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    hb = Heartbeat(args.ckpt_dir + "/hb", 0) if args.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        state, extra = ckpt.restore(ckpt.latest_step(), state)
        data.load_state_dict(extra["data"])
        start_step = extra["train_step"]
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(
        make_train_step(
            cfg, opt_cfg, mesh, sched, compress_grads=args.compress_grads
        ),
        donate_argnums=(0,),
    )

    with mesh:
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            state, metrics = step_fn(state, batch)
            if hb:
                hb.beat(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.device_get(metrics)
                dt = time.time() - t0
                print(
                    f"[train] step={step} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                    f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                    f"({dt / max(step - start_step + 1, 1):.2f}s/step)",
                    flush=True,
                )
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save_async(
                    step, state,
                    extra={"data": data.state_dict(), "train_step": step},
                )
        if ckpt:
            ckpt.save(
                args.steps, state,
                extra={"data": data.state_dict(), "train_step": args.steps},
            )
            ckpt.wait()
    data.stop()
    print("[train] done")


if __name__ == "__main__":
    main()
