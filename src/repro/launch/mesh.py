"""Production mesh definitions.

A function, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh", "make_serving_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = data if data is not None else max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(tp: int):
    """A (1, tp) ("data", "model") mesh over the FIRST ``tp`` devices.

    Unlike :func:`make_local_mesh` this takes a device subset, so a tp=2
    engine on an 8-device host (``--xla_force_host_platform_device_count``)
    uses exactly 2 devices — the shape tested by the sharded-serving bit-
    identity suite.  Data parallelism is replica-level (``serving.replica``
    runs one engine per replica), so the "data" axis stays 1 here.
    """
    devs = jax.devices()
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp > len(devs):
        raise ValueError(
            f"tp={tp} exceeds the {len(devs)} visible devices "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N for "
            "host-local meshes)"
        )
    from jax.sharding import Mesh

    return Mesh(np.array(devs[:tp]).reshape(1, tp), ("data", "model"))
