"""Production mesh definitions.

A function, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = data if data is not None else max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))
