"""Serving driver: batched requests through the slot engine.

CPU-smoke example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-110b --smoke \
      --requests 6 --max-new 16 --quant int4_packed
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from ..core.packed_linear import LinearSpec
from ..models import transformer as T
from ..models.registry import get_config
from ..serving.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--quant", default="native",
                    choices=["native", "int8", "int4_packed", "dsp_packed"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, quant=LinearSpec(mode=args.quant))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, ServeConfig(n_slots=args.slots, max_len=64))

    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(2, cfg.vocab_size, size=rng.integers(4, 10)))
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outputs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    for rid, toks in sorted(outputs.items()):
        print(f"[serve] request {rid}: {len(toks)} tokens -> {toks[:8]}...")
    print(f"[serve] {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s, quant={args.quant})")


if __name__ == "__main__":
    main()
