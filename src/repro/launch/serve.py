"""Serving driver: batched requests through the slot or paged engine.

CPU-smoke examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-110b --smoke \
      --requests 6 --max-new 16 --quant int4_packed --temperature 0.8
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-110b --smoke \
      --engine continuous --page-size 8 --stream --requests 6
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..models import transformer as T
from ..models.registry import get_config
from ..serving import (
    ContinuousEngine,
    Engine,
    ReplicaFront,
    SamplingParams,
    ServeConfig,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--engine", default="slot",
                    choices=["slot", "continuous"],
                    help="'slot' = fixed-slot FIFO over dense per-slot cache "
                         "windows; 'continuous' = continuous batching over "
                         "a paged KV cache (attention-only families)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="continuous: KV tokens per physical cache page")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="continuous: physical page pool size (default: "
                         "memory parity with the slot engine's windows)")
    ap.add_argument("--watermark-pages", type=int, default=None,
                    help="continuous: free-page floor admission keeps "
                         "(default: one growth page per lane)")
    ap.add_argument("--stream", action="store_true",
                    help="print (rid, token) pairs as they are emitted "
                         "instead of waiting for requests to finish")
    ap.add_argument("--quant", default="native",
                    choices=["native", "int8", "int4_packed", "dsp_packed",
                             "dsp_tuned", "dsp_mixed"])
    ap.add_argument("--error-budget", type=float, default=0.5,
                    help="dsp_tuned: max MAE per extraction a plan may incur")
    def _plan_bits(arg: str) -> tuple[int, int] | str:
        if arg == "auto":
            return "auto"
        try:
            a_bits, w_bits = (int(b) for b in arg.split(","))
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"--plan-bits wants two comma-separated ints 'A,W' "
                f"(e.g. 8,8) or 'auto', got {arg!r}"
            )
        return a_bits, w_bits

    ap.add_argument("--plan-bits", type=_plan_bits, default=(4, 4),
                    metavar="A,W|auto",
                    help="dsp_tuned: operand widths to plan for, e.g. 8,8 "
                         "(8-bit widths serve multi-DSP column-packed "
                         "plans); 'auto' allocates widths per layer by "
                         "measured sensitivity (= --quant dsp_mixed)")
    ap.add_argument("--mixed-budget", type=float, default=0.05,
                    help="dsp_mixed: model-level error budget (total added "
                         "logit-KL on the calibration forward) the greedy "
                         "per-layer width allocator may spend; 0 serves the "
                         "uniform widest-candidate plan")
    ap.add_argument("--calib-tokens", type=int, default=32,
                    help="dsp_mixed: calibration tokens per sequence for "
                         "the sensitivity pass (seeded from --seed)")
    ap.add_argument("--autotune-plans", action="store_true",
                    help="dsp_tuned: wall-clock block-size sweep per layer "
                         "shape and per serving phase (slower engine build, "
                         "measured ranking; decode GEMVs get their own "
                         "small-M blocks)")
    ap.add_argument("--no-prepack", dest="prepack", action="store_false",
                    help="skip building device-resident prepacked weight "
                         "operands at engine build (storage-only leaves; "
                         "decode falls back to per-step packing)")
    ap.add_argument("--fuse", dest="fuse_projections", default="none",
                    choices=["none", "mlp", "all"],
                    help="engine-build projection fusion for packed modes: "
                         "'mlp' fuses up|gate, 'all' also fuses q|k|v "
                         "(fused splits cost more than they save inside the "
                         "scanned CPU decode step — default 'none'; flip on "
                         "for TPU)")
    ap.add_argument("--plan-db", default=None, metavar="DIR",
                    help="persisted plan database directory "
                         "(tuning.plandb): engine build consults it before "
                         "running the dsp_tuned/dsp_mixed plan searches and "
                         "stores cold results back — a restarted engine "
                         "builds in seconds")
    ap.add_argument("--governor", action="store_true",
                    help="load-adaptive precision governor "
                         "(serving.governor): hold a uniformly-narrow "
                         "fallback weight tier beside the primary plan and "
                         "swap to it when the queue backs up — graceful "
                         "quality degradation instead of latency collapse "
                         "(dsp_tuned/dsp_mixed only)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request wall-clock deadline from submission; "
                         "requests past it are shed (finish_reason "
                         "'deadline') instead of occupying lanes")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard packed weights over "
                         "the first --tp devices (runtime.tp_packed; decode "
                         "stays bit-identical to --tp 1). CPU smoke: set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind a "
                         "join-shortest-queue front (serving.replica); each "
                         "replica is a full engine — combine with --tp for "
                         "2D scaling")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine_cls = ContinuousEngine if args.engine == "continuous" else Engine
    serve_cfg = ServeConfig(
        n_slots=args.slots, max_len=args.max_len,
        prefill_chunk=args.prefill_chunk, quant_mode=args.quant,
        seed=args.seed, error_budget=args.error_budget,
        autotune_plans=args.autotune_plans,
        plan_bits="auto" if args.quant == "dsp_mixed" else args.plan_bits,
        mixed_budget=args.mixed_budget,
        calib_tokens=args.calib_tokens,
        prepack=args.prepack,
        fuse_projections=args.fuse_projections,
        page_size=args.page_size,
        n_pages=args.n_pages,
        watermark_pages=args.watermark_pages,
        plan_db=args.plan_db,
        governor=args.governor,
        deadline_ms=args.deadline_ms,
        tp=args.tp,
    )
    if args.replicas > 1:
        if args.stream:
            raise SystemExit("--replicas does not support --stream "
                             "(per-replica token streams interleave)")
        front = ReplicaFront(cfg, params, serve_cfg,
                             n_replicas=args.replicas,
                             engine_cls=engine_cls)
        rng = np.random.default_rng(0)
        prompts = [
            list(rng.integers(2, cfg.vocab_size, size=rng.integers(4, 10)))
            for _ in range(args.requests)
        ]
        t0 = time.time()
        outputs = front.generate(prompts, max_new=args.max_new)
        dt = time.time() - t0
        total_tokens = sum(len(v) for v in outputs.values())
        for grid, toks in sorted(outputs.items()):
            print(f"[serve] request {grid} (replica "
                  f"{front.replica_of(grid)}): {len(toks)} tokens "
                  f"-> {toks[:8]}...")
        stats = front.stats()
        print(f"[serve] {total_tokens} tokens in {dt:.2f}s across "
              f"{stats['n_replicas']} replicas (tp={args.tp}, "
              f"quant={args.quant}, finished {stats['finished']})")
        return
    engine = engine_cls(cfg, params, serve_cfg)
    if engine.mixed_allocation is not None:
        alloc = engine.mixed_allocation
        print(f"[serve] mixed-precision allocation (budget "
              f"{alloc.budget:.4g}, predicted error "
              f"{alloc.predicted_error:.4g}, cost "
              f"{alloc.cost_vs_uniform_base:.2f}x uniform "
              f"a{alloc.base_bits[0]}w{alloc.base_bits[1]}):")
        for path, (a, w) in sorted(alloc.assignments.items()):
            print(f"[serve]   {path}: a{a}w{w} "
                  f"({alloc.plans[path].name})")
    if engine.plan_table:
        plans = {r.name for r in engine.plan_table.values()}
        print(f"[serve] tuned packing plans (budget {args.error_budget}): "
              + ", ".join(sorted(plans)))
        if args.autotune_plans:
            per_phase = {
                f"{r.name}: prefill {r.block} / decode {r.decode_block}"
                for r in engine.plan_table.values()
            }
            print("[serve] per-phase tuned blocks: "
                  + "; ".join(sorted(per_phase)))
    if engine.tiers is not None:
        print("[serve] governor tiers: " + "; ".join(
            f"{i}:{t.name} (certified MAE <= {t.max_certified_mae:g})"
            for i, t in enumerate(engine.tiers)))
    sampling = SamplingParams(args.temperature, args.top_k, args.top_p)

    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(2, cfg.vocab_size, size=rng.integers(4, 10)))
        for _ in range(args.requests)
    ]
    t0 = time.time()
    if args.stream:
        rids = [engine.submit(p, max_new=args.max_new, sampling=sampling,
                              admit=False) for p in prompts]
        while engine.active.any() or engine.scheduler.n_queued:
            engine.step()
            for rid, tok in engine.drain_stream():
                print(f"[stream] rid {rid} -> {tok}")
        outputs = {r: list(engine.scheduler.requests[r].tokens) for r in rids}
    else:
        outputs = engine.generate(prompts, max_new=args.max_new,
                                  sampling=sampling)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    for rid, toks in sorted(outputs.items()):
        reason = engine.scheduler.requests[rid].finish_reason
        print(f"[serve] request {rid}: {len(toks)} tokens ({reason}) "
              f"-> {toks[:8]}...")
    stats = engine.stats()
    print(f"[serve] {total_tokens} tokens in {dt:.2f}s "
          f"(engine={args.engine}, quant={engine.scfg.quant_mode}, "
          f"prefill {stats['prefill_tok_s']:.1f} tok/s, "
          f"decode {stats['decode_tok_s']:.1f} tok/s, "
          f"ttft p50 {stats['p50_ttft_s'] * 1e3:.0f}ms / "
          f"p99 {stats['p99_ttft_s'] * 1e3:.0f}ms, "
          f"mean latency {stats['mean_latency_s'] * 1e3:.0f}ms)")
    if args.engine == "continuous":
        print(f"[serve] pages: {stats['n_pages'] - stats['free_pages']}"
              f"/{stats['n_pages']} in use at exit "
              f"(page_size {stats['page_size']}, watermark "
              f"{stats['watermark_pages']}, "
              f"preempted {stats['preempted']})")
    if args.deadline_ms is not None:
        print(f"[serve] shed {stats['shed']} of "
              f"{stats['finished'] + stats['cancelled']} requests at the "
              f"{args.deadline_ms:.0f}ms deadline")
    if "plan_db" in stats:
        db = stats["plan_db"]
        warm = "warm" if db["hits"] else "cold"
        print(f"[serve] plan db {db['directory']}: {warm} build "
              f"({db['hits']} hit / {db['misses']} miss / "
              f"{db['stale']} stale, key {db['key'][:12]})")
    if "governor" in stats:
        g = stats["governor"]
        print(f"[serve] governor: tier {g['tier']} ({g['tier_name']}) "
              f"after {g['swaps']} swaps over {g['observations']} steps")


if __name__ == "__main__":
    main()
