"""Assigned input-shape sets and per-cell input_specs (ShapeDtypeStructs).

Four shapes per LM arch (40 cells total):
  train_4k     seq 4 096 × global_batch 256   → train_step
  prefill_32k  seq 32 768 × global_batch 32   → prefill_step
  decode_32k   KV depth 32 768 × batch 128    → serve_step
  long_500k    KV depth 524 288 × batch 1     → serve_step (sub-quadratic only)

``supported()`` encodes the DESIGN.md §5 skip table: ``long_500k`` needs a
sub-quadratic decode path (SWA ring buffer, SSM state, or hybrid), pure
full-attention archs skip it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "supported", "cache_specs"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None
        )
        if not sub_quadratic:
            return False, (
                "pure full-attention arch: 500k decode needs sub-quadratic "
                "attention (skip noted in DESIGN.md §5)"
            )
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), i32), "labels": _sds((b, s), i32)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, cfg.encoder_len, cfg.d_model), bf16)
        if cfg.family == "vlm":
            batch = {
                "tokens": _sds((b, s - cfg.n_patches), i32),
                "labels": _sds((b, s - cfg.n_patches), i32),
                "patches": _sds((b, cfg.n_patches, cfg.d_model), bf16),
            }
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), i32)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, cfg.encoder_len, cfg.d_model), bf16)
        if cfg.family == "vlm":
            batch = {
                "tokens": _sds((b, s - cfg.n_patches), i32),
                "patches": _sds((b, cfg.n_patches, cfg.d_model), bf16),
            }
        return batch
    # decode: one new token against a cache of depth s
    batch = {"tokens": _sds((b, 1), i32), "positions": _sds((b, 1), i32)}
    if cfg.family == "encdec":
        batch["encoder_out"] = _sds((b, cfg.encoder_len, cfg.d_model), bf16)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct tree for the decode cache of this cell."""
    from ..models import transformer as T

    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
