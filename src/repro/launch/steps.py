"""train_step / prefill_step / serve_step — the jit roots.

These are the functions the dry-run lowers for every (arch × shape × mesh)
cell and the train/serve drivers run for real.  Sharding constraints that
depend on the mesh are injected via the ``mesh`` argument; everything else
is pure model math.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

import contextlib

from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, adamw_update
from ..runtime.act_sharding import activation_sharding
from ..runtime.compression import compressed_grads
from ..runtime.sharding import logits_pspec


def _act_ctx(mesh, group_shardings=None):
    if mesh is None:
        return contextlib.nullcontext()
    return activation_sharding(mesh, group_shardings)

__all__ = ["loss_fn", "make_train_step", "make_prefill_step", "make_serve_step"]


def loss_fn(
    params,
    cfg: ModelConfig,
    batch: dict[str, jax.Array],
    mesh=None,
    aux_weight: float = 0.01,
    z_weight: float = 1e-4,
):
    """Next-token CE (+ MoE aux + z-loss).  Logits stay vocab-sharded."""
    kw: dict[str, Any] = {}
    if cfg.family == "encdec":
        kw["encoder_out"] = T.encode(params, cfg, batch["frames"])
    if cfg.family == "vlm":
        kw["patch_embeds"] = batch["patches"]
    logits, _, aux = T.forward(params, cfg, batch["tokens"], **kw)
    if mesh is not None:
        logits = jax.lax.with_sharding_constraint(
            logits,
            NamedSharding(mesh, logits_pspec(mesh, batch["tokens"].shape[0])),
        )
    labels = batch["labels"]
    if cfg.family == "vlm":  # patches carry no labels
        logits = logits[:, -labels.shape[1] :]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - gold)
    z = jnp.mean(jnp.square(lse))
    return ce + aux_weight * aux + z_weight * z, {"ce": ce, "aux": aux}


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh=None,
    lr_schedule=None,
    compress_grads: bool = False,
    grad_shardings=None,
    microbatches: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", ("error_buf")}.  Gradient compression
    (int8 + error feedback) applies between grad and optimizer — the
    cross-pod reduction then carries int8-representable values.
    ``microbatches > 1`` = gradient accumulation: the global batch is
    processed in sequential slices, dividing activation memory by the
    slice count (the loop is unrolled so XLA cost analysis stays exact).
    """

    compute_dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]

    def cast_for_compute(p):
        # mixed-precision FSDP: matrices are all-gathered in bf16 (half the
        # collective bytes); small/1-D leaves stay f32 (norms, biases).
        if p.dtype == jnp.float32 and p.ndim > 1:
            return p.astype(compute_dtype)
        return p

    def train_step(state, batch):
        def loss_of(p, b):
            pc = jax.tree.map(cast_for_compute, p)
            with _act_ctx(mesh):
                return loss_fn(pc, cfg, b, mesh)

        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                state["params"], batch
            )
        else:
            losses, grads, metrics = [], None, None
            for i in range(microbatches):  # unrolled accumulation
                mb = {
                    k: v.reshape(microbatches, -1, *v.shape[1:])[i]
                    for k, v in batch.items()
                }
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                    state["params"], mb
                )
                losses.append(l)
                metrics = m
                grads = (
                    g
                    if grads is None
                    else jax.tree.map(jnp.add, grads, g)
                )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = sum(losses) / microbatches
        if grad_shardings is not None:
            # pin gradients to the parameter (FSDP) layout right at the
            # autodiff boundary: XLA then emits reduce-scatter instead of
            # all-reduce + slice for the data-parallel reduction
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads,
                grad_shardings,
            )
        if compress_grads:
            grads, new_err = compressed_grads(grads, state["error_buf"])
        new_params, new_opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg, lr_schedule
        )
        new_state = {"params": new_params, "opt": new_opt}
        if compress_grads:
            new_state["error_buf"] = new_err
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None, group_shardings=None):
    """Full-sequence forward → (last-position logits).  Inference prefill."""

    def prefill_step(params, batch):
        kw: dict[str, Any] = {}
        with _act_ctx(mesh, group_shardings):
            if cfg.family == "encdec":
                kw["encoder_out"] = T.encode(params, cfg, batch["frames"])
            if cfg.family == "vlm":
                kw["patch_embeds"] = batch["patches"]
            logits, _, _ = T.forward(params, cfg, batch["tokens"], **kw)
            return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None, group_shardings=None):
    """One decode step over a KV/state cache of ``seq_len`` depth."""

    def serve_step(params, cache, batch):
        kw: dict[str, Any] = {}
        with _act_ctx(mesh, group_shardings):
            if cfg.family == "encdec":
                kw["encoder_out"] = batch["encoder_out"]
            logits, new_cache, _ = T.forward(
                params,
                cfg,
                batch["tokens"],
                positions=batch["positions"],
                cache=cache,
                **kw,
            )
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return next_tok, new_cache

    return serve_step
