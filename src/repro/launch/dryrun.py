"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces the compiled SPMD executable on 512 (or 256)
placeholder host devices and records:
  * ``memory_analysis``  — per-device bytes (proves the sharding fits HBM)
  * ``cost_analysis``    — HLO FLOPs / bytes accessed (roofline numerator)
  * collective bytes     — parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and are
consumed by ``benchmarks/roofline.py`` and EXPERIMENTS.md §Dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
      --shape train_4k [--multi-pod] [--all] [--out artifacts/dryrun]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import dataclasses
import json
import math
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import transformer as T
from ..models.registry import get_config, list_archs
from ..optim.adamw import AdamWConfig, adamw_init
from ..runtime.sharding import (
    cache_pspec,
    fsdp_axes,
    param_shardings,
)
from .mesh import make_production_mesh
from .shapes import SHAPES, cache_specs, input_specs, supported
from .steps import make_prefill_step, make_serve_step, make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> float:
    """'f32[256,8192]' -> bytes."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0.0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result bytes of every collective op in the optimized HLO."""
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    shape_re = re.compile(r"[a-z0-9]+\[[0-9,]*\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*?) ([a-z\-]+-start|[a-z\-]+)\(", stripped)
        if not m:
            continue
        shapes_part, opname = m.groups()
        opname = opname.removesuffix("-start")
        if opname not in _COLLECTIVES:
            continue
        total = sum(_shape_bytes(s) for s in shape_re.findall(shapes_part))
        out[opname] += total
    out["total"] = sum(out.values())
    return out


def _mem_dict(mem) -> dict:
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


VARIANTS = (
    "baseline", "flash", "tp_serve", "int4_serve", "flash_rs", "mb4",
    "flash_mb4", "tp_fix", "tp_fix_flash",
)


def _apply_variant(cfg, variant: str):
    if variant in ("flash", "flash_rs", "tp_fix_flash"):
        return dataclasses.replace(cfg, attention_chunk=1024)
    if variant == "mb4":
        return dataclasses.replace(cfg, remat="full")
    if variant == "flash_mb4":
        return dataclasses.replace(cfg, attention_chunk=1024, remat="full")
    return cfg


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    smoke: bool = False,
    depth_groups: int | None = None,
    variant: str = "baseline",
):
    """Build the jitted step for one cell and lower it.

    ``depth_groups`` replaces the model with an UNROLLED ``k``-group-deep
    variant — used to derive exact per-group cost increments, because XLA's
    cost analysis counts a while-loop (scan) body once regardless of trip
    count.  ``None`` = the real full-depth scanned model.

    ``variant`` selects a §Perf optimization (see VARIANTS): ``flash`` =
    chunked online-softmax attention; ``tp_serve`` = TP-only serving params;
    ``int4_serve`` = packed int4 serving weights + TP-only.
    """
    cfg = _apply_variant(get_config(arch, smoke=smoke), variant)
    if depth_groups is not None:
        enc = (
            {"n_encoder_layers": depth_groups}
            if cfg.family == "encdec"
            else {}
        )
        cfg = dataclasses.replace(
            cfg,
            n_layers=depth_groups * cfg.group_size,
            scan_layers=False,
            **enc,
        )
    shape = SHAPES[shape_name]
    ok, reason = supported(cfg, shape)
    if not ok:
        raise ValueError(f"unsupported cell: {reason}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    fsdp = fsdp_axes(mesh)
    fs = fsdp if len(fsdp) > 1 else fsdp[0]
    batch = input_specs(cfg, shape)
    repl = NamedSharding(mesh, P())

    def batch_shardings(tree):
        out = {}
        for k, v in tree.items():
            spec = [None] * len(v.shape)
            if v.shape[0] % (2 * 16 if multi_pod else 16) == 0:
                spec[0] = fs
            out[k] = NamedSharding(mesh, P(*spec))
        return out

    if shape.kind == "train":
        params_shape = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        )
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
        p_shard = param_shardings(params_shape, mesh)
        state_shard = {
            "params": p_shard,
            "opt": {
                "m": p_shard,
                "v": p_shard,
                "step": repl,
            },
        }
        state_shape = {"params": params_shape, "opt": opt_shape}
        step_fn = make_train_step(
            cfg, AdamWConfig(), mesh,
            grad_shardings=p_shard if variant == "flash_rs" else None,
            microbatches=4 if variant in ("mb4", "flash_mb4") else 1,
        )
        in_shardings = (state_shard, batch_shardings(batch))
        jitted = jax.jit(
            step_fn,
            in_shardings=in_shardings,
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_shape, batch)
        return lowered, cfg, mesh

    serving_tp_only = variant in ("tp_serve", "int4_serve")
    if variant == "int4_serve":
        from ..core.packed_params import quantize_params_for_serving

        params_shape = jax.eval_shape(
            lambda: quantize_params_for_serving(
                T.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
            )
        )
    else:
        params_shape = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
        )
    p_shard = param_shardings(params_shape, mesh, serving=serving_tp_only)

    def sliced_group_shardings():
        if "groups" not in params_shape:
            return None
        sliced = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
            params_shape["groups"],
        )
        return param_shardings(sliced, mesh, serving=serving_tp_only)

    if shape.kind == "prefill":
        step_fn = make_prefill_step(cfg, mesh, sliced_group_shardings())
        jitted = jax.jit(
            step_fn, in_shardings=(p_shard, batch_shardings(batch))
        )
        lowered = jitted.lower(params_shape, batch)
        return lowered, cfg, mesh

    # decode
    cache_shape = cache_specs(cfg, shape)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    c_shard = jax.tree.unflatten(
        treedef,
        [
            NamedSharding(
                mesh,
                cache_pspec(
                    mesh, leaf.shape, shape.global_batch,
                    path="/".join(
                        str(getattr(q, "key", getattr(q, "idx", q))) for q in pth
                    ),
                ),
            )
            for pth, leaf in flat
        ],
    )
    step_fn = make_serve_step(cfg, mesh, sliced_group_shardings())
    jitted = jax.jit(
        step_fn,
        in_shardings=(p_shard, c_shard, batch_shardings(input_specs(cfg, shape))),
        out_shardings=(None, c_shard),
        donate_argnums=(1,),
    )
    lowered = jitted.lower(params_shape, cache_shape, input_specs(cfg, shape))
    return lowered, cfg, mesh


def _packing_plan_verdicts(cfg) -> dict:
    """Per-layer serving plan + certificate verdict, from shapes alone.

    ``plan_linear_layers`` only reads leaf shapes, so the abstract
    ``eval_shape`` tree is enough — no weights are materialized at dry-run
    scale.  Each row carries the selected plan name and its certificate's
    exact/bounded verdict (plus the certified per-extraction WCE when
    bounded), mirroring what ``quantize_for_serving`` would build."""
    from ..tuning.tuner import plan_linear_layers

    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    )
    out = {}
    for lpath, report in plan_linear_layers(params_shape).items():
        cert = report.certificate
        out[lpath] = {
            "plan": report.name,
            "verdict": cert.verdict,
            "wce_per_extraction": cert.wce_per_extraction,
        }
    return out


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, out_dir: str,
    smoke: bool = False, variant: str = "baseline",
) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if variant != "baseline":
        tag += f"__{variant}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{tag}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False,
        "variant": variant,
    }
    try:
        # 1) full-depth scanned model: proves sharding/memory at 256/512 dev
        lowered, cfg, mesh = lower_cell(arch, shape_name, multi_pod, smoke,
                                        variant=variant)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        def _cost(compiled_exe):
            cost = compiled_exe.cost_analysis() or {}
            if isinstance(cost, (list, tuple)):
                # older jaxlib returns a one-element list of dicts
                cost = cost[0] if cost else {}
            coll = collective_bytes(compiled_exe.as_text())
            return {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": coll,
            }

        # 2) unrolled 1-group / 2-group variants: exact per-group increments
        #    (XLA cost analysis counts a scan body once, so the full-depth
        #    numbers must be reconstructed as f1 + (G-1)·(f2-f1)).
        c1 = _cost(lower_cell(arch, shape_name, multi_pod, smoke, 1,
                              variant=variant)[0].compile())
        c2 = _cost(lower_cell(arch, shape_name, multi_pod, smoke, 2,
                              variant=variant)[0].compile())
        groups = cfg.n_groups

        def extrap(key):
            return c1[key] + (groups - 1) * (c2[key] - c1[key])

        coll = {
            k: c1["coll"][k] + (groups - 1) * (c2["coll"][k] - c1["coll"][k])
            for k in c1["coll"]
        }
        mem = _mem_dict(compiled.memory_analysis())
        n_params = sum(
            math.prod(leaf.shape)
            for leaf in jax.tree.leaves(
                jax.eval_shape(
                    lambda: T.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
                )
            )
        )
        record.update(
            ok=True,
            flops=extrap("flops"),
            bytes_accessed=extrap("bytes"),
            collectives=coll,
            scan_body={"flops_1g": c1["flops"], "flops_2g": c2["flops"]},
            memory=mem,
            n_devices=int(mesh.devices.size),
            n_params=int(n_params),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
        )
        print(f"[dryrun] {tag}: OK flops/dev={record['flops']:.3e} "
              f"coll={coll['total']:.3e}B lower={t_lower:.0f}s compile={t_compile:.0f}s",
              flush=True)
        if variant == "int4_serve":
            # which plan each layer would serve, with its static error
            # pedigree — the registry-config projection of exact vs
            # bounded serving arithmetic (non-fatal: a planning failure
            # must not mask a successful lowering)
            try:
                record["packing_plans"] = _packing_plan_verdicts(cfg)
                for lpath, row in sorted(record["packing_plans"].items()):
                    extra = (
                        "" if row["verdict"] == "exact" else
                        f" wce/extraction={row['wce_per_extraction']}"
                    )
                    print(f"[dryrun]   {lpath}: {row['plan']} "
                          f"[{row['verdict']}{extra}]", flush=True)
            except Exception as e:  # noqa: BLE001
                record["packing_plans_error"] = f"{type(e).__name__}: {e}"
                print(f"[dryrun]   packing plans unavailable: {e}",
                      flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure for the report
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {tag}: FAIL {record['error'][:200]}", flush=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


PACKED_TP_ARCHS = ("qwen1.5-110b", "dbrx-132b", "jamba-v0.1-52b")


def packed_tp_projection(arch: str, tp: int, smoke: bool = False) -> dict:
    """Per-shard packed-serving memory + interconnect projection, from
    shapes alone.

    Projects what ``runtime.tp_packed.shard_params_tp`` would place on
    each of ``tp`` devices — prepacked int32 weight words plus per-column
    scales — and what one decode step moves over the interconnect, without
    constructing a single weight: the tree is ``jax.eval_shape`` abstract
    and every number below is arithmetic on leaf shapes.

    Accounting (per (…, K, N) packable leaf, ``lead`` = stacked dims):

    * words HBM: ``lead · K/2 · N · 4`` bytes (two int4 pairs per int32
      word, the prepacked operand layout) — divided by ``tp`` along N for
      column-parallel leaves and along K for row-parallel ones, when the
      axis divides; otherwise the leaf replicates.
    * scales: ``lead · N · 4`` bytes, replicated (per-output-channel).
    * decode interconnect, batch row ``m=1``: column-parallel leaves
      all-gather their output row (ring: ``(tp-1)/tp · N·4`` bytes);
      row-parallel leaves all-reduce the accumulator row (ring:
      ``2·(tp-1)/tp · N·4`` bytes).  Replicated leaves move nothing.
    """
    from ..core.packed_params import iter_packable_weights
    from ..runtime.sharding import linear_partition

    cfg = get_config(arch, smoke=smoke)
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    )
    rows = {}
    tot_shard = tot_repl = tot_net = 0.0
    for path, leaf in iter_packable_weights(params_shape):
        *lead, k_dim, n_dim = leaf.shape
        lead_n = math.prod(lead) if lead else 1
        words = lead_n * (k_dim // 2) * n_dim * 4.0
        scales = lead_n * n_dim * 4.0
        kind = linear_partition(path)
        if kind == "col" and n_dim % tp == 0:
            shard, net = words / tp, (tp - 1) / tp * n_dim * 4.0
        elif kind == "row" and k_dim % tp == 0:
            shard, net = words / tp, 2 * (tp - 1) / tp * n_dim * 4.0
        else:
            kind, shard, net = "replicate", words, 0.0
        rows[path] = {
            "shape": list(leaf.shape), "partition": kind,
            "words_bytes_per_shard": shard, "scale_bytes": scales,
            "decode_net_bytes": lead_n * net,
        }
        tot_shard += shard
        tot_repl += scales
        tot_net += lead_n * net
    return {
        "arch": arch, "tp": tp,
        "packed_words_bytes_per_shard": tot_shard,
        "replicated_scale_bytes": tot_repl,
        "decode_step_interconnect_bytes": tot_net,
        "layers": rows,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="every supported cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--packed-tp", type=int, default=None, metavar="N",
                    help="project per-shard packed-weight HBM and per-"
                         "decode-step interconnect bytes for an N-way "
                         "tensor-parallel packed engine (shapes only, no "
                         "weights; default archs: "
                         + ", ".join(PACKED_TP_ARCHS) + ")")
    args = ap.parse_args()

    if args.packed_tp is not None:
        archs = [args.arch] if args.arch else list(PACKED_TP_ARCHS)
        for arch in archs:
            rec = packed_tp_projection(arch, args.packed_tp, args.smoke)
            gib = 1 << 30
            print(f"[dryrun] {arch} packed tp={args.packed_tp}: "
                  f"{rec['packed_words_bytes_per_shard'] / gib:.2f} GiB "
                  f"packed words/shard + "
                  f"{rec['replicated_scale_bytes'] / gib:.3f} GiB "
                  f"replicated scales, "
                  f"{rec['decode_step_interconnect_bytes'] / 1e6:.2f} MB "
                  f"interconnect per decode row", flush=True)
            os.makedirs(args.out, exist_ok=True)
            path = os.path.join(
                args.out, f"{arch}__packed_tp{args.packed_tp}.json"
            )
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        return

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        n_ok = n_skip = n_fail = 0
        for arch in list_archs():
            cfg = get_config(arch)
            for shape_name, shape in SHAPES.items():
                ok, reason = supported(cfg, shape)
                if not ok:
                    n_skip += 1
                    print(f"[dryrun] {arch}__{shape_name}: SKIP ({reason})", flush=True)
                    continue
                for mp in meshes:
                    rec = run_cell(arch, shape_name, mp, args.out, args.smoke,
                                   args.variant)
                    n_ok += rec["ok"]
                    n_fail += not rec["ok"]
        print(f"[dryrun] done: ok={n_ok} fail={n_fail} skipped-cells={n_skip}")
        return

    for mp in meshes:
        rec = run_cell(args.arch, args.shape, mp, args.out, args.smoke,
                       args.variant)
        if rec["ok"]:
            print(json.dumps({k: v for k, v in rec.items() if k != "traceback"}, indent=1))


if __name__ == "__main__":
    main()
