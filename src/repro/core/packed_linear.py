"""PackedLinear: every matmul in the model zoo routes through this module.

One module, five compute modes — the paper's technique is a first-class,
config-selectable feature of the framework rather than a bolt-on:

  * ``native``      — plain dense matmul (bf16/f32), the unquantized baseline
  * ``qat4``/``qat8`` — fake-quant STE on weights (+ optionally activations):
                      differentiable, used for quantization-aware *training*
  * ``int8``        — real int8×int8→int32 arithmetic (MXU-native path)
  * ``int4_packed`` — packed-nibble storage + production Pallas kernel
  * ``dsp_packed``  — the paper's pair-packed wide-multiply path (Pallas),
                      correction scheme selectable via ``PackedDotSpec``
  * ``dsp_tuned``   — per-layer tuned pair-packed plans: weights arrive as
                      ``DspTunedLeaf`` (quantized once at engine build, plan
                      attached as static aux) and each layer runs ITS plan's
                      arithmetic; float leaves under this mode fall back to
                      the native matmul (only packable weights get plans)

Inference-only integer paths raise under differentiation by construction
(they are used inside ``serve_step``).  Params are plain pytrees (plus the
registered ``DspTunedLeaf`` node for tuned weights).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..kernels import ops, ref
from ..kernels.ref import INT4_EXACT, PackedDotSpec
from .quantize import fake_quant_signed, quantize_signed

__all__ = ["LinearSpec", "init_linear", "apply_linear"]

MODES = ("native", "qat4", "qat8", "int8", "int4_packed", "dsp_packed",
         "dsp_tuned")


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    mode: str = "native"
    dsp_spec: PackedDotSpec = INT4_EXACT
    use_kernel: bool = False  # Pallas kernel vs jnp ref (CPU tests use ref)
    act_bits: int | None = None  # fake-quant activations too (QAT)

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")


def init_linear(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
    scale = d_in**-0.5
    params = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
    return params


def _flatten_batch(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def apply_linear(params, x: jax.Array, spec: LinearSpec = LinearSpec()) -> jax.Array:
    """``x @ w (+ b)`` through the selected compute mode."""
    from .packed_params import (
        is_dsp_tuned_leaf,
        is_packed_leaf,
        materialize_weight,
    )

    w = params["w"]
    mode = spec.mode
    if type(w).__name__ == "TpLinear":
        # tensor-parallel serving: the leaf was mesh-partitioned at engine
        # build (runtime.tp_packed.shard_params_tp); the wrapper carries
        # the partition kind and runs the shard_map'd arithmetic
        from ..runtime.tp_packed import TpLinear, apply_tp_linear

        if isinstance(w, TpLinear):
            x2, lead = _flatten_batch(x.astype(jnp.float32))
            y = apply_tp_linear(w, x2, spec)
            n_out = y.shape[-1]
            y = y.reshape(*lead, n_out).astype(x.dtype)
            if "b" in params:
                y = y + params["b"].astype(y.dtype)
            return y
    if is_dsp_tuned_leaf(w):
        if w.payload.ndim == 2:
            # serving decode path: this layer's tuned plan rides on the leaf
            # (static aux), weights were quantized once at engine build
            x2, lead = _flatten_batch(x.astype(jnp.float32))
            n_out = w.scale.shape[-1]
            if w.prepacked:
                # prepacked fast path: words/zp built once, nothing repacks;
                # proven-exact plans additionally take the f32-GEMM shortcut
                # (bit-identical — see ops.dsp_tuned_matmul_prepacked_f32)
                y = ops.dsp_tuned_matmul_prepacked_f32(
                    x2, w.words, w.wsc, w.zp_row, w.scale, w.w_f32,
                    spec=w.spec, block=w.block_for(x2.shape[0]),
                    use_kernel=spec.use_kernel,
                    exact_f32=w.w_f32 is not None and not spec.use_kernel,
                )
            else:
                y = ops.dsp_tuned_matmul_f32(
                    x2, w.values, w.scale, spec=w.spec,
                    block=w.block or (128, 128, 128),
                    use_kernel=spec.use_kernel,
                )
            y = y.reshape(*lead, n_out).astype(x.dtype)
        else:
            # stacked leaves outside a layer scan: dequantize at use
            y = x @ materialize_weight(w, x.dtype)
        if "b" in params:
            y = y + params["b"].astype(y.dtype)
        return y
    if is_packed_leaf(w):
        if mode == "int4_packed" and w["packed"].ndim == 2:
            x2, lead = _flatten_batch(x.astype(jnp.float32))
            if "w_f32" in w and not spec.use_kernel:
                # prepacked fast path: the nibble grid was decoded once at
                # engine build; the f32 GEMM computes the exact int8×int4
                # matmul (bit-identical to the unpack+int-dot path)
                y = ops.int4_prepacked_matmul_f32(x2, w["w_f32"], w["scale"])
            else:
                # run the production packed kernel straight off the stored
                # nibbles — no per-call repack
                y = ops.int4_matmul_f32(
                    x2, w["packed"], w["scale"], use_kernel=spec.use_kernel
                )
            y = y.reshape(*lead, w["packed"].shape[-1]).astype(x.dtype)
        else:
            # packed-storage representation under a float compute mode:
            # nibbles live in HBM, dequantize at the point of use (fused
            # into the matmul on TPU)
            y = x @ materialize_weight(w, x.dtype)
        if "b" in params:
            y = y + params["b"].astype(y.dtype)
        return y
    if mode in ("native", "dsp_tuned"):
        # dsp_tuned reaching a float leaf means the weight was not packable
        # (tiny, odd-shaped, embedding): serve it natively
        y = x @ w.astype(x.dtype)
    elif mode in ("qat4", "qat8"):
        bits = 4 if mode == "qat4" else 8
        wq = fake_quant_signed(w.astype(jnp.float32), bits, 0).astype(x.dtype)
        xq = (
            fake_quant_signed(x.astype(jnp.float32), spec.act_bits, -1).astype(x.dtype)
            if spec.act_bits
            else x
        )
        y = xq @ wq
    elif mode == "int8":
        x2, lead = _flatten_batch(x.astype(jnp.float32))
        xq = quantize_signed(x2, bits=8, axis=-1)
        wq = quantize_signed(w.astype(jnp.float32), bits=8, axis=0)
        acc = ref.ref_quantized_matmul(xq.values, wq.values)
        y = (acc.astype(jnp.float32) * xq.scale * wq.scale).reshape(
            *lead, w.shape[1]
        ).astype(x.dtype)
    elif mode == "int4_packed":
        x2, lead = _flatten_batch(x.astype(jnp.float32))
        wq = quantize_signed(w.astype(jnp.float32), bits=4, axis=0)
        packed = ref.pack_int4_weights(wq.values)
        y = ops.int4_matmul_f32(
            x2, packed, wq.scale, use_kernel=spec.use_kernel
        ).reshape(*lead, w.shape[1]).astype(x.dtype)
    elif mode == "dsp_packed":
        x2, lead = _flatten_batch(x.astype(jnp.float32))
        y = ops.packed_matmul_f32(
            x2, w.astype(jnp.float32), spec=spec.dsp_spec,
            use_kernel=spec.use_kernel,
        ).reshape(*lead, w.shape[1]).astype(x.dtype)
    else:  # pragma: no cover
        raise AssertionError(mode)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y
