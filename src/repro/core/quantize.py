"""Quantizers feeding the DSP-packing compute paths.

The packing scheme wants *unsigned* activations and *signed* weights
(paper §III).  Signed activations are handled with an offset-binary zero
point ``zp = 2**(bits-1)``; the resulting constant ``zp * Σ_k w[k, n]`` is
folded out of the matmul once per output channel (``zero_point_correction``).

``fake_quant_*`` are straight-through-estimator (STE) versions for QAT: the
forward pass quantize→dequantizes, the backward pass is the identity inside
the clipping range.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "QuantizedTensor",
    "quantize_signed",
    "quantize_unsigned",
    "dequantize",
    "fake_quant_signed",
    "fake_quant_unsigned",
    "zero_point_correction",
]


@dataclasses.dataclass
class QuantizedTensor:
    """Integer payload + per-channel scale (+ zero point for unsigned)."""

    values: jax.Array  # int8 payload (narrow values stored widened)
    scale: jax.Array  # f32, broadcastable against values along `axis`
    bits: int
    zero_point: int = 0  # 0 for signed; 2**(bits-1) for unsigned

    def dequantize(self) -> jax.Array:
        return (self.values.astype(jnp.float32) - self.zero_point) * self.scale


jax.tree_util.register_dataclass(
    QuantizedTensor,
    data_fields=["values", "scale"],
    meta_fields=["bits", "zero_point"],
)


def _absmax_scale(x: jax.Array, axis, qmax: int) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_signed(x: jax.Array, bits: int = 4, axis=-1) -> QuantizedTensor:
    """Symmetric signed quantization: values in ``[-2^(b-1), 2^(b-1)-1]``."""
    qmax = (1 << (bits - 1)) - 1
    scale = _absmax_scale(x, axis, qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return QuantizedTensor(q, scale, bits=bits, zero_point=0)


def quantize_unsigned(x: jax.Array, bits: int = 4, axis=-1) -> QuantizedTensor:
    """Offset-binary quantization: values in ``[0, 2^b - 1]``, zp at mid.

    The payload is uint8: an int8 store would saturate the upper half of the
    8-bit offset-binary range (float→int8 conversion clamps at 127, so every
    value above the zero point collapsed — a silent a8 activation bug the
    prepacked kernel's fused-quantize parity check caught)."""
    zp = 1 << (bits - 1)
    qmax = zp - 1
    scale = _absmax_scale(x, axis, qmax)
    q = jnp.clip(jnp.round(x / scale) + zp, 0, (1 << bits) - 1).astype(jnp.uint8)
    return QuantizedTensor(q, scale, bits=bits, zero_point=zp)


def dequantize(q: QuantizedTensor) -> jax.Array:
    return q.dequantize()


def zero_point_correction(w_q: jax.Array, zp: int) -> jax.Array:
    """``zp * Σ_k w[k, n]`` — folded back after an unsigned×signed matmul.

    With ``a_u = a + zp``: ``a·w = a_u·w − zp·Σ w`` per output channel; the
    packed path computes ``a_u·w`` and this term restores the true product.
    """
    return zp * jnp.sum(w_q.astype(jnp.int32), axis=0)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant_signed(x: jax.Array, bits: int = 4, axis=-1) -> jax.Array:
    q = quantize_signed(x, bits=bits, axis=axis)
    return q.dequantize().astype(x.dtype)


def _fq_signed_fwd(x, bits, axis):
    qmax = (1 << (bits - 1)) - 1
    scale = _absmax_scale(x, axis, qmax)
    mask = (jnp.abs(x) <= scale * (qmax + 1)).astype(x.dtype)
    return fake_quant_signed(x, bits, axis), mask


def _fq_signed_bwd(bits, axis, mask, g):
    return (g * mask,)


fake_quant_signed.defvjp(_fq_signed_fwd, _fq_signed_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant_unsigned(x: jax.Array, bits: int = 4, axis=-1) -> jax.Array:
    q = quantize_unsigned(x, bits=bits, axis=axis)
    return q.dequantize().astype(x.dtype)


def _fq_unsigned_fwd(x, bits, axis):
    zp = 1 << (bits - 1)
    scale = _absmax_scale(x, axis, zp - 1)
    mask = (jnp.abs(x) <= scale * zp).astype(x.dtype)
    return fake_quant_unsigned(x, bits, axis), mask


def _fq_unsigned_bwd(bits, axis, mask, g):
    return (g * mask,)


fake_quant_unsigned.defvjp(_fq_unsigned_fwd, _fq_unsigned_bwd)
