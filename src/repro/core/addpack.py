"""Addition packing: several narrow adders in one wide accumulator (§VII).

Packs ``k`` narrow additions as bit fields of one 48-bit add (Fig. 7).  A
lane only errs when the lane below it carries out across the field boundary,
which corrupts the victim lane's LSB (worst-case absolute error 1).  One
guard bit between lanes catches the carry and makes every lane exact
(Fig. 8) at the cost of one payload bit per boundary.

The paper's motivating application is Spiking Neural Networks, whose main
operation is accumulation rather than MAC; :func:`accumulate` provides a
chunked accumulator that extracts lanes before any field can overflow.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .packing import sign_extend

__all__ = ["AddPackConfig", "pack_lanes", "packed_add", "extract_lanes", "accumulate"]


@dataclasses.dataclass(frozen=True)
class AddPackConfig:
    """Lane layout for addition packing.

    ``lane_widths[i]`` payload bits per lane, ``guard_bits`` zero bits
    inserted between lanes (0 = the approximate scheme of Table III),
    ``signed`` lanes are interpreted in two's complement.
    """

    lane_widths: tuple[int, ...]
    guard_bits: int = 0
    total_bits: int = 48
    signed: bool = True

    def __post_init__(self) -> None:
        if self.bits_used() > self.total_bits:
            raise ValueError(
                f"lanes need {self.bits_used()} bits > accumulator "
                f"{self.total_bits}"
            )

    @property
    def n_lanes(self) -> int:
        return len(self.lane_widths)

    @property
    def offsets(self) -> tuple[int, ...]:
        out, off = [], 0
        for width in self.lane_widths:
            out.append(off)
            off += width + self.guard_bits
        return tuple(out)

    def bits_used(self) -> int:
        return sum(self.lane_widths) + self.guard_bits * (self.n_lanes - 1)

    def packing_density(self) -> float:
        return sum(self.lane_widths) / self.total_bits


def five_by_nine() -> AddPackConfig:
    """The paper's example: five 9-bit adders, no guard bits (Table III)."""
    return AddPackConfig(lane_widths=(9,) * 5, guard_bits=0)


def _field(cfg: AddPackConfig, x: np.ndarray, i: int) -> np.ndarray:
    mask = np.int64((1 << cfg.lane_widths[i]) - 1)
    return np.asarray(x, dtype=np.int64) & mask


def pack_lanes(cfg: AddPackConfig, x: np.ndarray) -> np.ndarray:
    """Place each lane's two's-complement field at its offset (Fig. 7)."""
    x = np.asarray(x, dtype=np.int64)
    if x.shape[-1] != cfg.n_lanes:
        raise ValueError(f"x last dim {x.shape[-1]} != {cfg.n_lanes}")
    out = np.zeros(x.shape[:-1], dtype=np.int64)
    for i, off in enumerate(cfg.offsets):
        out = out + (_field(cfg, x[..., i], i) << np.int64(off))
    return out


def packed_add(cfg: AddPackConfig, p: np.ndarray, q: np.ndarray) -> np.ndarray:
    """One wide addition, wrapped to the accumulator width."""
    total = np.int64((1 << cfg.total_bits) - 1)
    return (np.asarray(p, np.int64) + np.asarray(q, np.int64)) & total


def extract_lanes(cfg: AddPackConfig, p: np.ndarray) -> np.ndarray:
    """Slice lane fields back out of the accumulator."""
    p = np.asarray(p, dtype=np.int64)
    lanes = []
    for i, off in enumerate(cfg.offsets):
        field = (p >> np.int64(off)) & np.int64((1 << cfg.lane_widths[i]) - 1)
        lanes.append(
            sign_extend(field, cfg.lane_widths[i]) if cfg.signed else field
        )
    return np.stack(lanes, axis=-1)


def lane_add_expected(cfg: AddPackConfig, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """What k standalone narrow adders would produce (wrap per lane)."""
    s = np.asarray(x, np.int64) + np.asarray(y, np.int64)
    cols = []
    for i in range(cfg.n_lanes):
        field = s[..., i] & np.int64((1 << cfg.lane_widths[i]) - 1)
        cols.append(
            sign_extend(field, cfg.lane_widths[i]) if cfg.signed else field
        )
    return np.stack(cols, axis=-1)


def packed_lane_add(cfg: AddPackConfig, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """End-to-end: pack both operand vectors, add once, extract lanes."""
    return extract_lanes(cfg, packed_add(cfg, pack_lanes(cfg, x), pack_lanes(cfg, y)))


def accumulate(
    cfg: AddPackConfig, terms: np.ndarray, headroom_bits: int | None = None
) -> np.ndarray:
    """Accumulate ``terms[..., t, lane]`` over ``t`` in the packed adder.

    SNN-style accumulation.  With ``guard_bits = g`` a lane can absorb
    ``2**g`` worst-case carries error-free; accumulation therefore runs in
    chunks of ``2**guard_bits`` packed adds between extractions, and chunk
    results are combined exactly outside the accumulator.
    """
    terms = np.asarray(terms, dtype=np.int64)
    chunk = 2 ** (cfg.guard_bits if headroom_bits is None else headroom_bits)
    steps = terms.shape[-2]
    total = np.zeros(terms.shape[:-2] + (cfg.n_lanes,), dtype=np.int64)
    for start in range(0, steps, max(chunk, 1)):
        acc = np.zeros(terms.shape[:-2], dtype=np.int64)
        for t in range(start, min(start + max(chunk, 1), steps)):
            acc = packed_add(cfg, acc, pack_lanes(cfg, terms[..., t, :]))
        total = total + extract_lanes(cfg, acc)
    return total
