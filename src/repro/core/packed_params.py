"""Packed-weight parameter trees for serving.

`quantize_params_for_serving` converts every matmul weight into the paper's
packed-density representation: int4 values, two per uint8 byte (+ per-output
-channel f32 scale).  This is the framework-level translation of DSP-packing
for TPU serving (DESIGN.md §2): weight HBM bytes drop 4× vs bf16, which both
(a) moves the decode roofline's memory term down and (b) lets models that
needed per-step FSDP gathers fit TP-only-replicated — removing the per-token
parameter all-gather entirely (EXPERIMENTS.md §Perf, cells A/C).

``dsp_tuned`` is the per-layer generalization: the ``repro.tuning`` planner
picks, per weight, the fastest pair-packed plan inside an error budget, and
the weight is quantized ONCE to the plan's signed integer grid and stored in
a :class:`DspTunedLeaf` — a registered pytree node that carries the plan
(spec + block) as static aux data, so jitted serving programs specialize on
the plan without retracing per call.

The leaf separates STORAGE from COMPUTE operands (the prepacked decode fast
path):

* storage — ``payload``: the signed plan grid nibble-packed two values per
  uint8 byte when ``bits_w <= 4`` (sub-byte storage, 2× denser than the old
  int8 store), plain int8 otherwise.  ``values`` decodes it on demand.
* compute — ``words``/``wsc``: the pair-packed int32 weight words (and, for
  mr plans only, the contamination operands) from
  ``kernels.ref.pack_weight_words``, built ONCE at engine build so no decode
  step ever repacks; ``zp_row``: the precomputed zero-point correction
  ``zp·Σ_k w``; ``w_f32``: the signed grid cast to f32 — on backends whose
  integer dots lower to scalar loops (CPU), *provably exact* plans run the
  identical integer matmul through the fast f32 GEMM unit, bit-for-bit
  (``ref.exact_int_matmul_fits_f32``).

The storage-vs-HBM tradeoff is explicit: ``payload`` is what a checkpoint /
HBM-resident copy costs (0.5–1 byte per value), the prepacked operands are
a decode-speed cache costing extra device bytes (4 bytes per value for
``words``, +4 for ``w_f32``, +8 for mr ``wsc``).  ``prepack=False`` keeps
storage only.

Norms, biases, embeddings and 1-D leaves stay bf16 (gather tables and
vector ops gain nothing from nibble packing).
"""

from __future__ import annotations

from typing import Any, Iterator

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels.ref import INT4_EXACT, PackedDotSpec
from .quantize import quantize_signed, zero_point_correction

__all__ = [
    "quantize_params_for_serving",
    "quantize_for_serving",
    "fuse_projection_weights",
    "is_packed_leaf",
    "is_dsp_tuned_leaf",
    "iter_packable_weights",
    "split_expert_stacks",
    "pack_signed_nibbles",
    "unpack_signed_nibbles",
    "DspTunedLeaf",
    "SERVING_MODES",
]

MIN_DIM = 32  # don't pack tiny matrices (router tables etc. stay exact)

# Weight-conversion modes accepted by the serving engine.  Storage packing
# happens for ``int4_packed`` (nibbles) and ``dsp_tuned``/``dsp_mixed``
# (per-layer plan integers — ``dsp_mixed`` is ``dsp_tuned`` with a
# sensitivity-allocated per-layer width map, see ``tuning.mixed``);
# ``int8``/``dsp_packed`` keep float weights and quantize at the point of
# use (their arithmetic is selected via ``LinearSpec.mode``), and
# ``native``/``none`` serve the weights as-is.
SERVING_MODES = ("native", "none", "int8", "int4_packed", "dsp_packed",
                 "dsp_tuned", "dsp_mixed")


def is_packed_leaf(p) -> bool:
    return isinstance(p, dict) and "packed" in p and "scale" in p


def is_dsp_tuned_leaf(p) -> bool:
    return isinstance(p, DspTunedLeaf)


# ---- sub-byte storage -----------------------------------------------------


def pack_signed_nibbles(v: jax.Array) -> jax.Array:
    """(…, K, N) signed ints in [-8, 7] → (…, K//2, N) uint8 nibbles.

    The generalization of ``ref.pack_int4_weights`` to any leading batch
    shape — the storage layout of every ``bits_w <= 4`` plan grid."""
    v = jnp.asarray(v, jnp.int8)
    k = v.shape[-2]
    if k % 2:
        raise ValueError("K must be even to pack nibbles")
    lo = v[..., 0::2, :] & 0xF
    hi = v[..., 1::2, :] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_signed_nibbles(packed: jax.Array) -> jax.Array:
    """(…, K//2, N) uint8 → (…, K, N) int8, sign-extended — the exact
    inverse of :func:`pack_signed_nibbles` on the signed grid."""
    b = packed.astype(jnp.int8)
    lo = (b << 4) >> 4  # arithmetic shift sign-extends the low nibble
    hi = b >> 4
    k2, n = packed.shape[-2:]
    out = jnp.stack([lo, hi], axis=-2)  # (..., K/2, 2, N)
    return out.reshape(packed.shape[:-2] + (2 * k2, n))


# ---- the tuned-plan leaf --------------------------------------------------


@jax.tree_util.register_pytree_node_class
class DspTunedLeaf:
    """A matmul weight quantized once to a tuned packing plan.

    Constructed from ``values`` ((…, d_in, d_out) signed ints on the plan's
    ``bits_w`` grid) and ``scale`` ((…, 1, d_out) f32); stores the nibble/
    int8 ``payload`` plus, when ``prepack=True`` (the default), the
    device-resident prepacked compute operands described in the module
    docstring.  ``spec``/``block``/``decode_block`` are static aux data —
    part of the treedef, so jitted programs specialize per plan.
    ``exact`` marks plans PROVEN error-free (algebraically or by exhaustive
    enumeration), unlocking the f32-GEMM fast path where it is bit-safe.
    """

    def __init__(self, values=None, scale=None, spec: PackedDotSpec = None,
                 block=None, *, decode_block=None, exact: bool | None = None,
                 payload=None, words=None, wsc=None, zp_row=None, w_f32=None,
                 prepack: bool = True):
        if spec is None:
            raise ValueError("DspTunedLeaf needs its PackedDotSpec")
        self.scale = scale
        self.spec = spec
        self.block = tuple(block) if block is not None else None
        self.decode_block = (
            tuple(decode_block) if decode_block is not None else None
        )
        if exact is None:
            # the certificate is the authority (it proves exactness for a
            # superset of the constructor's provably_exact predicate)
            from ..analysis.verify import certify_spec

            exact = certify_spec(spec).exact
        self.exact = bool(exact)
        if payload is None:
            if values is None:
                raise ValueError("DspTunedLeaf needs values or payload")
            values = jnp.asarray(values)
            if spec.bits_w <= 4 and values.shape[-2] % 2 == 0:
                payload = pack_signed_nibbles(values)
            else:
                payload = values.astype(jnp.int8)
        self.payload = payload
        self.words = words
        self.wsc = wsc
        self.zp_row = zp_row
        self.w_f32 = w_f32
        if prepack and words is None and values is not None:
            self._prepack(values)

    @property
    def nibble_packed(self) -> bool:
        return self.payload.dtype == jnp.uint8

    @property
    def values(self) -> jax.Array:
        """The signed plan-grid integers, decoded from storage (int8)."""
        if self.nibble_packed:
            return unpack_signed_nibbles(self.payload)
        return self.payload

    def _prepack(self, values) -> None:
        """Build the compute operands once (engine build time)."""
        v32 = values.astype(jnp.int32)
        zp = 1 << (self.spec.bits_a - 1)

        def one(m):
            packed = ref.pack_weight_words(m, self.spec)
            return packed.words, packed.wsc, zero_point_correction(m, zp)

        if v32.ndim == 2:
            self.words, self.wsc, self.zp_row = one(v32)
        else:
            lead = v32.shape[:-2]
            flat = v32.reshape((-1,) + v32.shape[-2:])
            if self.spec.uses_mr:
                words, wsc, zp_row = jax.vmap(one)(flat)
                self.wsc = wsc.reshape(lead + wsc.shape[1:])
            else:
                words, _, zp_row = jax.vmap(lambda m: one(m))(flat)
            self.words = words.reshape(lead + words.shape[1:])
            self.zp_row = zp_row.reshape(lead + zp_row.shape[1:])
        # the f32 shortcut is only bit-safe when the plan is proven exact
        # AND every partial sum fits the f32 mantissa
        k = v32.shape[-2]
        max_a = (1 << self.spec.bits_a) - 1
        max_w = 1 << (self.spec.bits_w - 1)
        if self.exact and ref.exact_int_matmul_fits_f32(k, max_a, max_w):
            self.w_f32 = values.astype(jnp.float32)

    @property
    def prepacked(self) -> bool:
        return self.words is not None

    def block_for(self, m: int):
        """Phase-appropriate tuned block: decode GEMVs (small m) get the
        decode-tuned block, prefill the general one."""
        from ..kernels.packed_matmul import DECODE_BLOCK

        if m <= DECODE_BLOCK[0] and self.decode_block is not None:
            return self.decode_block
        return self.block

    def tree_flatten(self):
        children = (self.payload, self.scale, self.words, self.wsc,
                    self.zp_row, self.w_f32)
        aux = (self.spec, self.block, self.decode_block, self.exact)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        leaf = cls.__new__(cls)
        (leaf.payload, leaf.scale, leaf.words, leaf.wsc, leaf.zp_row,
         leaf.w_f32) = children
        leaf.spec, leaf.block, leaf.decode_block, leaf.exact = aux
        return leaf


def iter_packable_weights(
    params, min_dim: int = MIN_DIM, path: str = ""
) -> Iterator[tuple[str, Any]]:
    """Yield ``(tree_path, leaf)`` for every matmul weight eligible for
    packed serving — the single eligibility predicate shared by the weight
    converters here and the per-layer planner (``tuning.plan_linear_layers``),
    so plan tables and converted trees always agree on coverage."""
    if not isinstance(params, dict):
        return
    parent = path.rsplit("/", 1)[-1]
    for k, v in params.items():
        p = f"{path}/{k}"
        # per-expert leaves from ``split_expert_stacks`` ("up/e0", "down/e3"…)
        expert_leaf = (
            k.startswith("e") and k[1:].isdigit()
            and parent in ("up", "gate", "down")
        )
        if (
            (k in ("w", "up", "gate", "down") or expert_leaf)
            and hasattr(v, "ndim")
            and v.ndim >= 2
            and "embed" not in path
            and "patch_proj" not in path
            and "router" not in p  # keep routing exact (tiny)
            and v.shape[-2] >= min_dim
            and v.shape[-1] >= min_dim
            and v.shape[-2] % 2 == 0
        ):
            yield p, v
        else:
            yield from iter_packable_weights(v, min_dim, p)


def split_expert_stacks(params):
    """Split stacked MoE expert weights into per-expert leaves.

    ``init_moe`` stores each projection as one ``(…, E, d_in, d_out)``
    stack.  A single stack can only carry a single quantization plan, and a
    stacked packed leaf dequantizes at use (``apply_linear``'s prepacked
    fast path needs a 2-D payload).  Splitting the stack into
    ``{"e0": (…, d_in, d_out), "e1": …}`` children gives every expert its
    own tree path — its own plan, its own sensitivity row, its own
    prepacked leaf — and ``moe_ffn`` then routes each expert's capacity
    buffer through ``apply_linear``.  Expert stacks are recognized
    structurally: an ``up``/``gate``/``down`` array of ``ndim >= 3`` whose
    parent dict also holds a ``router`` (the expert axis is always
    third-from-last, under any outer layer stacking).  Idempotent — an
    already-split tree passes through unchanged.
    """
    if not isinstance(params, dict):
        return params
    out = {}
    is_moe = "router" in params
    for k, v in params.items():
        if (
            is_moe
            and k in ("up", "gate", "down")
            and hasattr(v, "ndim")
            and v.ndim >= 3
        ):
            out[k] = {f"e{i}": v[..., i, :, :] for i in range(v.shape[-3])}
        else:
            out[k] = split_expert_stacks(v)
    return out


# ---- projection fusion ----------------------------------------------------


def fuse_projection_weights(params, fuse_attn: bool = True,
                            fuse_mlp: bool = True):
    """Engine-build fusion of same-input projections (packed modes only).

    Attention's q/k/v and SwiGLU's up/gate each consume the same activation;
    concatenating their weights along the output axis at build time turns
    three (two) GEMVs per decode step into one, and — because both weight
    and activation quantization are per-output-channel / per-row — the fused
    quantized matmul is BIT-IDENTICAL per column to the unfused one.  Only
    self-attention blocks are fused (cross-attention's q and k/v read
    different inputs), recognized structurally: a dict holding wq/wk/wv
    sub-dicts under any key except ``xattn``.  Biases concatenate alongside.

    ``fuse_attn``/``fuse_mlp`` gate the two fusion sites independently: on
    backends where the fused qkv output must be re-sliced through the head
    reshape (CPU XLA), attention fusion can cost more than the saved GEMV
    dispatches, while up|gate fusion is a pure win — the serving engine maps
    its ``fuse_projections`` config onto these switches.
    """

    def is_linear(d):
        return isinstance(d, dict) and "w" in d and hasattr(d["w"], "ndim")

    def walk(tree):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for k, v in tree.items():
            if (
                fuse_attn
                and k != "xattn"
                and isinstance(v, dict)
                and all(is_linear(v.get(n)) for n in ("wq", "wk", "wv"))
            ):
                v = dict(v)
                parts = [v.pop("wq"), v.pop("wk"), v.pop("wv")]
                fused = {"w": jnp.concatenate([p["w"] for p in parts], axis=-1)}
                if all("b" in p for p in parts):
                    fused["b"] = jnp.concatenate(
                        [p["b"] for p in parts], axis=-1
                    )
                out[k] = {"wqkv": fused, **{n: walk(s) for n, s in v.items()}}
            elif (
                fuse_mlp
                and isinstance(v, dict)
                and all(is_linear(v.get(n)) for n in ("up", "gate", "down"))
                and v["up"]["w"].shape == v["gate"]["w"].shape
            ):
                v = dict(v)
                up, gate = v.pop("up"), v.pop("gate")
                fused = {"w": jnp.concatenate([up["w"], gate["w"]], axis=-1)}
                if "b" in up and "b" in gate:
                    fused["b"] = jnp.concatenate([up["b"], gate["b"]], axis=-1)
                out[k] = {"upgate": fused, **{n: walk(s) for n, s in v.items()}}
            else:
                out[k] = walk(v)
        return out

    return walk(params)


def _pack_matrix(w: jax.Array, prepack: bool = False) -> dict:
    """(…, d_in, d_out) float -> packed int4 nibbles + per-channel scale.

    ``prepack=True`` (engine build) additionally stores ``w_f32`` — the
    int4 grid decoded once and cast to f32 — so the decode fast path runs
    the exact int8×int4 matmul through the f32 GEMM unit instead of
    unpacking nibbles and looping an integer dot every step."""
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    w2 = w.reshape((-1, d_in, d_out)).astype(jnp.float32)
    q = jax.vmap(lambda m: quantize_signed(m, bits=4, axis=0))(w2)
    packed = jax.vmap(ref.pack_int4_weights)(q.values)
    leaf = {
        "packed": packed.reshape(lead + (d_in // 2, d_out)),
        "scale": q.scale.reshape(lead + (1, d_out)).astype(jnp.float32),
    }
    if prepack and ref.exact_int_matmul_fits_f32(d_in, 128, 8):
        leaf["w_f32"] = (
            q.values.astype(jnp.float32).reshape(lead + (d_in, d_out))
        )
    return leaf


def _tune_matrix(w: jax.Array, spec: PackedDotSpec,
                 block: tuple[int, int, int] | None,
                 decode_block: tuple[int, int, int] | None = None,
                 exact: bool | None = None,
                 prepack: bool = True) -> DspTunedLeaf:
    """(…, d_in, d_out) float -> plan-grid signed ints + per-channel scale
    (+ the prepacked compute operands when ``prepack``)."""
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    w2 = w.reshape((-1, d_in, d_out)).astype(jnp.float32)
    q = jax.vmap(lambda m: quantize_signed(m, bits=spec.bits_w, axis=0))(w2)
    return DspTunedLeaf(
        values=q.values.astype(jnp.int8).reshape(lead + (d_in, d_out)),
        scale=q.scale.reshape(lead + (1, d_out)).astype(jnp.float32),
        spec=spec,
        block=block,
        decode_block=decode_block,
        exact=exact,
        prepack=prepack,
    )


def dequantize_packed(p: dict, dtype=jnp.bfloat16) -> jax.Array:
    """Graph-level unpack: two arithmetic shifts + scale.  On real TPU the
    Pallas kernel (`kernels/int4_matmul.py`) does this inside VMEM; the
    jnp path is the portable equivalent with the same HBM byte profile."""
    w = unpack_signed_nibbles(p["packed"])
    shape = p["packed"].shape[:-2] + (2 * p["packed"].shape[-2], p["packed"].shape[-1])
    return (w.reshape(shape).astype(jnp.float32) * p["scale"]).astype(dtype)


def materialize_weight(p, dtype):
    if is_packed_leaf(p):
        return dequantize_packed(p, dtype)
    if is_dsp_tuned_leaf(p):
        return (p.values.astype(jnp.float32) * p.scale).astype(dtype)
    return p


def _convert_tree(params, paths_to_convert: dict, convert):
    """Replace the leaves named in ``paths_to_convert`` (path -> per-leaf
    conversion argument); everything else passes through untouched."""

    def walk(tree, path=""):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for k, v in tree.items():
            p = f"{path}/{k}"
            if p in paths_to_convert:
                out[k] = convert(v, paths_to_convert[p])
            else:
                out[k] = walk(v, p)
        return out

    return walk(params)


def quantize_params_for_serving(params, min_dim: int = MIN_DIM,
                                prepack: bool = False):
    """Replace every large matmul weight leaf 'w' (and MoE expert stacks)
    with its packed representation.  Tree structure changes: callers use
    the transformed tree for sharding/eval_shape as well.

    ``prepack=False`` (default) stores nibbles only — the checkpoint/HBM
    density representation; the engine passes ``prepack=True`` to also
    build the decode-speed operands."""
    params = split_expert_stacks(params)
    targets = {p: None for p, _ in iter_packable_weights(params, min_dim)}
    return _convert_tree(
        params, targets, lambda w, _: _pack_matrix(w, prepack=prepack)
    )


def quantize_for_serving(params, mode: str = "int4_packed",
                         min_dim: int = MIN_DIM, plans=None,
                         prepack: bool = True, only_planned: bool = False):
    """Engine-build-time weight conversion step.

    ``int4_packed`` packs every large matmul weight to nibbles *once*; the
    decode path (`packed_linear.apply_linear`) then runs the packed matmul
    straight off the stored representation every step — no per-call
    re-quantization, and (with ``prepack``, the engine default) no per-step
    unpacking either.

    ``dsp_tuned`` quantizes each weight to its tuned plan (``plans``: a
    ``{tree_path: PlanReport-or-spec}`` table from
    ``tuning.plan_linear_layers``; paths missing from the table fall back
    to the exact int4 preset) and stores :class:`DspTunedLeaf` leaves —
    nibble/int8 payload plus prepacked pair words — so decode runs
    per-layer pair-packed arithmetic off operands packed once.  The plan
    map is genuinely per layer: entries may carry different ``(a_bits,
    w_bits)`` — each leaf quantizes onto ITS spec's grid and serves its
    own arithmetic (the ``dsp_mixed`` mode is exactly this with a
    sensitivity-allocated width map from ``tuning.mixed``).
    ``only_planned=True`` converts ONLY the paths named in ``plans`` and
    leaves every other weight float — the single-layer probe the
    sensitivity pass runs.

    The other modes keep float weights (``int8`` and ``dsp_packed``
    quantize at the point of use through their ``LinearSpec.mode``
    arithmetic; ``native``/``none`` are pass-through).
    """
    if mode not in SERVING_MODES:
        raise ValueError(f"serving mode {mode!r} not in {SERVING_MODES}")
    if mode not in ("native", "none"):
        # MoE expert stacks get per-expert leaves under every quantizing
        # mode (int8/dsp_packed quantize per expert at the point of use)
        params = split_expert_stacks(params)
    if mode == "int4_packed":
        return quantize_params_for_serving(
            params, min_dim=min_dim, prepack=prepack
        )
    if mode in ("dsp_tuned", "dsp_mixed"):
        plans = plans or {}
        targets = {}
        for p, _ in iter_packable_weights(params, min_dim):
            plan = plans.get(p)
            if plan is None:
                if only_planned:
                    continue
                spec, block, dblock, exact = INT4_EXACT, None, None, None
            elif isinstance(plan, PackedDotSpec):
                spec, block, dblock, exact = plan, None, None, None
            else:  # tuning.PlanReport
                spec, block = plan.spec, plan.block
                dblock = getattr(plan, "decode_block", None)
                cert = getattr(plan, "certificate", None)
                exact = (cert.exact if cert is not None
                         else plan.spec.provably_exact) or (
                    plan.mae == 0 and plan.exhaustive
                )
            targets[p] = (spec, block, dblock, exact)
        return _convert_tree(
            params, targets,
            lambda w, t: _tune_matrix(w, t[0], t[1], t[2], t[3],
                                      prepack=prepack),
        )
    return params
