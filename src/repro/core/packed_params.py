"""Packed-weight parameter trees for serving.

`quantize_params_for_serving` converts every matmul weight into the paper's
packed-density representation: int4 values, two per uint8 byte (+ per-output
-channel f32 scale).  This is the framework-level translation of DSP-packing
for TPU serving (DESIGN.md §2): weight HBM bytes drop 4× vs bf16, which both
(a) moves the decode roofline's memory term down and (b) lets models that
needed per-step FSDP gathers fit TP-only-replicated — removing the per-token
parameter all-gather entirely (EXPERIMENTS.md §Perf, cells A/C).

``dsp_tuned`` is the per-layer generalization: the ``repro.tuning`` planner
picks, per weight, the fastest pair-packed plan inside an error budget, and
the weight is quantized ONCE to the plan's signed integer grid and stored in
a :class:`DspTunedLeaf` — a registered pytree node that carries the plan
(spec + block) as static aux data, so jitted serving programs specialize on
the plan without retracing per call.  Decode then runs the paper's packed
arithmetic straight off the stored integers, no per-step re-quantization.
Plans may be multi-DSP column-packed (``spec.n_columns > 1``), which is
what makes ``ServeConfig.plan_bits=(8, 8)`` servable: 8-bit operands have
no single-word plan inside int32, but a column plan spreads each dot
product across several packed words (weights still store one int8 per
value — the column slicing happens on the activations inside the kernel).

Norms, biases, embeddings and 1-D leaves stay bf16 (gather tables and
vector ops gain nothing from nibble packing).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels.ref import INT4_EXACT, PackedDotSpec
from .quantize import quantize_signed

__all__ = [
    "quantize_params_for_serving",
    "quantize_for_serving",
    "is_packed_leaf",
    "is_dsp_tuned_leaf",
    "iter_packable_weights",
    "DspTunedLeaf",
    "SERVING_MODES",
]

MIN_DIM = 32  # don't pack tiny matrices (router tables etc. stay exact)

# Weight-conversion modes accepted by the serving engine.  Storage packing
# happens for ``int4_packed`` (nibbles) and ``dsp_tuned`` (per-layer plan
# integers); ``int8``/``dsp_packed`` keep float weights and quantize at the
# point of use (their arithmetic is selected via ``LinearSpec.mode``), and
# ``native``/``none`` serve the weights as-is.
SERVING_MODES = ("native", "none", "int8", "int4_packed", "dsp_packed",
                 "dsp_tuned")


def is_packed_leaf(p) -> bool:
    return isinstance(p, dict) and "packed" in p and "scale" in p


def is_dsp_tuned_leaf(p) -> bool:
    return isinstance(p, DspTunedLeaf)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DspTunedLeaf:
    """A matmul weight quantized once to a tuned packing plan.

    ``values``: (…, d_in, d_out) signed ints on the plan's ``bits_w`` grid
    (stored int8 — the pair packer consumes plain integers; sub-byte
    *storage* nibble packing composes later and is a ROADMAP open item).
    ``scale``: (…, 1, d_out) f32 per-output-channel dequantization scale.
    ``spec``/``block``: the plan — static aux data, part of the pytree
    treedef, so a jitted program is specialized per plan.
    """

    values: Any
    scale: Any
    spec: PackedDotSpec
    block: tuple[int, int, int] | None = None

    def tree_flatten(self):
        return (self.values, self.scale), (self.spec, self.block)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)


def iter_packable_weights(
    params, min_dim: int = MIN_DIM, path: str = ""
) -> Iterator[tuple[str, Any]]:
    """Yield ``(tree_path, leaf)`` for every matmul weight eligible for
    packed serving — the single eligibility predicate shared by the weight
    converters here and the per-layer planner (``tuning.plan_linear_layers``),
    so plan tables and converted trees always agree on coverage."""
    if not isinstance(params, dict):
        return
    for k, v in params.items():
        p = f"{path}/{k}"
        if (
            k in ("w", "up", "gate", "down")
            and hasattr(v, "ndim")
            and v.ndim >= 2
            and "embed" not in path
            and "patch_proj" not in path
            and "router" not in p  # keep routing exact (tiny)
            and v.shape[-2] >= min_dim
            and v.shape[-1] >= min_dim
            and v.shape[-2] % 2 == 0
        ):
            yield p, v
        else:
            yield from iter_packable_weights(v, min_dim, p)


def _pack_matrix(w: jax.Array) -> dict:
    """(…, d_in, d_out) float -> packed int4 nibbles + per-channel scale."""
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    w2 = w.reshape((-1, d_in, d_out)).astype(jnp.float32)
    q = jax.vmap(lambda m: quantize_signed(m, bits=4, axis=0))(w2)
    packed = jax.vmap(ref.pack_int4_weights)(q.values)
    return {
        "packed": packed.reshape(lead + (d_in // 2, d_out)),
        "scale": q.scale.reshape(lead + (1, d_out)).astype(jnp.float32),
    }


def _tune_matrix(w: jax.Array, spec: PackedDotSpec,
                 block: tuple[int, int, int] | None) -> DspTunedLeaf:
    """(…, d_in, d_out) float -> plan-grid signed ints + per-channel scale."""
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    w2 = w.reshape((-1, d_in, d_out)).astype(jnp.float32)
    q = jax.vmap(lambda m: quantize_signed(m, bits=spec.bits_w, axis=0))(w2)
    return DspTunedLeaf(
        values=q.values.astype(jnp.int8).reshape(lead + (d_in, d_out)),
        scale=q.scale.reshape(lead + (1, d_out)).astype(jnp.float32),
        spec=spec,
        block=block,
    )


def dequantize_packed(p: dict, dtype=jnp.bfloat16) -> jax.Array:
    """Graph-level unpack: two arithmetic shifts + scale.  On real TPU the
    Pallas kernel (`kernels/int4_matmul.py`) does this inside VMEM; the
    jnp path is the portable equivalent with the same HBM byte profile."""
    b = p["packed"].astype(jnp.int8)
    lo = (b << 4) >> 4  # arithmetic shifts sign-extend the nibbles
    hi = b >> 4
    w = jnp.stack([lo, hi], axis=-2)  # (..., K/2, 2, N)
    shape = p["packed"].shape[:-2] + (2 * p["packed"].shape[-2], p["packed"].shape[-1])
    return (w.reshape(shape).astype(jnp.float32) * p["scale"]).astype(dtype)


def materialize_weight(p, dtype):
    if is_packed_leaf(p):
        return dequantize_packed(p, dtype)
    if is_dsp_tuned_leaf(p):
        return (p.values.astype(jnp.float32) * p.scale).astype(dtype)
    return p


def _convert_tree(params, paths_to_convert: dict, convert):
    """Replace the leaves named in ``paths_to_convert`` (path -> per-leaf
    conversion argument); everything else passes through untouched."""

    def walk(tree, path=""):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for k, v in tree.items():
            p = f"{path}/{k}"
            if p in paths_to_convert:
                out[k] = convert(v, paths_to_convert[p])
            else:
                out[k] = walk(v, p)
        return out

    return walk(params)


def quantize_params_for_serving(params, min_dim: int = MIN_DIM):
    """Replace every large matmul weight leaf 'w' (and MoE expert stacks)
    with its packed representation.  Tree structure changes: callers use
    the transformed tree for sharding/eval_shape as well."""
    targets = {p: None for p, _ in iter_packable_weights(params, min_dim)}
    return _convert_tree(params, targets, lambda w, _: _pack_matrix(w))


def quantize_for_serving(params, mode: str = "int4_packed",
                         min_dim: int = MIN_DIM, plans=None):
    """Engine-build-time weight conversion step.

    ``int4_packed`` packs every large matmul weight to nibbles *once*; the
    decode path (`packed_linear.apply_linear`) then runs the paper's packed
    matmul kernel directly on the stored nibbles every step — no per-call
    re-quantization.

    ``dsp_tuned`` quantizes each weight to its tuned plan (``plans``: a
    ``{tree_path: PlanReport-or-spec}`` table from
    ``tuning.plan_linear_layers``; paths missing from the table fall back
    to the exact int4 preset) and stores :class:`DspTunedLeaf` leaves, so
    decode runs per-layer pair-packed arithmetic off stored integers.

    The other modes keep float weights (``int8`` and ``dsp_packed``
    quantize at the point of use through their ``LinearSpec.mode``
    arithmetic; ``native``/``none`` are pass-through).
    """
    if mode not in SERVING_MODES:
        raise ValueError(f"serving mode {mode!r} not in {SERVING_MODES}")
    if mode == "int4_packed":
        return quantize_params_for_serving(params, min_dim=min_dim)
    if mode == "dsp_tuned":
        plans = plans or {}
        targets = {}
        for p, _ in iter_packable_weights(params, min_dim):
            plan = plans.get(p)
            if plan is None:
                spec, block = INT4_EXACT, None
            elif isinstance(plan, PackedDotSpec):
                spec, block = plan, None
            else:  # tuning.PlanReport
                spec, block = plan.spec, plan.block
            targets[p] = (spec, block)
        return _convert_tree(
            params, targets, lambda w, sb: _tune_matrix(w, sb[0], sb[1])
        )
    return params
