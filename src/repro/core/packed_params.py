"""Packed-weight parameter trees for serving.

`quantize_params_for_serving` converts every matmul weight into the paper's
packed-density representation: int4 values, two per uint8 byte (+ per-output
-channel f32 scale).  This is the framework-level translation of DSP-packing
for TPU serving (DESIGN.md §2): weight HBM bytes drop 4× vs bf16, which both
(a) moves the decode roofline's memory term down and (b) lets models that
needed per-step FSDP gathers fit TP-only-replicated — removing the per-token
parameter all-gather entirely (EXPERIMENTS.md §Perf, cells A/C).

Norms, biases, embeddings and 1-D leaves stay bf16 (gather tables and
vector ops gain nothing from nibble packing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ref
from .quantize import quantize_signed

__all__ = [
    "quantize_params_for_serving",
    "quantize_for_serving",
    "is_packed_leaf",
    "SERVING_MODES",
]

MIN_DIM = 32  # don't pack tiny matrices (router tables etc. stay exact)

# Weight-conversion modes accepted by the serving engine.  Storage packing
# only happens for ``int4_packed``; ``int8``/``dsp_packed`` keep float
# weights and quantize at the point of use (their arithmetic is selected via
# ``LinearSpec.mode``), and ``native``/``none`` serve the weights as-is.
SERVING_MODES = ("native", "none", "int8", "int4_packed", "dsp_packed")


def is_packed_leaf(p) -> bool:
    return isinstance(p, dict) and "packed" in p and "scale" in p


def _pack_matrix(w: jax.Array) -> dict:
    """(…, d_in, d_out) float -> packed int4 nibbles + per-channel scale."""
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    if d_in % 2:
        raise ValueError(f"d_in must be even to pack nibbles, got {d_in}")
    w2 = w.reshape((-1, d_in, d_out)).astype(jnp.float32)
    q = jax.vmap(lambda m: quantize_signed(m, bits=4, axis=0))(w2)
    packed = jax.vmap(ref.pack_int4_weights)(q.values)
    return {
        "packed": packed.reshape(lead + (d_in // 2, d_out)),
        "scale": q.scale.reshape(lead + (1, d_out)).astype(jnp.float32),
    }


def dequantize_packed(p: dict, dtype=jnp.bfloat16) -> jax.Array:
    """Graph-level unpack: two arithmetic shifts + scale.  On real TPU the
    Pallas kernel (`kernels/int4_matmul.py`) does this inside VMEM; the
    jnp path is the portable equivalent with the same HBM byte profile."""
    b = p["packed"].astype(jnp.int8)
    lo = (b << 4) >> 4  # arithmetic shifts sign-extend the nibbles
    hi = b >> 4
    w = jnp.stack([lo, hi], axis=-2)  # (..., K/2, 2, N)
    shape = p["packed"].shape[:-2] + (2 * p["packed"].shape[-2], p["packed"].shape[-1])
    return (w.reshape(shape).astype(jnp.float32) * p["scale"]).astype(dtype)


def materialize_weight(p, dtype):
    return dequantize_packed(p, dtype) if is_packed_leaf(p) else p


def quantize_params_for_serving(params, min_dim: int = MIN_DIM):
    """Replace every large matmul weight leaf 'w' (and MoE expert stacks)
    with its packed representation.  Tree structure changes: callers use
    the transformed tree for sharding/eval_shape as well."""

    def walk(tree, path=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                p = f"{path}/{k}"
                if (
                    k in ("w", "up", "gate", "down")
                    and hasattr(v, "ndim")
                    and v.ndim >= 2
                    and "embed" not in path
                    and "patch_proj" not in path
                    and "router" not in p  # keep routing exact (tiny)
                    and v.shape[-2] >= min_dim
                    and v.shape[-1] >= min_dim
                    and v.shape[-2] % 2 == 0
                ):
                    out[k] = _pack_matrix(v)
                else:
                    out[k] = walk(v, p)
            return out
        return tree

    return walk(params)


def quantize_for_serving(params, mode: str = "int4_packed", min_dim: int = MIN_DIM):
    """Engine-build-time weight conversion step.

    ``int4_packed`` packs every large matmul weight to nibbles *once*; the
    decode path (`packed_linear.apply_linear`) then runs the paper's packed
    matmul kernel directly on the stored nibbles every step — no per-call
    re-quantization.  The other modes keep float weights (``int8`` and
    ``dsp_packed`` quantize at the point of use through their
    ``LinearSpec.mode`` arithmetic; ``native``/``none`` are pass-through).
    """
    if mode not in SERVING_MODES:
        raise ValueError(f"serving mode {mode!r} not in {SERVING_MODES}")
    if mode == "int4_packed":
        return quantize_params_for_serving(params, min_dim=min_dim)
    return params
