"""Error-correction schemes for DSP packing (paper §V/§VI) + error metrics.

Schemes
  * ``naive``   — Xilinx white-paper extraction; biased by −1 whenever the
                  cumulative lower fields are negative (§V).
  * ``full``    — Full Error Correction: round-half-up at extraction
                  (Eqn. 7).  Exact for ``delta >= 0`` configs.
  * ``approx``  — Approximate Correction: pre-bias the product through the
                  accumulator (C port) with the anticipated sign of the
                  field below each result (Fig. 4).  No extra hardware.
  * ``mr``      — MR-Overpacking: for ``delta < 0``, restore each field's
                  corrupted MSBs by subtracting the exactly-computed LSBs of
                  the field above (Eqns. 8/9, Fig. 6).
  * ``mr+full`` — beyond-paper combination: MR restore *and* round-half-up.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .packing import (
    PackingConfig,
    extract_fields,
    mul_lsbs,
    multiply_packed,
    outer_product_exact,
    sign_extend,
)

__all__ = [
    "SCHEMES",
    "approx_correction_word",
    "simulate",
    "mr_restore",
    "ErrorStats",
    "error_stats",
    "exhaustive_operands",
]


def approx_correction_word(cfg: PackingConfig, w: np.ndarray) -> np.ndarray:
    """The 48-bit C-port pre-bias of §V-B (Fig. 4).

    For every result field ``n >= 1`` the field below it (``n-1``) floors the
    extraction by −1 exactly when the cumulative lower value is negative.
    Its sign is *anticipated* from the sign bit of the signed operand
    ``w_{j(n-1)}`` that generates field ``n-1`` (the unsigned ``a`` operand
    cannot flip a sign).  The anticipated bit is added at offset
    ``r_offsets[n]`` *before* the product is formed, cancelling the bias.
    The anticipation fails only when the generating product is zero while
    ``w < 0`` (e.g. ``a_{i(n-1)} == 0``) — the residual 3 % of §V-B.
    """
    w = np.asarray(w, dtype=np.int64)
    word = np.zeros(w.shape[:-1], dtype=np.int64)
    order = np.argsort(np.asarray(cfg.r_offsets, dtype=np.int64), kind="stable")
    for rank in range(1, cfg.n_results):
        below = int(order[rank - 1])
        here = int(order[rank])
        _, j_below = cfg.result_operands(below)
        sign_bit = (w[..., j_below] < 0).astype(np.int64)
        word = word + (sign_bit << np.int64(cfg.r_offsets[here]))
    return word


def mr_restore(
    cfg: PackingConfig,
    fields: np.ndarray,
    a: np.ndarray,
    w: np.ndarray,
) -> np.ndarray:
    """Most-significant-bit Restoring Overpacking (§VI-B).

    With ``delta < 0`` adjacent fields overlap by ``|delta|`` bits: the LSBs
    of field ``n+1`` were *added* into the top ``|delta|`` bits of field
    ``n``.  Those LSBs are recomputed exactly from the operands (cheap in
    hardware — Eqns. 8/9) and subtracted after extraction.
    """
    if cfg.delta >= 0:
        return fields
    a = np.asarray(a, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    out = fields.copy()
    order = np.argsort(np.asarray(cfg.r_offsets, dtype=np.int64), kind="stable")
    for rank in range(cfg.n_results - 1):
        n = int(order[rank])
        above = int(order[rank + 1])
        shift = cfg.r_offsets[above] - cfg.r_offsets[n]
        if shift >= cfg.r_widths[n]:
            continue  # no overlap between these two fields
        i, j = cfg.result_operands(above)
        contam = mul_lsbs(a[..., i], w[..., j], cfg.r_widths[n] - shift)
        # Field arithmetic is modulo 2**width: re-wrap after the subtraction
        # (the true product fits the field, so the congruent value is the
        # restored result up to the small LSB contamination from below).
        out[..., n] = sign_extend(
            out[..., n] - (contam << np.int64(shift)), cfg.r_widths[n]
        )
    return out


def simulate(
    cfg: PackingConfig,
    a: np.ndarray,
    w: np.ndarray,
    scheme: str = "naive",
    accumulate_correction: np.ndarray | None = None,
) -> np.ndarray:
    """End-to-end packed multiply → extraction under a correction scheme."""
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; options: {sorted(SCHEMES)}")
    cword = None
    if scheme == "approx":
        cword = approx_correction_word(cfg, w)
    if accumulate_correction is not None:
        cword = accumulate_correction if cword is None else cword + accumulate_correction
    p = multiply_packed(cfg, a, w, correction_word=cword)
    fields = extract_fields(cfg, p, round_half_up=scheme in ("full", "mr+full"))
    if scheme in ("mr", "mr+full"):
        fields = mr_restore(cfg, fields, a, w)
    return fields


SCHEMES = ("naive", "full", "approx", "mr", "mr+full")


# ---- error metrics (paper §VIII, Eqns. 10-12) ---------------------------


@dataclasses.dataclass(frozen=True)
class ErrorStats:
    """EP (%), MAE, WCE — per result field and aggregated (bar accent)."""

    ep: tuple[float, ...]
    mae: tuple[float, ...]
    wce: tuple[int, ...]

    @property
    def ep_bar(self) -> float:
        return float(np.mean(self.ep))

    @property
    def mae_bar(self) -> float:
        return float(np.mean(self.mae))

    @property
    def wce_bar(self) -> int:
        return int(np.max(self.wce))

    def row(self) -> str:
        return (
            f"MAE={self.mae_bar:.2f} EP={self.ep_bar:.2f}% WCE={self.wce_bar}"
        )


def error_stats(expected: np.ndarray, actual: np.ndarray) -> ErrorStats:
    """Eqns. (10)-(12) over the leading axes, per result field."""
    err = np.abs(np.asarray(actual, np.int64) - np.asarray(expected, np.int64))
    flat = err.reshape(-1, err.shape[-1]).astype(np.float64)
    ep = tuple(float(x) for x in (flat > 0).mean(axis=0) * 100.0)
    mae = tuple(float(x) for x in flat.mean(axis=0))
    wce = tuple(int(x) for x in flat.max(axis=0))
    return ErrorStats(ep=ep, mae=mae, wce=wce)


def exhaustive_operands(cfg: PackingConfig) -> tuple[np.ndarray, np.ndarray]:
    """Every possible (a, w) combination for a config — the paper's ``N``.

    Returns arrays of shape ``(N, n_a)`` and ``(N, n_w)``.  Feasible for the
    4-bit table configs (``16^4 = 65 536`` combinations).
    """
    axes = [np.arange(1 << width, dtype=np.int64) for width in cfg.a_widths]
    axes += [
        np.arange(-(1 << (width - 1)), 1 << (width - 1), dtype=np.int64)
        for width in cfg.w_widths
    ]
    grids = np.meshgrid(*axes, indexing="ij")
    flat = [g.reshape(-1) for g in grids]
    a = np.stack(flat[: cfg.n_a], axis=-1)
    w = np.stack(flat[cfg.n_a :], axis=-1)
    return a, w


def scheme_stats(cfg: PackingConfig, scheme: str) -> ErrorStats:
    """Exhaustive error statistics of ``scheme`` for ``cfg`` (Tables I/II)."""
    a, w = exhaustive_operands(cfg)
    expected = outer_product_exact(cfg, a, w)
    actual = simulate(cfg, a, w, scheme=scheme)
    return error_stats(expected, actual)
