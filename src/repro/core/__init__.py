"""Core packing math and packed-parameter plumbing.

The paper's arithmetic lives here framework-side: ``packing`` (the
pair-packing/extraction algebra), ``quantizers``, ``packed_linear``
(the ``apply_linear`` dispatch over float / packed / tuned / TP-wrapped
leaves), ``packed_params`` (serving-time weight quantization, fusion
and per-expert splitting) and ``addpack`` (accumulator packing, §VII).
Kernel-shaped entry points live in ``repro.kernels``; plan selection in
``repro.tuning``.
"""
