"""Generalized DSP multiplication packing (paper §III/§IV, Eqn. 4).

This module is the bit-exact ground truth for the whole framework: a NumPy
int64 simulation of packing several narrow integer multiplications into one
wide multiplier + accumulator (the Xilinx DSP48E2's ``P = B×(A+D) + C``
datapath).  Everything here is exhaustively validated against the paper's
Tables I/II/III in ``tests/test_packing_paper.py``; the JAX/Pallas compute
paths (``repro.kernels``) validate against these functions.

Terminology follows the paper:
  * ``a`` — vector of *unsigned* operands (activations), packed into one
    physical multiplier input at offsets ``a_offsets``.
  * ``w`` — vector of *signed* operands (weights), packed into the other
    input at offsets ``w_offsets``.
  * the single wide product contains the full outer product
    ``r[j*|a|+i] = a_i * w_j`` at offset ``a_offsets[i] + w_offsets[j]``.
  * ``delta`` — padding bits between adjacent result fields.  ``delta >= 0``
    allows ``2**delta`` products to be accumulated before fields collide;
    ``delta < 0`` is *Overpacking* (§VI): fields overlap and corrupt each
    other by ``|delta|`` bits.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "PackingConfig",
    "int4_packing",
    "int8_packing",
    "intn_packing",
    "pack_activations",
    "pack_weights",
    "multiply_packed",
    "extract_fields",
    "outer_product_exact",
    "sign_extend",
    "mul_lsbs",
]

# The DSP48E2 port budgets (bits).  `a` rides the 18-bit signed B port (so 17
# usable bits for unsigned payload), `w` the 27-bit signed pre-adder path (26
# payload bits + sign), and the product/accumulator is 48-bit signed.
DSP48_A_BITS = 17
DSP48_W_BITS = 26
DSP48_P_BITS = 47


def sign_extend(v: np.ndarray, width: int) -> np.ndarray:
    """Reinterpret the low ``width`` bits of ``v`` as a signed integer."""
    v = np.asarray(v, dtype=np.int64)
    mask = np.int64((1 << width) - 1)
    sign = np.int64(1 << (width - 1))
    return ((v & mask) ^ sign) - sign


def mul_lsbs(a: np.ndarray, w: np.ndarray, nbits: int) -> np.ndarray:
    """The ``nbits`` least-significant bits of ``a*w`` (paper Eqns. 8/9).

    In hardware this is a handful of AND/XOR gates (the first two LSBs of a
    multiplication are nearly free); in the simulation it is simply the
    product modulo ``2**nbits``.
    """
    prod = np.asarray(a, dtype=np.int64) * np.asarray(w, dtype=np.int64)
    return prod & np.int64((1 << nbits) - 1)


@dataclasses.dataclass(frozen=True)
class PackingConfig:
    """A packing configuration in the paper's notation (§IV).

    ``r_offsets[j*len(a)+i] = a_offsets[i] + w_offsets[j]`` and
    ``r_widths[j*len(a)+i] = a_widths[i] + w_widths[j]`` (Eqn. 4).
    """

    a_widths: tuple[int, ...]
    w_widths: tuple[int, ...]
    a_offsets: tuple[int, ...]
    w_offsets: tuple[int, ...]
    delta: int

    def __post_init__(self) -> None:
        if len(self.a_widths) != len(self.a_offsets):
            raise ValueError("a_widths and a_offsets must have equal length")
        if len(self.w_widths) != len(self.w_offsets):
            raise ValueError("w_widths and w_offsets must have equal length")
        if sorted(self.a_offsets) != list(self.a_offsets) or sorted(
            self.w_offsets
        ) != list(self.w_offsets):
            raise ValueError("offsets must be sorted ascending")
        if self.product_bits() > 62:
            raise ValueError(
                "packing config exceeds the int64 simulation budget "
                f"({self.product_bits()} bits)"
            )

    # ---- derived field algebra (Eqn. 4) -------------------------------
    @property
    def n_a(self) -> int:
        return len(self.a_widths)

    @property
    def n_w(self) -> int:
        return len(self.w_widths)

    @property
    def n_results(self) -> int:
        return self.n_a * self.n_w

    def result_index(self, i: int, j: int) -> int:
        """Flat index of result ``a_i * w_j``."""
        return j * self.n_a + i

    def result_operands(self, n: int) -> tuple[int, int]:
        """Inverse of :meth:`result_index`: flat index -> ``(i, j)``."""
        return n % self.n_a, n // self.n_a

    @property
    def r_offsets(self) -> tuple[int, ...]:
        out = [0] * self.n_results
        for j, woff in enumerate(self.w_offsets):
            for i, aoff in enumerate(self.a_offsets):
                out[self.result_index(i, j)] = aoff + woff
        return tuple(out)

    @property
    def r_widths(self) -> tuple[int, ...]:
        out = [0] * self.n_results
        for j, ww in enumerate(self.w_widths):
            for i, aw in enumerate(self.a_widths):
                out[self.result_index(i, j)] = aw + ww
        return tuple(out)

    def product_bits(self) -> int:
        """Upper bound on the bits needed by the packed product."""
        return max(o + w for o, w in zip(self.r_offsets, self.r_widths)) + 2

    def fits_dsp48(self) -> bool:
        """Whether the configuration fits the DSP48E2 port budgets."""
        a_bits = self.a_offsets[-1] + self.a_widths[-1]
        w_bits = self.w_offsets[-1] + self.w_widths[-1]
        return (
            a_bits <= DSP48_A_BITS
            and w_bits <= DSP48_W_BITS
            and self.product_bits() - 2 <= DSP48_P_BITS
        )

    def packing_density(self, total_bits: int = 48) -> float:
        """ρ = b_used / b_total (paper §VIII / Fig. 9).

        ``b_used`` counts *logical* result bits; under Overpacking fields
        overlap so ρ can exceed the physically occupied span — that is the
        squeeze.
        """
        return sum(self.r_widths) / total_bits

    def max_accumulations(self) -> int:
        """2**delta results can be accumulated error-free (paper §III)."""
        return 2 ** max(self.delta, 0)


def intn_packing(
    a_widths: Sequence[int], w_widths: Sequence[int], delta: int
) -> PackingConfig:
    """INT-N: derive a uniform-grid packing from widths + padding (§IV).

    Field spacing is ``s = max(result width) + delta``; activation offsets
    advance by ``s`` and weight offsets by ``s * len(a)`` so the outer
    product lands on a uniform grid of result offsets — exactly the scheme
    of Eqn. (3)/(4) and Figs. 2/6.
    """
    a_widths = tuple(int(x) for x in a_widths)
    w_widths = tuple(int(x) for x in w_widths)
    spacing = max(aw + ww for aw in a_widths for ww in w_widths) + delta
    if spacing <= 0:
        raise ValueError(f"spacing must be positive, got {spacing}")
    a_offsets = tuple(i * spacing for i in range(len(a_widths)))
    w_offsets = tuple(j * spacing * len(a_widths) for j in range(len(w_widths)))
    return PackingConfig(a_widths, w_widths, a_offsets, w_offsets, delta)


def int4_packing(delta: int = 3) -> PackingConfig:
    """The Xilinx INT4 configuration (§III / Fig. 2) for ``delta=3``.

    ``delta<3`` yields the Overpacked variants (e.g. Fig. 6 is ``delta=-2``).
    """
    return intn_packing((4, 4), (4, 4), delta)


def int8_packing(delta: int = 2) -> PackingConfig:
    """The Xilinx INT8 (wp486) configuration: two 8-bit multiplies."""
    return intn_packing((8,), (8, 8), delta)


# ---- packing / wide multiply / extraction ------------------------------


def _check_ranges(cfg: PackingConfig, a: np.ndarray, w: np.ndarray) -> None:
    a = np.asarray(a)
    w = np.asarray(w)
    if a.shape[-1] != cfg.n_a:
        raise ValueError(f"a last dim {a.shape[-1]} != {cfg.n_a}")
    if w.shape[-1] != cfg.n_w:
        raise ValueError(f"w last dim {w.shape[-1]} != {cfg.n_w}")
    for i, width in enumerate(cfg.a_widths):
        ai = a[..., i]
        if (ai < 0).any() or (ai >= (1 << width)).any():
            raise ValueError(f"a[{i}] out of unsigned {width}-bit range")
    for j, width in enumerate(cfg.w_widths):
        wj = w[..., j]
        lo, hi = -(1 << (width - 1)), (1 << (width - 1)) - 1
        if (wj < lo).any() or (wj > hi).any():
            raise ValueError(f"w[{j}] out of signed {width}-bit range")


def pack_activations(cfg: PackingConfig, a: np.ndarray) -> np.ndarray:
    """Pack unsigned operands: ``A = Σ a_i · 2^a_offsets[i]`` (B port)."""
    a = np.asarray(a, dtype=np.int64)
    out = np.zeros(a.shape[:-1], dtype=np.int64)
    for i, off in enumerate(cfg.a_offsets):
        out = out + (a[..., i] << np.int64(off))
    return out


def pack_weights(cfg: PackingConfig, w: np.ndarray) -> np.ndarray:
    """Pack signed operands: ``W = Σ w_j · 2^w_offsets[j]``.

    This models the DSP pre-adder forming ``D·2^off + sext(A)``; the packed
    value is a plain (possibly negative) integer.
    """
    w = np.asarray(w, dtype=np.int64)
    out = np.zeros(w.shape[:-1], dtype=np.int64)
    for j, off in enumerate(cfg.w_offsets):
        out = out + (w[..., j] << np.int64(off))
    return out


def multiply_packed(
    cfg: PackingConfig,
    a: np.ndarray,
    w: np.ndarray,
    correction_word: np.ndarray | None = None,
    check: bool = True,
) -> np.ndarray:
    """One wide multiply: ``P = pack(a) × pack(w) (+ C)`` — the DSP op."""
    if check:
        _check_ranges(cfg, a, w)
    p = pack_activations(cfg, a) * pack_weights(cfg, w)
    if correction_word is not None:
        p = p + correction_word
    return p


def extract_fields(cfg: PackingConfig, p: np.ndarray, round_half_up: bool = False) -> np.ndarray:
    """Extract every result field from the packed product (last axis).

    ``round_half_up=False`` is the naive extraction (arithmetic right shift,
    floors toward −∞ — the biased scheme of the Xilinx white papers, §V).
    ``round_half_up=True`` implements the paper's Full Error Correction
    (Eqn. 7): inspect the bit just below the field and round to nearest.
    """
    p = np.asarray(p, dtype=np.int64)
    fields = []
    for n in range(cfg.n_results):
        off, width = cfg.r_offsets[n], cfg.r_widths[n]
        if round_half_up and off > 0:
            shifted = ((p >> np.int64(off - 1)) + np.int64(1)) >> np.int64(1)
        else:
            shifted = p >> np.int64(off)
        fields.append(sign_extend(shifted, width))
    return np.stack(fields, axis=-1)


def outer_product_exact(cfg: PackingConfig, a: np.ndarray, w: np.ndarray) -> np.ndarray:
    """The mathematically exact outer product, ordered like the fields."""
    a = np.asarray(a, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    cols = []
    for n in range(cfg.n_results):
        i, j = cfg.result_operands(n)
        cols.append(a[..., i] * w[..., j])
    return np.stack(cols, axis=-1)
