"""Public wrappers around the Pallas kernels.

Handles shape padding to block multiples, scale/zero-point bookkeeping and
backend dispatch (``interpret=True`` everywhere except real TPUs), and
exposes a float-in/float-out ``packed_linear_apply`` used by the model zoo.

The ``*_prepacked`` entries are the serving decode fast path: weights
arrive as operands packed ONCE at engine build (``core.packed_params``), so
a decode step does no per-call weight packing, no zero-point reduction and
no M-padding to MXU tiles — the historical packed-decode tax was a ~64x
padded GEMV plus a full weight repack per K-step per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.quantize import (
    quantize_signed,
    quantize_unsigned,
    zero_point_correction,
)
from . import ref
from .int4_matmul import int4_matmul
from .packed_matmul import default_block_for, packed_matmul, packed_matmul_prepacked

__all__ = [
    "auto_interpret",
    "packed_matmul_f32",
    "dsp_tuned_matmul_f32",
    "dsp_tuned_matmul_prepacked_f32",
    "int4_matmul_f32",
    "int4_prepacked_matmul_f32",
    "quantized_matmul_ref",
]

from .ref import INT4_EXACT, PackedDotSpec


def auto_interpret() -> bool:
    """Pallas interpret mode everywhere but a real TPU backend."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret", "use_kernel")
)
def packed_matmul_f32(
    x: jax.Array,
    w: jax.Array,
    spec: PackedDotSpec = INT4_EXACT,
    block=(128, 128, 128),
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """float (M, K) × float (K, N) through the pair-packed integer path.

    Quantizes activations offset-binary unsigned (zero point folded back via
    ``zero_point_correction``) and weights signed per output channel, runs
    the packed integer matmul, and dequantizes.
    """
    xq = quantize_unsigned(x, bits=spec.bits_a, axis=-1)
    wq = quantize_signed(w, bits=spec.bits_w, axis=0)
    # ragged shapes are padded (bit-transparently) inside the compute paths
    if use_kernel:
        acc = packed_matmul(
            xq.values, wq.values, spec=spec, block=block,
            interpret=auto_interpret() if interpret is None else interpret,
        )
    else:
        acc = ref.ref_packed_matmul(xq.values, wq.values, spec=spec)
    acc = acc - zero_point_correction(wq.values, xq.zero_point)[None, :]
    return acc.astype(jnp.float32) * xq.scale * wq.scale


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret", "use_kernel")
)
def dsp_tuned_matmul_f32(
    x: jax.Array,
    w_values: jax.Array,
    w_scale: jax.Array,
    spec: PackedDotSpec,
    block=(128, 128, 128),
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """float (M, K) × pre-quantized signed (K, N) through a tuned plan.

    The per-call companion of :func:`dsp_tuned_matmul_prepacked_f32` for
    weights that are quantized but not prepacked (weights repacked into
    words on every call) — kept for stacked leaves outside a layer scan and
    for benchmarking the repacking tax itself.
    """
    xq = quantize_unsigned(x, bits=spec.bits_a, axis=-1)
    wv = w_values.astype(jnp.int32)
    if use_kernel:
        acc = packed_matmul(
            xq.values, wv, spec=spec, block=block,
            interpret=auto_interpret() if interpret is None else interpret,
        )
    else:
        acc = ref.ref_packed_matmul(xq.values, wv, spec=spec)
    acc = acc - zero_point_correction(wv, xq.zero_point)[None, :]
    return acc.astype(jnp.float32) * xq.scale * w_scale


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block", "interpret", "use_kernel", "exact_f32"),
)
def dsp_tuned_matmul_prepacked_f32(
    x: jax.Array,
    words: jax.Array,
    wsc: jax.Array | None,
    zp_row: jax.Array,
    w_scale: jax.Array,
    w_f32: jax.Array | None,
    spec: PackedDotSpec,
    block: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    use_kernel: bool = True,
    exact_f32: bool = False,
) -> jax.Array:
    """float (M, K) × PREPACKED tuned-plan weights → f32 (M, N).

    The serving decode fast path: ``words``/``wsc``/``zp_row``/``w_f32``
    were built once at engine build (``DspTunedLeaf``), so the per-step work
    is activation quantization plus the compute stage — nothing repacks.

    ``exact_f32`` (only legal when the plan is PROVEN exact and the operand
    bound fits the f32 mantissa — the leaf's ``w_f32`` existence encodes
    both) evaluates the identical integer matmul on the f32 GEMM unit:
    bit-for-bit the packed kernel's output, at dense-float speed on
    backends whose integer dots lower to scalar loops.

    With the kernel path, the activation quantize is fused into the kernel
    prologue (``x_scale``/``x_zp``): the int activation tensor never stages
    through HBM.
    """
    m = x.shape[0]
    if exact_f32 and w_f32 is not None:
        # quantize_unsigned without the uint8 round-trip (values are exact
        # small integers either way; the clip never binds — |x/scale| is
        # bounded by zp-1 by construction); the f32 GEMM then computes the
        # exact packed-plan matmul — see the docstring
        zp = 1 << (spec.bits_a - 1)
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        x_scale = jnp.maximum(amax, 1e-8) / (zp - 1)
        q = jnp.round(x / x_scale) + zp
        acc = q @ w_f32  # exact: fits the f32 mantissa
        acc = acc - zp_row.astype(jnp.float32)[None, :]
        return acc * x_scale * w_scale
    if use_kernel:
        # fused-quantize prologue: pass raw f32 + per-row scale to the kernel
        zp = 1 << (spec.bits_a - 1)
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        x_scale = jnp.maximum(amax, 1e-8) / (zp - 1)
        acc = packed_matmul_prepacked(
            x.astype(jnp.float32), words, wsc, spec=spec,
            block=block or default_block_for(m, spec),
            interpret=auto_interpret() if interpret is None else interpret,
            x_scale=x_scale, x_zp=zp,
        )
        out_scale = x_scale
    else:
        xq = quantize_unsigned(x, bits=spec.bits_a, axis=-1)
        acc = ref.ref_packed_matmul_prepacked(
            xq.values.astype(jnp.int32), ref.PackedWeightWords(words, wsc),
            spec,
        )
        out_scale = xq.scale
    acc = acc - zp_row[None, :]
    return acc.astype(jnp.float32) * out_scale * w_scale


@functools.partial(jax.jit, static_argnames=("block", "interpret", "use_kernel"))
def int4_matmul_f32(
    x: jax.Array,
    w_packed: jax.Array,
    w_scale: jax.Array,
    block=None,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """float (M, K) × packed int4 (K//2, N) → f32, int8 activations.

    The ref path runs unpadded (an (M, K, N) problem needs no MXU tile
    grid); the kernel path pads to a decode-aware block — small-M GEMV
    blocks for decode shapes instead of 128-row tiles that used to pad a
    2-slot decode ~64x in M.
    """
    m, k = x.shape
    xq = quantize_signed(x, bits=8, axis=-1)
    if use_kernel:
        if block is None:
            block = default_block_for(m)
        bm, bn, bk = block
        xv = _pad_to(_pad_to(xq.values, bm, 0), bk, 1)
        wv = _pad_to(_pad_to(w_packed, bk // 2, 0), bn, 1)
        acc = int4_matmul(
            xv, wv, block=block,
            interpret=auto_interpret() if interpret is None else interpret,
        )[:m, : w_packed.shape[1]]
    else:
        acc = ref.ref_int4_matmul(xq.values, w_packed)
    return acc.astype(jnp.float32) * xq.scale * w_scale


def _quantize_signed_f32(x: jax.Array, bits: int):
    """``quantize_signed`` without the int8 round-trip: the quantized grid
    values are computed (and kept) in f32 — they are exact small integers,
    so the downstream f32 GEMM sees bit-identical operands while decode
    skips two dtype conversions per linear.  The clip is omitted because it
    never binds: ``|x / scale| <= qmax`` by the scale's construction, so
    ``round`` already lands inside the signed grid."""
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    return jnp.round(x / scale), scale


@jax.jit
def int4_prepacked_matmul_f32(
    x: jax.Array,
    w_f32: jax.Array,
    w_scale: jax.Array,
) -> jax.Array:
    """float (M, K) × int4 grid decoded once to f32 (K, N) → f32 (M, N).

    The int4_packed serving fast path: ``w_f32`` holds the nibble grid
    decoded at engine build.  With int8 activations every partial sum is an
    integer below 2**24 (guarded at build via
    ``ref.exact_int_matmul_fits_f32``), so the f32 GEMM computes the exact
    int8×int4 integer matmul — bit-identical to ``ref.ref_int4_matmul`` on
    the stored nibbles — while hitting the dense-float unit.
    """
    q, scale = _quantize_signed_f32(x, bits=8)
    acc = q @ w_f32
    return acc * scale * w_scale


def quantized_matmul_ref(x: jax.Array, w: jax.Array, bits: int = 4) -> jax.Array:
    """Exact-arithmetic quantized matmul (no packing) — accuracy oracle."""
    xq = quantize_unsigned(x, bits=bits, axis=-1)
    wq = quantize_signed(w, bits=bits, axis=0)
    acc = ref.ref_quantized_matmul(xq.values, wq.values)
    acc = acc - zero_point_correction(wq.values, xq.zero_point)[None, :]
    return acc.astype(jnp.float32) * xq.scale * wq.scale
