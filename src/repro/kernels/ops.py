"""Public wrappers around the Pallas kernels.

Handles shape padding to block multiples, scale/zero-point bookkeeping and
backend dispatch (``interpret=True`` everywhere except real TPUs), and
exposes a float-in/float-out ``packed_linear_apply`` used by the model zoo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.quantize import (
    quantize_signed,
    quantize_unsigned,
    zero_point_correction,
)
from . import ref
from .int4_matmul import int4_matmul
from .packed_matmul import packed_matmul
from .ref import INT4_EXACT, PackedDotSpec

__all__ = [
    "auto_interpret",
    "packed_matmul_f32",
    "dsp_tuned_matmul_f32",
    "int4_matmul_f32",
    "quantized_matmul_ref",
]


def auto_interpret() -> bool:
    """Pallas interpret mode everywhere but a real TPU backend."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret", "use_kernel")
)
def packed_matmul_f32(
    x: jax.Array,
    w: jax.Array,
    spec: PackedDotSpec = INT4_EXACT,
    block=(128, 128, 128),
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """float (M, K) × float (K, N) through the pair-packed integer path.

    Quantizes activations offset-binary unsigned (zero point folded back via
    ``zero_point_correction``) and weights signed per output channel, runs
    the packed integer matmul, and dequantizes.
    """
    xq = quantize_unsigned(x, bits=spec.bits_a, axis=-1)
    wq = quantize_signed(w, bits=spec.bits_w, axis=0)
    # ragged shapes are padded (bit-transparently) inside the compute paths
    if use_kernel:
        acc = packed_matmul(
            xq.values, wq.values, spec=spec, block=block,
            interpret=auto_interpret() if interpret is None else interpret,
        )
    else:
        acc = ref.ref_packed_matmul(xq.values, wq.values, spec=spec)
    acc = acc - zero_point_correction(wq.values, xq.zero_point)[None, :]
    return acc.astype(jnp.float32) * xq.scale * wq.scale


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret", "use_kernel")
)
def dsp_tuned_matmul_f32(
    x: jax.Array,
    w_values: jax.Array,
    w_scale: jax.Array,
    spec: PackedDotSpec,
    block=(128, 128, 128),
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """float (M, K) × pre-quantized signed (K, N) through a tuned plan.

    The serving-side companion of ``packed_matmul_f32``: weights were
    quantized ONCE at engine build (``packed_params.quantize_for_serving``
    with mode ``dsp_tuned``) onto ``spec``'s signed grid, so every decode
    step only quantizes the activations and runs the packed integer path —
    no per-call weight re-quantization.  Multi-DSP column plans
    (``spec.n_columns > 1``, e.g. every a8w8 plan) need no special casing
    here: activations quantize to the full ``spec.bits_a`` grid and the
    kernel slices them into column streams internally.
    """
    xq = quantize_unsigned(x, bits=spec.bits_a, axis=-1)
    wv = w_values.astype(jnp.int32)
    if use_kernel:
        acc = packed_matmul(
            xq.values, wv, spec=spec, block=block,
            interpret=auto_interpret() if interpret is None else interpret,
        )
    else:
        acc = ref.ref_packed_matmul(xq.values, wv, spec=spec)
    acc = acc - zero_point_correction(wv, xq.zero_point)[None, :]
    return acc.astype(jnp.float32) * xq.scale * w_scale


@functools.partial(jax.jit, static_argnames=("block", "interpret", "use_kernel"))
def int4_matmul_f32(
    x: jax.Array,
    w_packed: jax.Array,
    w_scale: jax.Array,
    block=(128, 128, 128),
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """float (M, K) × packed int4 (K//2, N) → f32, int8 activations."""
    m, k = x.shape
    xq = quantize_signed(x, bits=8, axis=-1)
    bm, bn, bk = block
    xv = _pad_to(_pad_to(xq.values, bm, 0), bk, 1)
    wv = _pad_to(_pad_to(w_packed, bk // 2, 0), bn, 1)
    if use_kernel:
        acc = int4_matmul(
            xv, wv, block=block,
            interpret=auto_interpret() if interpret is None else interpret,
        )[:m, : w_packed.shape[1]]
    else:
        acc = ref.ref_int4_matmul(xv, wv)[:m, : w_packed.shape[1]]
    return acc.astype(jnp.float32) * xq.scale * w_scale


def quantized_matmul_ref(x: jax.Array, w: jax.Array, bits: int = 4) -> jax.Array:
    """Exact-arithmetic quantized matmul (no packing) — accuracy oracle."""
    xq = quantize_unsigned(x, bits=bits, axis=-1)
    wq = quantize_signed(w, bits=bits, axis=0)
    acc = ref.ref_quantized_matmul(xq.values, wq.values)
    acc = acc - zero_point_correction(wq.values, xq.zero_point)[None, :]
    return acc.astype(jnp.float32) * xq.scale * wq.scale
