"""Flash-attention forward Pallas kernel (prefill/serving hot spot).

Online-softmax over KV tiles held in VMEM: grid (B·H, S/bq); each program
streams K/V in ``bk``-sized tiles through VMEM (pl.ds slices), carrying the
running (max, denom, acc) in VREGs — the S×S score matrix never exists.
Causal masking prunes whole tiles past the diagonal.  Training uses the
graph-level chunked attention (`models/layers.py`) for autodiff; this
kernel is the serving-side fast path, validated in interpret mode against
the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention", "ref_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, seq: int, scale: float):
    iq = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale  # (bq, hd)
    hd = q.shape[-1]
    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq)

    def body(ik, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(ik * bk, bk), :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[pl.ds(ik * bk, bk), :].astype(jnp.float32)
        s = q @ k.T  # (bq, bk)
        k_pos = ik * bk + jax.lax.iota(jnp.int32, bk)
        s = jnp.where(k_pos[None, :] <= q_pos[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    # causal pruning: only tiles up to the diagonal of this q block
    n_tiles = (iq + 1) * bq // bk
    init = (
        jnp.full((bq,), NEG_INF, jnp.float32),
        jnp.zeros((bq,), jnp.float32),
        jnp.zeros((bq, hd), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, init)
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Causal attention.  q,k,v: (B, H, S, hd) → (B, H, S, hd)."""
    b, h, s, hd = q.shape
    assert s % bq == 0 and s % bk == 0 and bq % bk == 0
    scale = hd**-0.5
    qf = q.reshape(b * h, s, hd)
    kf = k.reshape(b * h, s, hd)
    vf = v.reshape(b * h, s, hd)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, seq=s, scale=scale),
        grid=(b * h, s // bq),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Pure-jnp causal attention oracle."""
    b, h, s, hd = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * hd**-0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
