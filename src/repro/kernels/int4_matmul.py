"""Packed-storage int4 matmul — the production Pallas kernel.

The *memory* translation of DSP-packing density (DESIGN.md §2): weights live
in HBM packed two nibbles per byte (like operands packed into a DSP port),
halving weight bytes moved — the quantity that dominates decode-phase
rooflines.  Nibbles are unpacked inside VMEM with two arithmetic shifts and
fed to the MXU int8 path (``preferred_element_type=int32``).

Grid (M/bm, N/bn, K/bk); the packed weight block is (bk//2, bn) so the HBM
traffic for weights really is half of the int8 kernel's.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["int4_matmul", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (128, 128, 128)


def _kernel(x_ref, wp_ref, out_ref, *, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # (bm, bk) int8
    packed = wp_ref[...].astype(jnp.int8)  # (bk//2, bn) two nibbles per byte
    lo = (packed << 4) >> 4  # arithmetic shifts sign-extend the nibbles
    hi = packed >> 4
    k2, bn = packed.shape
    w = jnp.stack([lo, hi], axis=1).reshape(bk, bn)  # (bk, bn) int8

    out_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def int4_matmul(
    x_q: jax.Array,
    w_packed: jax.Array,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """(M, K) int8 × (K//2, N) packed-nibble uint8 → (M, N) int32."""
    m, k = x_q.shape
    k2, n = w_packed.shape
    assert k == 2 * k2, (k, k2)
    bm, bn, bk = block
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape {(m, k, n)} not aligned to block {block}")

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x_q, w_packed)
