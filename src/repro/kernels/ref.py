"""Pure-jnp oracles for the Pallas kernels.

Two compute paths (see DESIGN.md §2):

* pair-packed "DSP-sim" matmul — the paper-faithful adaptation.  Activations
  (unsigned, offset-binary) and weights (signed) are packed in pairs along K
  into int32 words; ONE int32 multiply per pair produces the pair's
  dot-product contribution in the middle bit field (the dot-product variant
  of the paper's Eqn. 4: the outer-product cross terms land in the low/high
  fields).  ``n_pairs`` words are accumulated before the field is extracted,
  mirroring the paper's ``2**delta`` accumulation budget.  Multi-DSP
  *column* packing (``PackedDotSpec.n_columns``, the wide-datapath related
  work's decomposition) splits the activation into unsigned bit-slices, one
  packed-word stream per slice, and recombines the extracted dot fields by
  shifted summation — lifting the int32 ceiling to 8-bit operands.

* packed-storage int4 matmul — the production path: weights live in HBM as
  two nibbles per byte (the *memory* translation of packing density), are
  unpacked in VMEM and fed to the int8 MXU path.

``ref_packed_matmul`` is bit-accurate to the kernel (same chunking,
extraction and correction arithmetic) so kernels are tested for *bit
equality*, errors included.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import clauses

__all__ = [
    "PackedDotSpec",
    "PackedWeightWords",
    "CORRECTIONS",
    "INT4_EXACT",
    "INT4_NAIVE",
    "INT4_MR_OVERPACKED",
    "INT2_EXACT",
    "widen_for_shards",
    "extract_accumulated_field",
    "contamination_mask",
    "contamination_term",
    "contamination_terms",
    "slice_column",
    "pack_weight_words",
    "packed_tile_matmul",
    "packed_tile_matmul_prepacked",
    "ref_packed_matmul",
    "ref_packed_matmul_prepacked",
    "ref_quantized_matmul",
    "exact_int_matmul_fits_f32",
    "pack_int4_weights",
    "unpack_int4_weights",
    "ref_int4_matmul",
]

# Correction schemes of the pair-packed dot path, mirroring
# ``core.correction.SCHEMES`` (the ``approx`` C-port scheme has no dot-product
# analogue — the accumulated middle field carries its own sign):
#   * ``naive``   — floor extraction (biased, Xilinx white-paper semantics)
#   * ``full``    — round-half-up extraction, bit-exact for legal specs (§V-A)
#   * ``mr``      — overpacked spacing, naive extraction + MSB restore (§VI-B)
#   * ``mr+full`` — MSB restore *and* round-half-up (beyond-paper combination)
CORRECTIONS = ("naive", "full", "mr", "mr+full")


@dataclasses.dataclass(frozen=True)
class PackedDotSpec:
    """Parameters of the pair-packed int32 dot path.

    ``p``        — field spacing in bits (the paper's result width + δ).
    ``n_pairs``  — packed products accumulated per extraction
                   (the paper's ``2**delta`` accumulation budget).
    ``correction`` — one of :data:`CORRECTIONS`.
    ``mr_bits``  — overlap bits restored in the ``mr``/``mr+full`` modes
                   (how far below the exact spacing ``p`` was squeezed).
    ``n_columns`` — multi-DSP column packing (the wide-datapath related
                   work's column decomposition): the activation's ``bits_a``
                   bits are split into ``n_columns`` unsigned bit-slices of
                   :attr:`col_bits_a` bits each, every slice runs its own
                   packed-word stream ("column") against the SHARED packed
                   weights, and the per-column extracted dot fields are
                   recombined as ``Σ_j field_j << (j·col_bits_a)``.  All
                   legality budgets below then apply PER COLUMN, which is
                   what lifts the int32 ceiling: widths with no
                   single-column plan (8-bit operands) become exactly
                   packable by spreading one dot product across several
                   int32 words at the cost of ``n_columns`` multiplies per
                   packed word position.
    """

    bits_a: int = 4
    bits_w: int = 4
    p: int = 11
    n_pairs: int = 4
    correction: str = "full"
    mr_bits: int = 0
    n_columns: int = 1

    def __post_init__(self) -> None:
        if self.correction not in CORRECTIONS:
            raise ValueError(
                f"bad correction {self.correction!r}; options: {CORRECTIONS}"
            )
        if self.bits_a < 1 or self.bits_w < 2:
            raise ValueError(
                f"operand widths too narrow: bits_a={self.bits_a} (min 1), "
                f"bits_w={self.bits_w} (min 2, signed)"
            )
        if self.n_pairs < 1 or self.p < 1:
            raise ValueError(f"n_pairs={self.n_pairs} and p={self.p} must be >= 1")
        if self.n_columns < 1 or self.n_columns > self.bits_a:
            raise ValueError(
                f"n_columns={self.n_columns} must be in [1, bits_a="
                f"{self.bits_a}]: every column carries at least one "
                "activation bit"
            )
        if (self.n_columns - 1) * self.col_bits_a >= self.bits_a:
            # e.g. 4 columns of ceil(6/4)=2-bit slices: the 4th slice is
            # provably zero — the same plan with 3 columns is strictly
            # cheaper, so the wasteful spelling is rejected outright
            canonical = -(-self.bits_a // self.col_bits_a)
            raise ValueError(
                f"n_columns={self.n_columns} leaves the last column with no "
                f"activation bits ({self.col_bits_a}-bit slices cover "
                f"bits_a={self.bits_a} with {canonical} columns); use "
                f"n_columns={canonical}"
            )
        if self.uses_mr and self.mr_bits < 1:
            raise ValueError(
                f"correction {self.correction!r} restores overlapped MSBs and "
                "needs mr_bits >= 1"
            )
        if not self.uses_mr and self.mr_bits:
            raise ValueError(
                f"mr_bits={self.mr_bits} is only meaningful with an mr "
                f"correction, not {self.correction!r}"
            )
        # int32 budget: |packed partial sum| must stay below 2**31, PER
        # COLUMN — each column only ever sees a ``col_bits_a``-bit slice of
        # the activation.  The three terms are the high / middle / low result
        # fields of one column's packed word after accumulating ``n_pairs``
        # products.
        max_a = (1 << self.col_bits_a) - 1
        max_w = 1 << (self.bits_w - 1)
        top = self.n_pairs * max_a * max_w * (1 << (2 * self.p))
        mid = self.n_pairs * 2 * max_a * max_w * (1 << self.p)
        low = self.n_pairs * max_a * max_w
        total = top + mid + low
        if total >= 1 << 31:
            per_col = " per column" if self.n_columns > 1 else ""
            raise ValueError(
                f"{self._describe()} overflows the int32 accumulator budget: "
                f"the accumulated packed sum spans {total.bit_length()} bits"
                f"{per_col} but the int32 accumulator provides 31 value bits; "
                f"reduce n_pairs (={self.n_pairs}), the field spacing p "
                f"(={self.p}), or raise n_columns (={self.n_columns}) "
                f"[certificate clause: {clauses.CLAUSE_INT32_ACCUMULATOR}]"
            )
        # The accumulated middle (dot-product) field must fit the bits the
        # extraction reads back: ``p`` for exact-spacing schemes,
        # ``p + mr_bits`` once the MSB restore widens the read.
        mid_mag = self.n_pairs * 2 * max_a * max_w
        if mid_mag >= 1 << (self.extract_width - 1):
            need = mid_mag.bit_length() + 1
            if self.uses_mr:
                raise ValueError(
                    f"{self._describe()} overflows the restored middle field: "
                    f"the accumulated dot product needs {need} bits but "
                    f"p + mr_bits = {self.extract_width}; raise p, raise "
                    f"mr_bits or reduce n_pairs "
                    f"[certificate clause: {clauses.CLAUSE_MIDDLE_FIELD}]"
                )
            raise ValueError(
                f"{self._describe()} overflows the middle field: the "
                f"accumulated dot product needs {need} bits but the field "
                f"spacing provides p = {self.p}; raise p, reduce n_pairs or "
                "use an mr correction "
                f"[certificate clause: {clauses.CLAUSE_MIDDLE_FIELD}]"
            )
        # Extraction aliasing: the sign-extension at ``extract_width`` reads
        # back M + g, where g is the low field's floor/rounding residue
        # (g = floor(L / 2^p), or the round-half-up variant).  The middle
        # field fitting is NOT enough — if the residue pushes the read-back
        # value past the signed extract width the sign bit flips and the
        # whole field wraps (error ~2^extract_width, far beyond the
        # advertised |g| bound).  Reachable for aggressive mr_bits, e.g.
        # a3w2 p=7 n_pairs=73 mr_bits=5 passes every check above.
        low_lo = -self.n_pairs * max_a * max_w
        low_hi = self.n_pairs * max_a * (max_w - 1)
        if self.rounds_half_up:
            g_lo = ((low_lo >> (self.p - 1)) + 1) >> 1
            g_hi = ((low_hi >> (self.p - 1)) + 1) >> 1
        else:
            g_lo, g_hi = low_lo >> self.p, low_hi >> self.p
        mid_hi = self.n_pairs * 2 * max_a * (max_w - 1)
        bound = 1 << (self.extract_width - 1)
        if -mid_mag + g_lo < -bound or mid_hi + g_hi > bound - 1:
            raise ValueError(
                f"{self._describe()} aliases under extraction: the dot field "
                f"plus the low-field residue spans "
                f"[{-mid_mag + g_lo}, {mid_hi + g_hi}] but sign-extension at "
                f"p + mr_bits = {self.extract_width} bits only represents "
                f"[{-bound}, {bound - 1}]; raise p or reduce mr_bits "
                f"[certificate clause: {clauses.CLAUSE_EXTRACTION_ALIAS}]"
            )

    def _describe(self) -> str:
        cols = f", n_columns={self.n_columns}" if self.n_columns > 1 else ""
        return (
            f"PackedDotSpec(a{self.bits_a}w{self.bits_w}, p={self.p}, "
            f"n_pairs={self.n_pairs}, {self.correction}{cols})"
        )

    @property
    def uses_mr(self) -> bool:
        return self.correction in ("mr", "mr+full")

    @property
    def rounds_half_up(self) -> bool:
        return self.correction in ("full", "mr+full")

    @property
    def chunk(self) -> int:
        """K elements consumed per extraction group (all columns together)."""
        return 2 * self.n_pairs

    @property
    def col_bits_a(self) -> int:
        """Activation bits per column slice (top slice may carry fewer)."""
        return -(-self.bits_a // self.n_columns)

    def column_shift(self, j: int) -> int:
        """Bit offset of column ``j``'s slice in the full activation — and
        therefore the recombination shift of its extracted dot field."""
        return j * self.col_bits_a

    @property
    def extract_width(self) -> int:
        return self.p + (self.mr_bits if self.uses_mr else 0)

    @property
    def delta(self) -> int:
        """Per-product padding in the paper's notation: spacing − result
        width (per column: a column's products are col_bits_a × bits_w)."""
        return self.p - (self.col_bits_a + self.bits_w)

    @property
    def provably_exact(self) -> bool:
        """Whether extraction is bit-exact for EVERY operand combination.

        ``full`` is exact by the legality checks (the middle field fits
        ``p`` and round-half-up absorbs the low-field borrow).  ``mr+full``
        is exact iff additionally the accumulated low field stays below
        ``2**(p-1)`` — then its spill into the squeezed middle field is
        fully absorbed by the rounding while the high-field contamination
        is subtracted exactly.  The biased schemes are never exact.  Column
        recombination preserves exactness: the slice identity
        ``a = Σ_j a_j · 2^(j·col_bits_a)`` is exact and the dot product is
        linear in the activation, so the recombined sum is exact whenever
        every column's extraction is."""
        if self.correction == "full":
            return True
        if self.correction == "mr+full":
            # exact iff round-half-up of the low field is identically zero:
            # L in [-n·amax·wmag, n·amax·(wmag-1)], and rhu(v) == 0 for
            # v in [-2^(p-1), 2^(p-1) - 1] — the lower bound is INCLUSIVE
            # (rhu(-2^(p-1)) = floor((-1+1)/2) = 0), hence <=.  The
            # analysis.verify interval walk derives the same boundary.
            max_a = (1 << self.col_bits_a) - 1
            max_w = 1 << (self.bits_w - 1)
            return self.n_pairs * max_a * max_w <= 1 << (self.p - 1)
        return False

    def name(self) -> str:
        """Stable human-readable plan id, e.g. ``a4w4-p10-n16-mr+full`` or
        ``a8w8-p11-n1-full-c4`` for a column-packed plan."""
        cols = f"-c{self.n_columns}" if self.n_columns > 1 else ""
        return (
            f"a{self.bits_a}w{self.bits_w}-p{self.p}-n{self.n_pairs}"
            f"-{self.correction}{cols}"
        )

    def density_vs_int8(self) -> float:
        """Multiplies saved vs one-multiply-per-product: each packed word
        computes 2 products, but every pair position costs ``n_columns``
        words."""
        return 2.0 / self.n_columns


# Optimal 32-bit-budget presets (derived in DESIGN.md §2 / EXPERIMENTS §Perf).
INT4_EXACT = PackedDotSpec(bits_a=4, bits_w=4, p=11, n_pairs=4, correction="full")
INT4_NAIVE = PackedDotSpec(bits_a=4, bits_w=4, p=11, n_pairs=4, correction="naive")
# Overpacked: spacing squeezed 13->10, 4x longer accumulation chains; the 3
# contaminated MSBs of the middle field are restored from exactly-computed
# LSBs of the high field (paper Eqns. 8/9 generalized to sums: products mod 8),
# plus round-half-up for the low-field borrow (beyond-paper combination).
INT4_MR_OVERPACKED = PackedDotSpec(
    bits_a=4, bits_w=4, p=10, n_pairs=16, correction="mr+full", mr_bits=3
)
INT2_EXACT = PackedDotSpec(bits_a=2, bits_w=2, p=10, n_pairs=32, correction="full")


def widen_for_shards(spec: PackedDotSpec, n_shards: int) -> PackedDotSpec:
    """The spec a ``n_shards``-way contraction-axis sharding must satisfy.

    Tensor-parallel row sharding reduces packed partial sums across devices
    IN WORD SPACE (psum of int32 packed words BEFORE field extraction — the
    same shifted-summation algebra as column recombination, stretched across
    the mesh).  The post-reduce word therefore accumulates
    ``n_shards * n_pairs`` products per extraction group, and every legality
    budget of :class:`PackedDotSpec` — the int32 accumulator ceiling, the
    middle-field width, extraction aliasing — must hold at THAT effective
    accumulation length, not the per-device one.

    Constructing the widened spec IS the legality check: an illegal sharding
    raises the constructor's certificate-clause-citing ``ValueError``
    (CLAUSE_INT32_ACCUMULATOR / CLAUSE_MIDDLE_FIELD /
    CLAUSE_EXTRACTION_ALIAS), exactly like an illegal ``n_pairs`` would.
    Extraction itself reads only ``p`` / ``extract_width`` / the correction
    — never ``n_pairs`` — so extracting the psummed word with the original
    spec is the same operation as extracting with the widened one; widening
    matters only for build-time legality and certification
    (see DESIGN.md §4).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards} must be >= 1")
    if n_shards == 1:
        return spec
    try:
        return dataclasses.replace(spec, n_pairs=n_shards * spec.n_pairs)
    except ValueError as e:
        raise ValueError(
            f"{spec.name()} cannot be row-sharded {n_shards} ways: the "
            f"cross-device word-space reduction accumulates "
            f"{n_shards}x{spec.n_pairs} products per extraction group and "
            f"the widened spec is illegal — {e}"
        ) from e


def _sext(v: jax.Array, width: int) -> jax.Array:
    mask = jnp.int32((1 << width) - 1)
    sign = jnp.int32(1 << (width - 1))
    return ((v & mask) ^ sign) - sign


def contamination_mask(spec: PackedDotSpec) -> int:
    """Bit mask of the high-field LSBs that corrupt an overpacked middle field."""
    return (1 << spec.mr_bits) - 1


def contamination_term(xa_chunk: jax.Array, ws_chunk: jax.Array,
                       spec: PackedDotSpec) -> jax.Array:
    """The high field's LSBs that leaked into the squeezed middle field.

    ``Σ a_odd·w_even mod 2**mr_bits`` over one extraction chunk, recomputed
    exactly from the operands (paper Eqns. 8/9 generalized to sums — only
    the low ``mr_bits`` of each operand can influence the result, so the
    masked dot is bit-exact and cheap).  Shared by the jnp reference and
    the Pallas kernel, like :func:`extract_accumulated_field`.

    ``xa_chunk``: (m, n_pairs, 2) paired activations;
    ``ws_chunk``: (n_pairs, 2, n) paired weights.
    """
    mask = jnp.int32(contamination_mask(spec))
    return jax.lax.dot_general(
        xa_chunk[:, :, 1] & mask,
        ws_chunk[:, 0, :] & mask,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ) & mask


def contamination_terms(xa: jax.Array, ws: jax.Array,
                        spec: PackedDotSpec) -> jax.Array:
    """Chunk-batched :func:`contamination_term`: every extraction group's
    contamination in ONE masked dot_general.

    ``xa``: (m, n_chunks, n_pairs, 2); ``ws``: (n_chunks, n_pairs, 2, n);
    returns (n_chunks, m, n).
    """
    mask = jnp.int32(contamination_mask(spec))
    return jax.lax.dot_general(
        xa[..., 1] & mask,        # (m, n_chunks, n_pairs)
        ws[..., 0, :] & mask,     # (n_chunks, n_pairs, n)
        (((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.int32,
    ) & mask


def extract_accumulated_field(
    partial: jax.Array, spec: PackedDotSpec, contam: jax.Array | None = None
) -> jax.Array:
    """Extract the accumulated middle (dot-product) field of a packed sum.

    This single helper IS the extraction semantics of the whole compute path:
    both ``ref_packed_matmul`` and the Pallas kernel call it, so the two are
    bit-identical by construction (and the parity matrix test re-verifies it
    empirically for every enumerated plan).

    ``contam`` — for mr corrections, the low ``mr_bits`` of the accumulated
    high field (``Σ a_odd·w_even mod 2**mr_bits``), recomputed exactly from
    the operands (paper Eqns. 8/9 generalized to sums) and subtracted after
    sign extension.
    """
    we = spec.extract_width
    if spec.rounds_half_up:
        t = ((partial >> (spec.p - 1)) + 1) >> 1
    else:  # naive floor extraction (arithmetic shift)
        t = partial >> spec.p
    e = _sext(t, we)
    if spec.uses_mr:
        if contam is None:
            raise ValueError("mr extraction needs the contamination term")
        e = _sext(e - (contam << (we - spec.mr_bits)), we)
    return e


def slice_column(x_u: jax.Array, spec: PackedDotSpec, j: int) -> jax.Array:
    """Column ``j``'s unsigned activation bit-slice (col_bits_a bits)."""
    if spec.n_columns == 1:
        return x_u.astype(jnp.int32)
    mask = jnp.int32((1 << spec.col_bits_a) - 1)
    return (x_u.astype(jnp.int32) >> spec.column_shift(j)) & mask


def _pad_k(x_u: jax.Array, w_s: jax.Array, mult: int):
    """Zero-pad the contraction axis to a multiple of ``mult``.

    Zero operand pairs contribute exactly zero in every correction scheme
    (packed words, extractions and contamination terms are all zero), so
    padding is bit-transparent."""
    k = x_u.shape[1]
    pad = (-k) % mult
    if pad:
        x_u = jnp.pad(x_u, ((0, 0), (0, pad)))
        w_s = jnp.pad(w_s, ((0, pad), (0, 0)))
    return x_u, w_s


class PackedWeightWords(NamedTuple):
    """Weights packed ONCE for reuse across many packed matmuls.

    ``words``: (n_chunks, n_pairs, n) int32 — each pair's packed word
    ``w_even + (w_odd << p)`` grouped into extraction chunks.
    ``wsc``: (n_chunks, n_pairs, 2, n) int32 contamination operands, built
    ONLY for mr corrections (the masked high-field dot needs the raw paired
    weights); ``None`` for exact-spacing plans — non-mr plans pay no
    reshape/traffic for an operand stream they never read.
    """

    words: jax.Array
    wsc: jax.Array | None

    @property
    def k(self) -> int:
        """Contraction length the words cover (a multiple of the chunk)."""
        return self.words.shape[-3] * 2 * self.words.shape[-2]


def pack_weight_words(w_s: jax.Array, spec: PackedDotSpec) -> PackedWeightWords:
    """The PACK stage of the packed matmul: (k, n) signed ints → reusable
    :class:`PackedWeightWords`.  Ragged ``k`` is zero-padded to a whole
    number of extraction chunks (bit-transparent — see :func:`_pad_k`).

    This is the "pack once" half of the paper's economics: operands are
    packed a single time (at quantize/engine-build time in serving) and the
    words are reused by every subsequent matmul, instead of being rebuilt
    from the stored integers on every K-step of every call.
    """
    k, n = w_s.shape
    pad = (-k) % spec.chunk
    if pad:
        w_s = jnp.pad(w_s, ((0, pad), (0, 0)))
        k += pad
    n_chunks = k // spec.chunk
    ws = w_s.astype(jnp.int32).reshape(k // 2, 2, n)
    words = (ws[:, 1, :] + (ws[:, 0, :] << spec.p)).reshape(
        n_chunks, spec.n_pairs, n
    )
    wsc = ws.reshape(n_chunks, spec.n_pairs, 2, n) if spec.uses_mr else None
    return PackedWeightWords(words, wsc)


def packed_tile_matmul_prepacked(
    x_u: jax.Array,
    words: jax.Array,
    wsc: jax.Array | None,
    spec: PackedDotSpec,
) -> jax.Array:
    """The COMPUTE stage: already-packed weight words × unsigned activations.

    Shared verbatim by the jnp reference and BOTH Pallas kernel bodies (the
    repacking and the prepacked entry), so all of them are bit-identical by
    construction.  ``x_u``: (m, k) with ``k = n_chunks * spec.chunk``;
    ``words``/``wsc`` as produced by :func:`pack_weight_words`.

    Per column: pack the activation slice's pair words, contract ALL
    extraction groups in one chunk-batched dot_general (n_pairs wide
    multiply-accumulates per packed word — no per-chunk python unroll, so
    n_pairs=1 column plans like a8w8 don't explode into hundreds of rank-1
    dots), extract every group's middle field, sum the fields (int32
    addition is associative mod 2**32) and recombine at the slice offset.
    Multi-column plans reuse the SAME packed weight words for every stream.
    """
    m, k = x_u.shape
    n_chunks, n_pairs, n = words.shape
    if spec.uses_mr and wsc is None:
        raise ValueError(
            f"{spec.name()} is an mr plan: the prepacked compute stage needs "
            "the contamination operands (pack_weight_words builds them)"
        )
    acc = jnp.zeros((m, n), dtype=jnp.int32)
    for j in range(spec.n_columns):
        xa = slice_column(x_u, spec, j).reshape(m, k // 2, 2)
        a_words = (xa[:, :, 0] + (xa[:, :, 1] << spec.p)).reshape(
            m, n_chunks, spec.n_pairs
        )
        partial = jax.lax.dot_general(   # (n_chunks, m, n), batched chunks
            a_words,
            words,
            (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.int32,
        )
        contam = (
            contamination_terms(
                xa.reshape(m, n_chunks, spec.n_pairs, 2), wsc, spec
            )
            if spec.uses_mr else None
        )
        field = extract_accumulated_field(partial, spec, contam)
        col = jnp.sum(field, axis=0)
        shift = spec.column_shift(j)
        acc = acc + (col << shift if shift else col)
    return acc


def packed_tile_matmul(x_u: jax.Array, w_s: jax.Array,
                       spec: PackedDotSpec) -> jax.Array:
    """Pack + compute in one call (the per-call path): (m, k) unsigned ×
    (k, n) signed → (m, n) int32, ``k`` a multiple of ``spec.chunk``.

    Kept as the kernel-body entry for callers whose weights change every
    call (training-style use); serving packs once via
    :func:`pack_weight_words` and runs only the compute stage per step.
    """
    packed = pack_weight_words(w_s, spec)
    return packed_tile_matmul_prepacked(x_u, packed.words, packed.wsc, spec)


def ref_packed_matmul(
    x_u: jax.Array, w_s: jax.Array, spec: PackedDotSpec = INT4_EXACT
) -> jax.Array:
    """Bit-accurate jnp mirror of the pair-packed Pallas kernel.

    ``x_u``: (M, K) unsigned ints (0..2^bits_a-1) stored in any int dtype.
    ``w_s``: (K, N) signed ints.  Ragged K is zero-padded to ``spec.chunk``.
    Returns int32 (M, N).

    Multi-column plans (``spec.n_columns > 1``) run one packed-word stream
    per activation bit-slice against the SAME packed weights and recombine
    each extracted dot field shifted by its slice offset — all in wrapping
    int32 arithmetic, so kernel/ref/simulator stay bit-identical even where
    a (caller-side) output overflow wraps.  The compute itself lives in
    :func:`packed_tile_matmul`, shared with the kernel body.
    """
    x_u, w_s = _pad_k(x_u, w_s, spec.chunk)
    return packed_tile_matmul(x_u, w_s, spec)


def ref_packed_matmul_prepacked(
    x_u: jax.Array,
    packed: PackedWeightWords,
    spec: PackedDotSpec = INT4_EXACT,
) -> jax.Array:
    """jnp prepacked matmul: consume :func:`pack_weight_words` output.

    Bit-identical to ``ref_packed_matmul(x_u, w_s, spec)`` for the weights
    the words were packed from (the compute stage is shared code); ``x_u``'s
    K is zero-padded up to the words' chunk grid."""
    k = x_u.shape[1]
    pad = packed.k - k
    if pad < 0:
        raise ValueError(
            f"activation K={k} exceeds the packed weights' K={packed.k}"
        )
    if pad:
        x_u = jnp.pad(x_u, ((0, 0), (0, pad)))
    return packed_tile_matmul_prepacked(x_u, packed.words, packed.wsc, spec)


def exact_int_matmul_fits_f32(k: int, max_a: int, max_w: int) -> bool:
    """Whether an integer matmul with |a| <= max_a, |w| <= max_w over a
    K-long contraction is EXACT when evaluated in f32.

    Every partial sum is an integer of magnitude <= k * max_a * max_w; f32
    represents all integers up to 2**24 exactly, so as long as that bound
    fits, an f32 GEMM (which hits the fast dense path on CPU/GPU backends
    where int dots lower to scalar loops) returns bit-identical integers to
    the int32 dot.  The serving fast path uses this to run *exact* packed
    plans through the float unit without changing a single output bit.
    """
    return k * max_a * max_w < 1 << 24


def ref_quantized_matmul(x_u: jax.Array, w_s: jax.Array) -> jax.Array:
    """The mathematically exact unsigned×signed integer matmul (int32)."""
    return jax.lax.dot_general(
        x_u.astype(jnp.int32),
        w_s.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


# ---- packed-storage int4 (production path) ------------------------------


def pack_int4_weights(w_s: np.ndarray | jax.Array) -> jax.Array:
    """(K, N) int4 values -> (K//2, N) uint8, two nibbles per byte."""
    w = jnp.asarray(w_s, dtype=jnp.int8)
    k = w.shape[0]
    if k % 2:
        raise ValueError("K must be even to pack nibbles")
    lo = w[0::2] & 0xF
    hi = w[1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4_weights(packed: jax.Array) -> jax.Array:
    """(K//2, N) uint8 -> (K, N) int8 with sign-extended nibbles."""
    b = packed.astype(jnp.int8)
    lo = (b << 4) >> 4  # arithmetic shift sign-extends the low nibble
    hi = b >> 4
    k2, n = packed.shape
    out = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
    return out


def ref_int4_matmul(x_q: jax.Array, w_packed: jax.Array) -> jax.Array:
    """Oracle for the production kernel: unpack then exact int32 matmul."""
    w = unpack_int4_weights(w_packed)
    return jax.lax.dot_general(
        x_q.astype(jnp.int32),
        w.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
