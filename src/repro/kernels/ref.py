"""Pure-jnp oracles for the Pallas kernels.

Two compute paths (see DESIGN.md §2):

* pair-packed "DSP-sim" matmul — the paper-faithful adaptation.  Activations
  (unsigned, offset-binary) and weights (signed) are packed in pairs along K
  into int32 words; ONE int32 multiply per pair produces the pair's
  dot-product contribution in the middle bit field (the dot-product variant
  of the paper's Eqn. 4: the outer-product cross terms land in the low/high
  fields).  ``n_pairs`` words are accumulated before the field is extracted,
  mirroring the paper's ``2**delta`` accumulation budget.

* packed-storage int4 matmul — the production path: weights live in HBM as
  two nibbles per byte (the *memory* translation of packing density), are
  unpacked in VMEM and fed to the int8 MXU path.

``ref_packed_matmul`` is bit-accurate to the kernel (same chunking,
extraction and correction arithmetic) so kernels are tested for *bit
equality*, errors included.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PackedDotSpec",
    "INT4_EXACT",
    "INT4_NAIVE",
    "INT4_MR_OVERPACKED",
    "INT2_EXACT",
    "ref_packed_matmul",
    "ref_quantized_matmul",
    "pack_int4_weights",
    "unpack_int4_weights",
    "ref_int4_matmul",
]


@dataclasses.dataclass(frozen=True)
class PackedDotSpec:
    """Parameters of the pair-packed int32 dot path.

    ``p``        — field spacing in bits (the paper's result width + δ).
    ``n_pairs``  — packed products accumulated per extraction
                   (the paper's ``2**delta`` accumulation budget).
    ``correction`` — ``naive`` (biased, Xilinx white-paper semantics),
                   ``full`` (round-half-up, exact — paper §V-A) or
                   ``mr`` (overpacked + MSB-restore, paper §VI-B).
    ``mr_bits``  — overlap bits restored in ``mr`` mode.
    """

    bits_a: int = 4
    bits_w: int = 4
    p: int = 11
    n_pairs: int = 4
    correction: str = "full"
    mr_bits: int = 0

    def __post_init__(self) -> None:
        if self.correction not in ("naive", "full", "mr"):
            raise ValueError(f"bad correction {self.correction!r}")
        max_a = (1 << self.bits_a) - 1
        max_w = 1 << (self.bits_w - 1)
        # int32 budget: |packed product sum| must stay below 2**31.
        top = self.n_pairs * max_a * max_w * (1 << (2 * self.p))
        mid = self.n_pairs * 2 * max_a * max_w * (1 << self.p)
        low = self.n_pairs * max_a * max_w
        if top + mid + low >= 1 << 31:
            raise ValueError("spec overflows the int32 accumulator budget")
        if self.correction != "mr":
            # exact extraction needs the accumulated middle field to fit p bits
            if self.n_pairs * 2 * max_a * max_w >= 1 << (self.p - 1):
                raise ValueError(
                    "middle field overflows spacing p; use mr correction"
                )

    @property
    def chunk(self) -> int:
        """K elements consumed per extraction."""
        return 2 * self.n_pairs

    @property
    def extract_width(self) -> int:
        return self.p + (self.mr_bits if self.correction == "mr" else 0)

    def density_vs_int8(self) -> float:
        """Multiplies saved vs one-multiply-per-product (2 products/mult)."""
        return 2.0


# Optimal 32-bit-budget presets (derived in DESIGN.md §2 / EXPERIMENTS §Perf).
INT4_EXACT = PackedDotSpec(bits_a=4, bits_w=4, p=11, n_pairs=4, correction="full")
INT4_NAIVE = PackedDotSpec(bits_a=4, bits_w=4, p=11, n_pairs=4, correction="naive")
# Overpacked: spacing squeezed 11->10, 4x longer accumulation chains; the 3
# contaminated MSBs of the middle field are restored from exactly-computed
# LSBs of the high field (paper Eqns. 8/9 generalized to sums: products mod 8).
INT4_MR_OVERPACKED = PackedDotSpec(
    bits_a=4, bits_w=4, p=10, n_pairs=16, correction="mr", mr_bits=3
)
INT2_EXACT = PackedDotSpec(bits_a=2, bits_w=2, p=10, n_pairs=32, correction="full")


def _sext(v: jax.Array, width: int) -> jax.Array:
    mask = jnp.int32((1 << width) - 1)
    sign = jnp.int32(1 << (width - 1))
    return ((v & mask) ^ sign) - sign


def _pack_words(x_u: jax.Array, w_s: jax.Array, spec: PackedDotSpec):
    """Pair along K: A = a_even + a_odd<<p ; W = w_odd + w_even<<p."""
    m, k = x_u.shape
    _, n = w_s.shape
    xa = x_u.astype(jnp.int32).reshape(m, k // 2, 2)
    ws = w_s.astype(jnp.int32).reshape(k // 2, 2, n)
    a_words = xa[:, :, 0] + (xa[:, :, 1] << spec.p)
    w_words = ws[:, 1, :] + (ws[:, 0, :] << spec.p)
    return a_words, w_words


def ref_packed_matmul(
    x_u: jax.Array, w_s: jax.Array, spec: PackedDotSpec = INT4_EXACT
) -> jax.Array:
    """Bit-accurate jnp mirror of the pair-packed Pallas kernel.

    ``x_u``: (M, K) unsigned ints (0..2^bits_a-1) stored in any int dtype.
    ``w_s``: (K, N) signed ints.  K must divide by ``spec.chunk``.
    Returns int32 (M, N).
    """
    m, k = x_u.shape
    if k % spec.chunk:
        raise ValueError(f"K={k} not a multiple of chunk={spec.chunk}")
    a_words, w_words = _pack_words(x_u, w_s, spec)
    n = w_s.shape[1]
    acc = jnp.zeros((m, n), dtype=jnp.int32)
    xa = x_u.astype(jnp.int32).reshape(m, k // 2, 2)
    ws = w_s.astype(jnp.int32).reshape(k // 2, 2, n)
    for c in range(k // spec.chunk):
        sl = slice(c * spec.n_pairs, (c + 1) * spec.n_pairs)
        partial = jax.lax.dot_general(
            a_words[:, sl],
            w_words[sl, :],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + _extract_mid(partial, spec, xa[:, sl], ws[sl])
    return acc


def _extract_mid(partial, spec: PackedDotSpec, xa_chunk, ws_chunk):
    """Extract the accumulated middle (dot-product) field of the packed sum."""
    we = spec.extract_width
    if spec.correction == "full":
        t = ((partial >> (spec.p - 1)) + 1) >> 1
        return _sext(t, we)
    if spec.correction == "naive":
        return _sext(partial >> spec.p, we)
    # mr: spacing was squeezed by mr_bits; the top mr_bits of the middle
    # field overlap the high field's LSBs.  Those LSBs are the low bits of
    # Σ a_odd·w_even, computed exactly mod 2**mr_bits and subtracted
    # (then round-half-up for the low-field borrow, beyond-paper combo).
    mask = jnp.int32((1 << spec.mr_bits) - 1)
    contam = jax.lax.dot_general(
        xa_chunk[:, :, 1] & mask,
        ws_chunk[:, 0, :] & mask,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ) & mask
    t = ((partial >> (spec.p - 1)) + 1) >> 1
    e = _sext(t, we)
    return _sext(e - (contam << (we - spec.mr_bits)), we)


def ref_quantized_matmul(x_u: jax.Array, w_s: jax.Array) -> jax.Array:
    """The mathematically exact unsigned×signed integer matmul (int32)."""
    return jax.lax.dot_general(
        x_u.astype(jnp.int32),
        w_s.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


# ---- packed-storage int4 (production path) ------------------------------


def pack_int4_weights(w_s: np.ndarray | jax.Array) -> jax.Array:
    """(K, N) int4 values -> (K//2, N) uint8, two nibbles per byte."""
    w = jnp.asarray(w_s, dtype=jnp.int8)
    k = w.shape[0]
    if k % 2:
        raise ValueError("K must be even to pack nibbles")
    lo = w[0::2] & 0xF
    hi = w[1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4_weights(packed: jax.Array) -> jax.Array:
    """(K//2, N) uint8 -> (K, N) int8 with sign-extended nibbles."""
    b = packed.astype(jnp.int8)
    lo = (b << 4) >> 4  # arithmetic shift sign-extends the low nibble
    hi = b >> 4
    k2, n = packed.shape
    out = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)
    return out


def ref_int4_matmul(x_q: jax.Array, w_packed: jax.Array) -> jax.Array:
    """Oracle for the production kernel: unpack then exact int32 matmul."""
    w = unpack_int4_weights(w_packed)
    return jax.lax.dot_general(
        x_q.astype(jnp.int32),
        w.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
