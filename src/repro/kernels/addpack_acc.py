"""Addition-packing accumulator — paper §VII as a Pallas TPU kernel.

DSP48 48-bit accumulator → int32 VPU lanes: two narrow accumulators live in
one int32 word (``lane_bits`` payload + ``guard_bits`` carry catcher each),
so one vector add advances TWO integrations — the §VII density win on the
TPU's 8×128 int32 lanes.  Guard bits bound how many packed adds may run
between extractions (``2**guard_bits``, the §VII accumulation budget);
the kernel unpacks-and-spills exactly at that cadence, so results are EXACT
(the guard-bit variant of Fig. 8), validated bit-for-bit vs ``ref``.

Layout: terms (T, 2, N) int32 (narrow signed values), grid over N blocks,
output (2, N) int32 sums.  SNN usage: ``terms[t] = W @ spikes[t]`` slices.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["addpack_accumulate", "LANE_BITS", "GUARD_BITS"]

LANE_BITS = 14  # payload bits per lane
GUARD_BITS = 1  # carries absorbed between extractions
BLOCK_N = 256


def _sext(v, width: int):
    mask = jnp.int32((1 << width) - 1)
    sign = jnp.int32(1 << (width - 1))
    return ((v & mask) ^ sign) - sign


def _kernel(terms_ref, out_ref, *, t_steps: int, lane_bits: int, guard: int):
    field = lane_bits + guard
    mask = jnp.int32((1 << lane_bits) - 1)
    chunk = 1 << guard

    lo_total = jnp.zeros_like(out_ref[0])
    hi_total = jnp.zeros_like(out_ref[0])
    for start in range(0, t_steps, chunk):
        acc = jnp.zeros_like(out_ref[0])
        for t in range(start, min(start + chunk, t_steps)):
            lo = terms_ref[t, 0, :] & mask  # two's-complement lane fields
            hi = terms_ref[t, 1, :] & mask
            acc = acc + (lo | (hi << field))  # ONE add, TWO accumulations
        lo_total = lo_total + _sext(acc, lane_bits)
        hi_total = hi_total + _sext(acc >> field, lane_bits)
    out_ref[0, :] = lo_total
    out_ref[1, :] = hi_total


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def addpack_accumulate(
    terms: jax.Array,
    block_n: int = BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    """(T, 2, N) int32 narrow values → (2, N) int32 exact lane sums."""
    t_steps, lanes, n = terms.shape
    assert lanes == 2, "two lanes per int32 word"
    if n % block_n:
        raise ValueError(f"N={n} not a multiple of block_n={block_n}")
    return pl.pallas_call(
        functools.partial(
            _kernel, t_steps=t_steps, lane_bits=LANE_BITS, guard=GUARD_BITS
        ),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((t_steps, 2, block_n), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((2, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.int32),
        interpret=interpret,
    )(terms)


def ref_addpack_accumulate(terms: jax.Array) -> jax.Array:
    """Oracle: plain per-lane integer sums."""
    return jnp.sum(terms.astype(jnp.int32), axis=0)
