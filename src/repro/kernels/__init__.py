"""Pallas kernels and their bit-accurate jnp oracles.

``ref`` holds the pure-jnp reference implementations (``PackedDotSpec``,
pack/compute split, widening); ``packed_matmul`` / ``int4_matmul`` /
``addpack_acc`` are the Pallas entries, each pinned bit-identical to the
oracle by ``tests/test_kernel_parity_matrix.py``; ``ops`` is the
dispatch layer the serving engines call.
"""
