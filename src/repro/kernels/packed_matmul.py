"""Pair-packed "DSP-sim" matmul — the paper's technique as a Pallas kernel.

TPU adaptation of DSP-Packing (DESIGN.md §2): the DSP48E2's wide multiplier
becomes the VPU's 32-bit integer multiply lanes; the 48-bit accumulator
becomes int32 accumulation with the paper's δ-padding governing how many
packed products are accumulated (``spec.n_pairs``) between field
extractions.  One int32 multiply computes TWO narrow products (the pair's
dot-product contribution lands in the middle bit field), halving multiply
count for sub-8-bit operands.

The kernel dispatches ANY legal :class:`~repro.kernels.ref.PackedDotSpec`
(arbitrary operand widths, n_pairs counts, correction schemes and multi-DSP
column counts — the plans the ``repro.tuning`` enumerator emits), not just
the int4 presets.  ``spec.n_columns > 1`` spreads one dot product across
several packed int32 words: each activation bit-slice drives its own
packed-word stream against the shared packed weights, fields are extracted
per column and recombined by shifted int32 summation (the wide-datapath
related work's column decomposition) — this is what lifts the int32
accumulator ceiling to exact a8w8 / a8w4 plans.
Extraction semantics live in ``ref.extract_accumulated_field``, shared with
the jnp oracle, so kernel and reference are bit-identical by construction.

Correctness modes mirror the paper exactly:
  * ``naive``   — biased floor extraction (Xilinx white-paper semantics, §V)
  * ``full``    — round-half-up, bit-exact vs the integer matmul (§V-A)
  * ``mr``      — overpacked spacing + MSB restore from cheap LSBs (§VI-B)
  * ``mr+full`` — MSB restore and round-half-up (beyond-paper combination)

Layout: grid (M/bm, N/bn, K/bk); x/w tiles in VMEM; the int32 output block
doubles as the accumulator across K steps (revisited output block).  Ragged
M/N/K are zero-padded to the block grid internally (zero operand pairs are
bit-transparent in every scheme) and the true (M, N) slice is returned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import PackedDotSpec, INT4_EXACT

__all__ = ["packed_matmul", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk) — MXU/VPU aligned


def _kernel(x_ref, w_ref, out_ref, *, spec: PackedDotSpec):
    """One (bm, bk)×(bk, bn) step; accumulates into the revisited out block."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)  # (bm, bk) unsigned payload
    w = w_ref[...].astype(jnp.int32)  # (bk, bn) signed payload
    # The whole pack → chunk-batched wide multiply → extract → column
    # recombination pipeline is ref.packed_tile_matmul, shared VERBATIM
    # with the jnp reference — kernel == ref by construction.
    out_ref[...] += ref.packed_tile_matmul(x, w, spec)


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret")
)
def packed_matmul(
    x_u: jax.Array,
    w_s: jax.Array,
    spec: PackedDotSpec = INT4_EXACT,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """(M, K) unsigned × (K, N) signed → (M, N) int32 via pair packing.

    Any shape is accepted: M/N/K are zero-padded up to the block grid and
    the result is sliced back to (M, N).  ``block[2]`` must be a multiple
    of ``spec.chunk`` so every K tile holds whole extraction groups.
    """
    m, k = x_u.shape
    k2, n = w_s.shape
    assert k == k2, (k, k2)
    bm, bn, bk = block
    if bk % spec.chunk:
        raise ValueError(
            f"block bk={bk} must be a multiple of spec.chunk={spec.chunk} "
            f"({spec.name()})"
        )
    x_u = _pad_axis(_pad_axis(x_u, bm, 0), bk, 1)
    w_s = _pad_axis(_pad_axis(w_s, bk, 0), bn, 1)
    mp, kp = x_u.shape
    np_ = w_s.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(x_u, w_s)
    return out[:m, :n]
