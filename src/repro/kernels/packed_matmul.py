"""Pair-packed "DSP-sim" matmul — the paper's technique as a Pallas kernel.

TPU adaptation of DSP-Packing (DESIGN.md §2): the DSP48E2's wide multiplier
becomes the VPU's 32-bit integer multiply lanes; the 48-bit accumulator
becomes int32 accumulation with the paper's δ-padding governing how many
packed products are accumulated (``spec.n_pairs``) between field
extractions.  One int32 multiply computes TWO narrow products (the pair's
dot-product contribution lands in the middle bit field), halving multiply
count for sub-8-bit operands.

The kernel dispatches ANY legal :class:`~repro.kernels.ref.PackedDotSpec`
(arbitrary operand widths, n_pairs counts, correction schemes and multi-DSP
column counts — the plans the ``repro.tuning`` enumerator emits), not just
the int4 presets.  ``spec.n_columns > 1`` spreads one dot product across
several packed int32 words: each activation bit-slice drives its own
packed-word stream against the shared packed weights, fields are extracted
per column and recombined by shifted int32 summation (the wide-datapath
related work's column decomposition) — this is what lifts the int32
accumulator ceiling to exact a8w8 / a8w4 plans.
Extraction semantics live in ``ref.extract_accumulated_field``, shared with
the jnp oracle, so kernel and reference are bit-identical by construction.

Correctness modes mirror the paper exactly:
  * ``naive``   — biased floor extraction (Xilinx white-paper semantics, §V)
  * ``full``    — round-half-up, bit-exact vs the integer matmul (§V-A)
  * ``mr``      — overpacked spacing + MSB restore from cheap LSBs (§VI-B)
  * ``mr+full`` — MSB restore and round-half-up (beyond-paper combination)

Layout: grid (M/bm, N/bn, K/bk); x/w tiles in VMEM; the int32 output block
doubles as the accumulator across K steps (revisited output block).  Ragged
M/N/K are zero-padded to the block grid internally (zero operand pairs are
bit-transparent in every scheme) and the true (M, N) slice is returned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import PackedDotSpec, INT4_EXACT

__all__ = [
    "packed_matmul",
    "packed_matmul_prepacked",
    "DEFAULT_BLOCK",
    "DECODE_BLOCK",
    "default_block_for",
]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk) — MXU/VPU aligned
# Decode GEMVs carry a handful of rows (the serving slot count); a 128-row
# M block would pad them ~16-64x.  The small-M default keeps the grid square
# in N/K while the M axis hugs the real batch.
DECODE_BLOCK = (8, 128, 128)


def default_block_for(m: int, spec: PackedDotSpec | None = None):
    """Phase-appropriate default block: small-M GEMV blocks for decode-sized
    ``m``, the MXU-aligned default otherwise.  ``spec`` (when given) bumps
    ``bk`` up to one whole extraction chunk."""
    block = DECODE_BLOCK if m <= DECODE_BLOCK[0] else DEFAULT_BLOCK
    if spec is not None and block[2] % spec.chunk:
        block = (block[0], block[1], spec.chunk * -(-block[2] // spec.chunk))
    return block


def _kernel(x_ref, w_ref, out_ref, *, spec: PackedDotSpec):
    """One (bm, bk)×(bk, bn) step; accumulates into the revisited out block."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)  # (bm, bk) unsigned payload
    w = w_ref[...].astype(jnp.int32)  # (bk, bn) signed payload
    # The whole pack → chunk-batched wide multiply → extract → column
    # recombination pipeline is ref.packed_tile_matmul, shared VERBATIM
    # with the jnp reference — kernel == ref by construction.
    out_ref[...] += ref.packed_tile_matmul(x, w, spec)


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret")
)
def packed_matmul(
    x_u: jax.Array,
    w_s: jax.Array,
    spec: PackedDotSpec = INT4_EXACT,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """(M, K) unsigned × (K, N) signed → (M, N) int32 via pair packing.

    Any shape is accepted: M/N/K are zero-padded up to the block grid and
    the result is sliced back to (M, N).  ``block[2]`` must be a multiple
    of ``spec.chunk`` so every K tile holds whole extraction groups.
    """
    m, k = x_u.shape
    k2, n = w_s.shape
    assert k == k2, (k, k2)
    bm, bn, bk = block
    if bk % spec.chunk:
        raise ValueError(
            f"block bk={bk} must be a multiple of spec.chunk={spec.chunk} "
            f"({spec.name()})"
        )
    x_u = _pad_axis(_pad_axis(x_u, bm, 0), bk, 1)
    w_s = _pad_axis(_pad_axis(w_s, bk, 0), bn, 1)
    mp, kp = x_u.shape
    np_ = w_s.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(x_u, w_s)
    return out[:m, :n]


# ---- prepacked entry ------------------------------------------------------


def _quantize_tile(x, scale_ref, zp: int):
    """Fused activation-quantize prologue: f32 tile → offset-binary ints.

    The per-row scale is the global row absmax (computed once outside — a
    (m, 1) reduction), so quantizing tile-by-tile inside the kernel is
    exactly the staged quantization; the int activation tensor never round
    -trips through HBM."""
    q = jnp.round(x / scale_ref[...]) + zp
    return jnp.clip(q, 0, 2 * zp - 1).astype(jnp.int32)


def _prepacked_kernel(x_ref, w_ref, *rest, spec: PackedDotSpec,
                      x_zp: int | None):
    """One (bm, bk) × (bk//chunk, n_pairs, bn) step off prepacked words."""
    if spec.uses_mr:
        if x_zp is not None:
            wsc_ref, scale_ref, out_ref = rest
        else:
            wsc_ref, out_ref = rest
            scale_ref = None
        wsc = wsc_ref[...].astype(jnp.int32)
    else:
        if x_zp is not None:
            scale_ref, out_ref = rest
        else:
            (out_ref,) = rest
            scale_ref = None
        wsc = None
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    x = (
        _quantize_tile(x, scale_ref, x_zp)
        if scale_ref is not None
        else x.astype(jnp.int32)
    )
    words = w_ref[...].astype(jnp.int32)  # (bk//chunk, n_pairs, bn)
    # compute stage shared VERBATIM with the jnp reference
    out_ref[...] += ref.packed_tile_matmul_prepacked(x, words, wsc, spec)


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret", "x_zp")
)
def packed_matmul_prepacked(
    x: jax.Array,
    words: jax.Array,
    wsc: jax.Array | None = None,
    spec: PackedDotSpec = INT4_EXACT,
    block: tuple[int, int, int] | None = None,
    interpret: bool = True,
    x_scale: jax.Array | None = None,
    x_zp: int | None = None,
) -> jax.Array:
    """(M, K) activations × prepacked weight words → (M, N) int32.

    The serving-side kernel entry: weights arrive as
    :func:`ref.pack_weight_words` output (packed ONCE at engine build), so
    no K-step ever rebuilds ``w_words`` or the ``wsc`` contamination stream.
    Bit-identical to ``packed_matmul(x, w, spec)`` by construction — the
    compute stage is the same code.

    ``x_scale``/``x_zp`` fuse the activation quantize into the kernel
    prologue: ``x`` is then the raw f32 activation and ``x_scale`` its
    per-row quantization scale ((M, 1), the row absmax over the FULL K), so
    decode does no f32→int staging round-trip through HBM.  Without them
    ``x`` must already hold offset-binary unsigned ints.
    """
    m, k = x.shape
    n_chunks, n_pairs, n = words.shape
    kw = n_chunks * spec.chunk
    if k > kw:
        raise ValueError(f"activation K={k} exceeds packed weights' K={kw}")
    if (x_scale is None) != (x_zp is None):
        raise ValueError("fused quantize needs both x_scale and x_zp")
    if block is None:
        block = default_block_for(m, spec)
    bm, bn, bk = block
    if bk % spec.chunk:
        raise ValueError(
            f"block bk={bk} must be a multiple of spec.chunk={spec.chunk} "
            f"({spec.name()})"
        )
    # One K grid covers both operands: a multiple of bk no smaller than
    # either the activation's K or the words' K (an x shorter than the
    # packed weights, e.g. a truncated activation, pads up to the words; a
    # bk-rounded x pads the words with zero chunks — both bit-transparent).
    kp = -(-max(x.shape[1], kw) // bk) * bk
    if x.shape[1] < kp:
        x = jnp.pad(x, ((0, 0), (0, kp - x.shape[1])))
    if kp > kw:
        pad_chunks = (kp - kw) // spec.chunk
        words = jnp.pad(words, ((0, pad_chunks), (0, 0), (0, 0)))
        if wsc is not None:
            wsc = jnp.pad(wsc, ((0, pad_chunks), (0, 0), (0, 0), (0, 0)))
        n_chunks += pad_chunks
    x = _pad_axis(x, bm, 0)
    words = _pad_axis(words, bn, 2)
    if wsc is not None:
        wsc = _pad_axis(wsc, bn, 3)
    mp, kp = x.shape
    np_ = words.shape[2]
    bkc = bk // spec.chunk  # word-chunks per K step

    grid = (mp // bm, np_ // bn, kp // bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bkc, n_pairs, bn), lambda i, j, kk: (kk, 0, j)),
    ]
    operands = [x, words]
    if spec.uses_mr:
        if wsc is None:
            raise ValueError(
                f"{spec.name()} is an mr plan: packed_matmul_prepacked needs "
                "the wsc contamination operands from pack_weight_words"
            )
        in_specs.append(
            pl.BlockSpec((bkc, n_pairs, 2, bn), lambda i, j, kk: (kk, 0, 0, j))
        )
        operands.append(wsc)
    if x_scale is not None:
        x_scale = x_scale.astype(jnp.float32)
        pad_m = (-x_scale.shape[0]) % bm
        if pad_m:  # pad with ones: padded rows must not divide by zero
            x_scale = jnp.pad(
                x_scale, ((0, pad_m), (0, 0)), constant_values=1.0
            )
        in_specs.append(pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)))
        operands.append(x_scale)
    out = pl.pallas_call(
        functools.partial(_prepacked_kernel, spec=spec, x_zp=x_zp),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]
