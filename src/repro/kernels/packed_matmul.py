"""Pair-packed "DSP-sim" matmul — the paper's technique as a Pallas kernel.

TPU adaptation of DSP-Packing (DESIGN.md §2): the DSP48E2's wide multiplier
becomes the VPU's 32-bit integer multiply lanes; the 48-bit accumulator
becomes int32 accumulation with the paper's δ-padding governing how many
packed products are accumulated (``spec.n_pairs``) between field
extractions.  One int32 multiply computes TWO narrow products (the pair's
dot-product contribution lands in the middle bit field), halving multiply
count for sub-8-bit operands.

Correctness modes mirror the paper exactly:
  * ``naive`` — biased extraction (Xilinx white-paper semantics, §V)
  * ``full``  — round-half-up, bit-exact vs the integer matmul (§V-A)
  * ``mr``    — overpacked spacing + MSB restore from cheap LSBs (§VI-B)

Layout: grid (M/bm, N/bn, K/bk); x/w tiles in VMEM; the int32 output block
doubles as the accumulator across K steps (revisited output block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PackedDotSpec, INT4_EXACT

__all__ = ["packed_matmul", "DEFAULT_BLOCK"]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk) — MXU/VPU aligned


def _sext(v, width: int):
    mask = jnp.int32((1 << width) - 1)
    sign = jnp.int32(1 << (width - 1))
    return ((v & mask) ^ sign) - sign


def _kernel(x_ref, w_ref, out_ref, *, spec: PackedDotSpec, bk: int):
    """One (bm, bk)×(bk, bn) step; accumulates into the revisited out block."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)  # (bm, bk) unsigned payload
    w = w_ref[...].astype(jnp.int32)  # (bk, bn) signed payload
    bm = x.shape[0]
    bn = w.shape[1]

    # Pair along K: one packed word per two K elements.
    xa = x.reshape(bm, bk // 2, 2)
    ws = w.reshape(bk // 2, 2, bn)
    a_words = xa[:, :, 0] + (xa[:, :, 1] << spec.p)  # (bm, bk//2)
    w_words = ws[:, 1, :] + (ws[:, 0, :] << spec.p)  # (bk//2, bn)

    acc = jnp.zeros((bm, bn), dtype=jnp.int32)
    we = spec.extract_width
    for c in range(bk // spec.chunk):  # unrolled: bk/chunk is small+static
        sl = slice(c * spec.n_pairs, (c + 1) * spec.n_pairs)
        # ONE wide multiply-accumulate per pair (the DSP op).
        partial = jax.lax.dot_general(
            a_words[:, sl],
            w_words[sl, :],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        if spec.correction == "naive":
            acc = acc + _sext(partial >> spec.p, we)
        elif spec.correction == "full":
            t = ((partial >> (spec.p - 1)) + 1) >> 1
            acc = acc + _sext(t, we)
        else:  # mr
            mask = jnp.int32((1 << spec.mr_bits) - 1)
            contam = (
                jax.lax.dot_general(
                    xa[:, sl, 1] & mask,
                    ws[sl, 0, :] & mask,
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                & mask
            )
            t = ((partial >> (spec.p - 1)) + 1) >> 1
            e = _sext(t, we)
            acc = acc + _sext(e - (contam << (we - spec.mr_bits)), we)

    out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("spec", "block", "interpret")
)
def packed_matmul(
    x_u: jax.Array,
    w_s: jax.Array,
    spec: PackedDotSpec = INT4_EXACT,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """(M, K) unsigned × (K, N) signed → (M, N) int32 via pair packing.

    Shapes must be multiples of ``block`` (use ``repro.kernels.ops`` for
    padding and scale handling).
    """
    m, k = x_u.shape
    k2, n = w_s.shape
    assert k == k2, (k, k2)
    bm, bn, bk = block
    if m % bm or n % bn or k % bk or bk % spec.chunk:
        raise ValueError(f"shape {(m, k, n)} not aligned to block {block}")

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, spec=spec, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(x_u, w_s)
