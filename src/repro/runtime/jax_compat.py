"""Version-portability shims: one import site for APIs that moved between
JAX 0.4.x and JAX >= 0.6.

The repo targets both the pinned 0.4.x CI environment and current JAX:

* ``shard_map`` — ``jax.shard_map`` (new) vs ``jax.experimental.shard_map``
  (0.4.x).  The new API renamed ``check_rep`` to ``check_vma``; callers here
  always speak ``check_vma`` and the shim translates.
* ``use_mesh`` — context manager that makes ``mesh`` the ambient mesh.
  ``jax.set_mesh`` where it exists, ``jax.sharding.use_mesh`` on the
  versions that had only that, and a no-op context on 0.4.x (where passing
  the mesh explicitly — as all call sites in this repo do — is sufficient).
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["shard_map", "use_mesh"]


def shard_map(f=None, *, mesh=None, in_specs=None, out_specs=None,
              check_vma: bool | None = None):
    """Drop-in for ``jax.shard_map`` that also runs on JAX 0.4.x.

    Usable directly or as a decorator factory (``shard_map(mesh=..., ...)``),
    mirroring how ``functools.partial(jax.shard_map, ...)`` is used.
    """
    if f is None:
        return lambda fn: shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def use_mesh(mesh):
    """``with use_mesh(mesh):`` — ambient-mesh context on every JAX version."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext(mesh)
