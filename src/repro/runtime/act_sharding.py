"""Activation sharding constraints (opt-in, mesh-aware, model-agnostic).

Without explicit constraints XLA's sharding propagation may keep FSDP dim
shards on weights and reshard *activations* instead (f32 all-to-alls on the
residual stream — observed in the baseline dry-run, see EXPERIMENTS.md
§Perf iteration 1).  ``activation_sharding(mesh)`` installs a thread-local
policy; ``constrain(x, kind)`` is a no-op unless a policy is active, so
model code stays pure and mesh-free.

Kinds: ``residual`` (B,S,D) → P(dp, None, None); ``heads`` (B,S,H,hd) and
``hidden`` (B,S,F) → model-sharded feature dim; ``expert`` (E,C,D) →
P(model, None, None).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_sharding", "constrain"]

_STATE = threading.local()


def _policy():
    return getattr(_STATE, "policy", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, group_shardings=None):
    """``group_shardings``: optional NamedSharding pytree for ONE sliced
    scan group; when set, the scan body re-pins its sliced params so XLA's
    while-loop layout pass cannot reshard the parameter stack per step."""
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    fs = fsdp if len(fsdp) > 1 else fsdp[0]
    n_fsdp = 1
    for a in fsdp:
        n_fsdp *= mesh.shape[a]
    n_model = mesh.shape["model"]

    def spec_for(kind: str, shape: tuple[int, ...]) -> P | None:
        batch = fs if shape[0] % n_fsdp == 0 else None
        if kind == "residual":
            return P(*((batch,) + (None,) * (len(shape) - 1)))
        if kind == "hidden":
            feat = "model" if shape[-1] % n_model == 0 else None
            return P(*((batch,) + (None,) * (len(shape) - 2) + (feat,)))
        if kind == "heads":  # (B, S, H, hd)
            # heads on model when divisible; otherwise batch-only — an
            # hd-sharded fallback would force S²-sized score psums
            # (measured 1.2e13 B/step on starcoder2 — §Perf cell B)
            if shape[1] > 1 and shape[2] % n_model == 0:
                return P(batch, None, "model", None)
            return P(batch, None, None, None)
        if kind == "expert":  # (E, C, D)
            e = "model" if shape[0] % n_model == 0 else None
            return P(e, None, None)
        if kind == "scores_decode":  # (B, H, q, S): shard the key axis so
            # softmax runs distributed (psum of lse) instead of XLA
            # gathering the whole KV cache per decoded token
            s = "model" if shape[-1] % n_model == 0 else None
            return P(batch, None, None, s)
        return None

    _STATE.policy = (mesh, spec_for, group_shardings)
    try:
        yield
    finally:
        _STATE.policy = None


def constrain(x: jax.Array, kind: str) -> jax.Array:
    pol = _policy()
    if pol is None:
        return x
    mesh, spec_for, _ = pol
    spec = spec_for(kind, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_group_params(gp):
    """Pin a sliced scan-group param tree to its per-group shardings."""
    pol = _policy()
    if pol is None or pol[2] is None:
        return gp
    return jax.tree.map(
        lambda t, s: jax.lax.with_sharding_constraint(t, s), gp, pol[2]
    )
