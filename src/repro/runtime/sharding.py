"""Sharding policy: logical-axis rules → PartitionSpecs per (arch × shape).

Mesh axes (launch/mesh.py):
  single-pod   (16, 16)        ("data", "model")
  multi-pod    (2, 16, 16)     ("pod", "data", "model")

Logical policy (DESIGN.md §4):
  * FSDP: parameters, gradients and optimizer state shard their largest
    non-"model" dimension over the composite ``fsdp = ("pod","data")`` axis.
  * TP (Megatron): attention heads / FFN inner dim / experts / vocab shard
    over "model"; row-parallel partners shard the opposite dim.
  * batch shards over fsdp for train/prefill/decode; ``long_500k``
    (batch=1) shards the KV/state *sequence or head* dims instead (SP).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "COL_TOKENS",
    "ROW_TOKENS",
    "fsdp_axes",
    "linear_partition",
    "param_pspec",
    "param_shardings",
    "batch_pspec",
    "cache_pspec",
    "logits_pspec",
]

# Megatron linear-partition conventions, shared by the training-time
# PartitionSpec policy (param_pspec) and the serving-time tensor-parallel
# wrapper (runtime.tp_packed).  Column-parallel linears shard their OUTPUT
# dim over "model" (no cross-device reduction: each shard owns whole
# output channels); row-parallel linears shard their INPUT (contraction)
# dim and need one reduction per call.  Fused projection names (wqkv,
# upgate — core.packed_params.fuse_projection_weights) are column-parallel
# like their unfused parts: fusion concatenates along the output dim.
COL_TOKENS = frozenset({
    "wq", "wk", "wv", "wqkv", "up", "gate", "upgate", "in_proj", "wz",
    "wi", "wf", "wo_gate", "lm_head", "x_proj", "dt_proj", "patch_proj",
})
ROW_TOKENS = frozenset({"wo", "down", "out_proj"})


def linear_partition(path: str) -> str | None:
    """Megatron partition kind for a linear weight's tree path.

    Returns ``"col"`` (output dim on "model"), ``"row"`` (contraction dim
    on "model", reduction after the shard-local matmul) or ``None``
    (replicate — norms, embeddings, router weights and anything the
    conventions don't name).  Tokens are matched exactly against the
    "/"-split path, never by substring ('groups' must not match 'up' —
    §Perf iteration 7).
    """
    tokens = set(path.lower().split("/"))
    if tokens & COL_TOKENS:
        return "col"
    if tokens & ROW_TOKENS:
        return "row"
    return None


def fsdp_axes(mesh: Mesh):
    """The composite data/FSDP axis: ("pod","data") when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _divisible(size: int, mesh: Mesh, axes) -> bool:
    n = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        n *= mesh.shape[a]
    return size % n == 0


def param_pspec(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Map one parameter (by its tree path) to a PartitionSpec.

    Conventions: stacked scan/group/expert axes lead; 2-D weights are
    (d_in, d_out).  TP axis choice follows Megatron: column-parallel for
    up/QKV (out dim on "model"), row-parallel for down/out projections
    (in dim on "model").  The remaining large dim takes FSDP.
    """
    fsdp = fsdp_axes(mesh)
    fs = fsdp if len(fsdp) > 1 else fsdp[0]
    name = path.lower()

    def ok(dim_size, axis) -> bool:
        return _divisible(dim_size, mesh, axis)

    # biases / norm scales / small vectors: replicate (possibly stacked)
    if len(shape) <= 1 or name.endswith("/b") or "scale" in name or "norm" in name:
        return P(*(None,) * len(shape))

    ndim = len(shape)
    lead = ndim - 2  # stacked axes (groups, experts, slots...)
    d_in, d_out = shape[-2], shape[-1]

    # exact path-token matching via the shared Megatron convention tables
    # (substring matching once made 'groups' match 'up' and col-sharded
    # every stacked weight — §Perf iteration 7)
    kind = linear_partition(name)
    col = kind == "col"
    row = kind == "row"
    if "embed" in name:
        # (vocab, d): vocab on model (TP vocab-parallel), d on fsdp
        spec = [None] * ndim
        if ok(d_in, "model"):
            spec[-2] = "model"
        if ok(d_out, fs):
            spec[-1] = fs
        return P(*spec)
    spec: list[Any] = [None] * ndim
    # expert parallelism: the innermost lead axis of a MoE expert stack is
    # the expert axis; shard it over "model".  Matched on the '/moe/' path
    # segment — substring matching on 'up' once matched 'groUPs' and
    # stack-sharded every dense weight (§Perf iteration 7).
    if lead >= 1 and "/moe/" in name and "router" not in name:
        li = lead - 1
        if ok(shape[li], "model") and shape[li] >= 4:
            spec[li] = "model"
            # EP consumed the model axis: FSDP the biggest matrix dim
            big = -1 if d_out >= d_in else -2
            if ok(shape[big], fs):
                spec[big] = fs
            return P(*spec)
    if col and ok(d_out, "model"):
        spec[-1] = "model"
        if ok(d_in, fs):
            spec[-2] = fs
    elif row and ok(d_in, "model"):
        spec[-2] = "model"
        if ok(d_out, fs):
            spec[-1] = fs
    else:  # fallback: FSDP the larger dim
        big = -1 if d_out >= d_in else -2
        if ok(shape[big], fs):
            spec[big] = fs
    return P(*spec)


def param_shardings(params_shape: Any, mesh: Mesh, serving: bool = False) -> Any:
    """ShapeDtypeStruct tree → NamedSharding tree (same structure).

    ``serving=True`` strips the FSDP axes (params replicate over data/pod,
    shard over model only): decode touches every weight every token, so
    FSDP-sharded serving params would force a full parameter all-gather
    per generated token (measured: 2e11 B/step on qwen decode —
    EXPERIMENTS.md §Perf cell A).
    """
    fsdp = set(fsdp_axes(mesh))

    def strip(spec: P) -> P:
        def keep(e):
            if e is None:
                return None
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a not in fsdp)
                return kept if kept else None
            return None if e in fsdp else e

        return P(*(keep(e) for e in spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = param_pspec(key, leaf.shape, mesh)
        if serving:
            spec = strip(spec)
        out.append(NamedSharding(mesh, spec))
    return jax.tree.unflatten(treedef, out)


def batch_pspec(mesh: Mesh, batch: int) -> P:
    fsdp = fsdp_axes(mesh)
    fs = fsdp if len(fsdp) > 1 else fsdp[0]
    return P(fs, None) if _divisible(batch, mesh, fsdp) else P(None, None)


def logits_pspec(mesh: Mesh, batch: int) -> P:
    fsdp = fsdp_axes(mesh)
    fs = fsdp if len(fsdp) > 1 else fsdp[0]
    b = fs if _divisible(batch, mesh, fsdp) else None
    return P(b, None, "model")


def cache_pspec(
    mesh: Mesh, cache_shape: tuple[int, ...], batch: int, path: str = "attn"
) -> P:
    """Decode caches: batch over fsdp when divisible, else shard the
    sequence axis (long_500k SP); heads/features over model when divisible.

    Layouts (leading axis is always the scan-group stack):
      attn KV    (G, B, S, kv, hd)           — path contains 'attn'
      ssm state  (G, [stack], B, feat...)    — mamba/mlstm/slstm caches
    """
    fsdp = fsdp_axes(mesh)
    fs = fsdp if len(fsdp) > 1 else fsdp[0]
    ndim = len(cache_shape)
    spec: list[Any] = [None] * ndim
    batch_ok = _divisible(batch, mesh, fsdp)

    if "attn" in path and ndim == 5:
        if batch_ok:
            spec[1] = fs
        elif _divisible(cache_shape[2], mesh, fsdp):
            spec[2] = fs  # sequence-parallel cache (long_500k, batch=1)
        if spec[2] is None and _divisible(cache_shape[2], mesh, "model"):
            # decode KV parallelism: shard the SEQUENCE axis over model —
            # scores/context contractions stay shard-local and only a tiny
            # (B,H,1) logsumexp + (B,H,1,hd) context psum cross chips.
            # (hd-sharded caches force a full K/V all-gather per decoded
            # token: 172 GB/step measured on qwen decode — §Perf cell A.)
            spec[2] = "model"
        else:
            for feat in (4, 3):  # prefer head_dim, fall back to kv heads
                if _divisible(cache_shape[feat], mesh, "model") and cache_shape[feat] > 1:
                    spec[feat] = "model"
                    break
        return P(*spec)

    # state caches: locate the batch axis by size (dim 1 or 2; a within-
    # group stack axis may precede it)
    batch_axis = next(
        (i for i in (1, 2) if i < ndim and cache_shape[i] == batch), None
    )
    if batch_axis is not None and batch_ok:
        spec[batch_axis] = fs
    start = (batch_axis or 0) + 1
    feats = [i for i in range(start, ndim) if spec[i] is None]
    if feats:
        biggest = max(feats, key=lambda i: cache_shape[i])
        if _divisible(cache_shape[biggest], mesh, "model") and cache_shape[biggest] > 1:
            spec[biggest] = "model"
    return P(*spec)
