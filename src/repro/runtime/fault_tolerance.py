"""Fault tolerance & straggler machinery (launcher-side).

On a real multi-pod deployment every host runs a ``Heartbeat`` writer and
the job leader runs a ``HeartbeatMonitor``; a missed deadline marks the host
dead, the launcher tears the slice down and restarts from
``Checkpointer.latest_step`` (restart-from-latest policy — the only sound
recovery under SPMD collectives, where one lost participant wedges every
collective).  ``StragglerDetector`` tracks per-step wall times and flags
hosts whose rolling median exceeds the fleet median by ``threshold``×,
feeding the launcher's replace-or-demote decision.

Everything is plain files + wall clock so it is fully exercisable in tests
on one CPU host (simulated hosts = directories).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque

__all__ = ["Heartbeat", "HeartbeatMonitor", "StragglerDetector", "RestartPolicy"]


class Heartbeat:
    """Per-host liveness beacon: atomically updated mtime + step file."""

    def __init__(self, directory: str, host_id: int):
        self.path = os.path.join(directory, f"host_{host_id:05d}.hb")
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)


class HeartbeatMonitor:
    def __init__(self, directory: str, deadline_s: float = 60.0):
        self.directory = directory
        self.deadline_s = deadline_s

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = time.time() if now is None else now
        dead = []
        for name in sorted(os.listdir(self.directory)):
            if not name.endswith(".hb"):
                continue
            with open(os.path.join(self.directory, name)) as f:
                t = json.load(f)["time"]
            if now - t > self.deadline_s:
                dead.append(int(name.split("_")[1].split(".")[0]))
        return dead

    def healthy(self, now: float | None = None) -> bool:
        return not self.dead_hosts(now)


class StragglerDetector:
    """Rolling per-host step-time medians; flags hosts slower than
    ``threshold`` × fleet median (straggler mitigation trigger).

    Buffers are ``deque(maxlen=window)`` so ``record`` is O(1) and memory
    is O(window) per host regardless of how long the job runs (the old
    list-slice trim degenerated to unbounded growth at ``window=0`` and
    shifted the whole buffer every call); ``rolling_median`` is
    O(window log window) over the retained window only, never the full
    history.  Besides the fleet-relative ``stragglers`` view, the
    single-stream ``rolling_median`` is the serving governor's slow-step
    signal: the continuous engine records each decode step's wall time
    under one host id and the governor compares the rolling median
    against its configured ceiling.
    """

    def __init__(self, window: int = 16, threshold: float = 1.5):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = window
        self.threshold = threshold
        self._times: dict[int, deque[float]] = {}

    def record(self, host_id: int, step_time_s: float) -> None:
        buf = self._times.get(host_id)
        if buf is None:
            buf = self._times[host_id] = deque(maxlen=self.window)
        buf.append(step_time_s)

    @staticmethod
    def _median(xs) -> float:
        ys = sorted(xs)
        n = len(ys)
        return ys[n // 2] if n % 2 else 0.5 * (ys[n // 2 - 1] + ys[n // 2])

    def rolling_median(self, host_id: int = 0) -> float:
        """Median step time over ``host_id``'s retained window (0.0 when
        the host has recorded nothing — callers treat that as "no
        signal", matching the empty-phase-rate convention)."""
        buf = self._times.get(host_id)
        return self._median(buf) if buf else 0.0

    def n_recorded(self, host_id: int = 0) -> int:
        """Samples currently retained for ``host_id`` (<= window)."""
        buf = self._times.get(host_id)
        return len(buf) if buf else 0

    def stragglers(self) -> list[int]:
        if len(self._times) < 2:
            return []
        meds = {h: self._median(ts) for h, ts in self._times.items() if ts}
        fleet = self._median(list(meds.values()))
        return [h for h, m in meds.items() if m > self.threshold * fleet]


@dataclasses.dataclass
class RestartPolicy:
    """Launcher decision table after a fault."""

    max_restarts: int = 100
    restarts: int = 0

    def on_fault(self, dead_hosts: list[int], latest_step: int | None) -> dict:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return {"action": "abort", "reason": "restart budget exhausted"}
        return {
            "action": "restart",
            "from_step": latest_step or 0,
            "replace_hosts": dead_hosts,
        }
