"""Gradient compression for cross-pod all-reduce (DESIGN.md §4).

DSP-packing's insight applied to the *network*: quantize gradients to int8
before the (slow, inter-pod) reduction, carry the quantization residual in
an error-feedback buffer so compression error does not bias convergence
(1-bit-Adam-style).  ``compressed_grads`` is a drop-in transform around the
grad tree inside ``train_step``; XLA reduces the dequantized values, and the
byte win is accounted analytically in the roofline (collective bytes ÷4 for
f32, ÷2 for bf16 — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_error_feedback", "compressed_grads"]


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_dequantize(g: jax.Array) -> jax.Array:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -128, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compressed_grads(grads, error_buf):
    """int8-compress each gradient leaf with error feedback.

    Returns (compressed_grads, new_error_buf).  The compressed values are
    exactly representable in int8×scale, so an int8 wire format loses no
    further information.
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        dq = _quantize_dequantize(g32)
        return dq.astype(g.dtype), g32 - dq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
