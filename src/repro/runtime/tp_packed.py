"""Tensor-parallel packed serving: ``shard_map`` over prepacked weights.

This module makes the serving engines' quantized weight trees
mesh-parallel while keeping every emitted token bit-identical to the
single-device engine.  The partitioning follows the Megatron conventions
shared with the training policy (``runtime.sharding.linear_partition``,
DESIGN.md §4): column-parallel linears shard their output channels over
the mesh "model" axis and need no reduction; row-parallel linears shard
the contraction axis and reduce once per call.

The load-bearing invariant is WHERE the row-parallel reduction happens
(DESIGN.md §4, "the packed-word reduction invariant"): for packed plans
it runs in **int32 packed-word space** — each shard accumulates its own
pair products into packed partial words, a ``psum`` adds the words
across devices (int32 wrapping addition is associative and commutative,
so the sum is order-independent bit-for-bit), and field extraction +
correction run ONCE on the reduced word.  That is exactly the arithmetic
of a single device running the *widened* plan
(``kernels.ref.widen_for_shards``: the plan with ``n_shards * n_pairs``
products per extraction group), so the sharding is legal if and only if
the widened spec is constructible — the ``PackedDotSpec`` constructor's
int32-accumulator / middle-field / aliasing clauses
(``analysis.clauses``) reject an overflowing sharding at build with the
violated clause named, the same way they reject an illegal ``n_pairs``.
``shard_params_tp`` additionally re-proves the widened spec through
``analysis.verify.certify_spec`` so every row-sharded leaf carries a
machine-checked certificate of the cross-device accumulation budget.

Bit-identity per path:

* **row, proven-exact plans** (the CPU serving default): the activation
  row is quantized OUTSIDE ``shard_map`` (the per-row scale must see
  every channel), the f32 GEMM runs per K-shard and a f32 ``psum``
  reduces.  Every partial sum is an exact small integer below the f32
  mantissa bound (guarded at prepack), so the reduction is exact in any
  order — bit-identical to the unsharded GEMM.
* **row, word path** (mr/overpacked plans, no f32 shortcut): the psum
  runs on int32 words pre-extraction as above; mr contamination terms
  psum the same way (residues mod ``2**mr_bits`` compose:
  ``(a mod r + b mod r) mod r == (a+b) mod r``).  The result is
  bit-identical to a single device running the widened spec — the
  shard-aware planner (``tuning.rank_plans(shard_groups=...)``) scores
  plans on exactly that widened arithmetic.
* **col**: each shard runs the full single-device arithmetic on its own
  output channels (integer work is channel-independent; the activation
  quantize is a replicated computation of replicated inputs) and an
  ``all_gather(tiled=True)`` reassembles channels in device order.

Outputs leave every ``shard_map`` fully replicated — downstream norms
and residuals see the same f32 values the single-device engine sees, so
XLA cannot reassociate a reduction differently per mesh shape.

Float ("native") weight trees pass through unwrapped: f32 matmul
reductions are not associative, so float leaves replicate — packed
integer representations are precisely what makes tensor-parallel decode
bit-exact (the thesis of DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..kernels import ref
from .jax_compat import shard_map
from .sharding import linear_partition

__all__ = ["TpLinear", "shard_params_tp", "apply_tp_linear"]


@jax.tree_util.register_pytree_node_class
class TpLinear:
    """A mesh-partitioned serving linear.

    Wraps one quantized weight leaf (a ``DspTunedLeaf`` or an int4
    ``{"packed","scale","w_f32"}`` dict) whose arrays were ``device_put``
    onto the mesh by :func:`shard_params_tp`.  The wrapper is a pytree
    node — the inner leaf's arrays are children (so ``lax.scan`` over
    stacked scan groups slices through it and checkpoint/eval_shape
    walks see the real arrays) while the partition kind, shard count and
    mesh ride the treedef as static aux, making every jitted engine step
    specialize per sharding exactly like it specializes per plan.

    ``core.packed_linear.apply_linear`` dispatches wrapped leaves to
    :func:`apply_tp_linear` instead of the single-device arithmetic.
    """

    def __init__(self, inner, *, kind: str, mesh, n_shards: int,
                 axis: str = "model"):
        if kind not in ("col", "row"):
            raise ValueError(f"kind {kind!r} not in ('col', 'row')")
        self.inner = inner
        self.kind = kind
        self.mesh = mesh
        self.n_shards = n_shards
        self.axis = axis

    def tree_flatten(self):
        return (self.inner,), (self.kind, self.mesh, self.n_shards, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = cls.__new__(cls)
        (obj.inner,) = children
        obj.kind, obj.mesh, obj.n_shards, obj.axis = aux
        return obj


def _last_axis_pspec(arr, axis: str) -> P:
    return P(*([None] * (arr.ndim - 1) + [axis]))


def _put(mesh, arr, spec: P):
    return None if arr is None else jax.device_put(arr, NamedSharding(mesh, spec))


# ---- wrapping (engine build) ----------------------------------------------


def _widened_grouping(arr, S: int, chunk_axis: int, pairs_axis: int):
    """Regroup packed per-chunk operands onto the WIDENED chunk grid.

    ``pack_weight_words`` lays pair words out as (..., n_chunks, n_pairs,
    ...); the widened plan's extraction group is ``S`` consecutive local
    chunks, so the widened layout is a pure reshape — (…, C, S·n_pairs, …)
    with ``C = n_chunks / S`` — after zero-padding the chunk axis to a
    multiple of ``S`` (zero pairs are bit-transparent in every correction
    scheme, see ``ref._pad_k``).  Shard slice ``d`` of the merged pairs
    axis is then exactly local chunk ``c·S + d`` of every widened chunk
    ``c`` — each device owns whole local chunks.
    """
    n_chunks = arr.shape[chunk_axis]
    pad = (-n_chunks) % S
    if pad:
        widths = [(0, 0)] * arr.ndim
        widths[chunk_axis] = (0, pad)
        arr = jnp.pad(arr, widths)
    c = (n_chunks + pad) // S
    shape = list(arr.shape)
    shape[chunk_axis] = c
    shape[pairs_axis] = S * shape[pairs_axis]
    return arr.reshape(shape)


def _wrap_tuned(leaf, path: str, mesh, S: int, axis: str):
    from ..analysis.verify import certify_spec
    from ..core.packed_params import DspTunedLeaf

    kind = linear_partition(path)
    if kind is None or leaf.words is None:
        # unnamed role, or a storage-only (prepack=False) leaf whose
        # apply path repacks per step: replicate
        return leaf

    if kind == "col":
        n = leaf.scale.shape[-1]
        if n % S:
            return leaf  # replicate fallback, mirroring param_pspec
        last = lambda a: _last_axis_pspec(a, axis)  # noqa: E731
        new = DspTunedLeaf(
            payload=_put(mesh, leaf.payload, last(leaf.payload)),
            scale=_put(mesh, leaf.scale, last(leaf.scale)),
            spec=leaf.spec, block=leaf.block,
            decode_block=leaf.decode_block, exact=leaf.exact,
            words=_put(mesh, leaf.words, last(leaf.words)),
            wsc=(None if leaf.wsc is None
                 else _put(mesh, leaf.wsc, last(leaf.wsc))),
            zp_row=_put(mesh, leaf.zp_row, last(leaf.zp_row)),
            w_f32=(None if leaf.w_f32 is None
                   else _put(mesh, leaf.w_f32, last(leaf.w_f32))),
            prepack=False,
        )
        return TpLinear(new, kind="col", mesh=mesh, n_shards=S, axis=axis)

    # row: the contraction axis is sharded, so the cross-device reduction
    # accumulates S shards' worth of pair products in one packed word
    # BEFORE extraction — legal iff the widened spec is constructible.
    # widen_for_shards raises the constructor's clause-citing ValueError
    # for an overflowing sharding; certify_spec re-proves the legal case.
    try:
        wide = ref.widen_for_shards(leaf.spec, S)
    except ValueError as e:
        raise ValueError(
            f"illegal row sharding for {path!r}: {e}"
        ) from e
    certify_spec(wide)

    words = _widened_grouping(
        leaf.words, S, leaf.words.ndim - 3, leaf.words.ndim - 2
    )
    wsc = None
    if leaf.wsc is not None:
        wsc = _widened_grouping(leaf.wsc, S, leaf.wsc.ndim - 4,
                                leaf.wsc.ndim - 3)
    # shard the merged pairs axis: P(..., "model", None) for words
    w_spec = P(*([None] * (words.ndim - 2) + [axis, None]))
    wsc_spec = None if wsc is None else P(
        *([None] * (wsc.ndim - 3) + [axis, None, None])
    )
    w_f32 = leaf.w_f32
    f32_spec = None
    if w_f32 is not None:
        if w_f32.shape[-2] % S:
            w_f32 = None  # ragged K: serve the word path instead
        else:
            f32_spec = P(*([None] * (w_f32.ndim - 2) + [axis, None]))
    new = DspTunedLeaf(
        payload=leaf.payload, scale=leaf.scale, spec=leaf.spec,
        block=leaf.block, decode_block=leaf.decode_block, exact=leaf.exact,
        words=_put(mesh, words, w_spec),
        wsc=None if wsc is None else _put(mesh, wsc, wsc_spec),
        zp_row=leaf.zp_row,
        w_f32=None if w_f32 is None else _put(mesh, w_f32, f32_spec),
        prepack=False,
    )
    return TpLinear(new, kind="row", mesh=mesh, n_shards=S, axis=axis)


def _wrap_int4(leaf: dict, path: str, mesh, S: int, axis: str):
    kind = linear_partition(path)
    w_f32 = leaf.get("w_f32")
    if kind is None or w_f32 is None:
        # the nibble-unpacking fallback quantizes per call — replicate
        return leaf
    if kind == "col":
        if leaf["scale"].shape[-1] % S:
            return leaf
        new = {
            "packed": _put(mesh, leaf["packed"],
                           _last_axis_pspec(leaf["packed"], axis)),
            "scale": _put(mesh, leaf["scale"],
                          _last_axis_pspec(leaf["scale"], axis)),
            "w_f32": _put(mesh, w_f32, _last_axis_pspec(w_f32, axis)),
        }
        return TpLinear(new, kind="col", mesh=mesh, n_shards=S, axis=axis)
    if w_f32.shape[-2] % S:
        return leaf
    new = {
        "packed": leaf["packed"],
        "scale": leaf["scale"],
        "w_f32": _put(
            mesh, w_f32, P(*([None] * (w_f32.ndim - 2) + [axis, None]))
        ),
    }
    return TpLinear(new, kind="row", mesh=mesh, n_shards=S, axis=axis)


def shard_params_tp(params, mesh, *, axis: str = "model",
                    use_kernel: bool = False):
    """Partition a quantized serving tree over ``mesh``'s ``axis``.

    Walks the post-quantization tree, classifies each packed linear by
    ``linear_partition`` of its tree path, ``device_put``s its operands
    onto the mesh and wraps it in :class:`TpLinear`.  Leaves the policy
    does not name — and float leaves, whose f32 reductions are not
    order-independent — stay replicated.  Raises the certificate-clause-
    citing ``ValueError`` for a row sharding whose widened accumulation
    would overflow (see module docstring).

    ``use_kernel=True`` is rejected: tensor-parallel serving runs the jnp
    reference / f32-shortcut paths (the Pallas kernels have no
    cross-device reduction stage).
    """
    from ..core.packed_params import is_dsp_tuned_leaf, is_packed_leaf

    S = int(mesh.shape[axis])
    if S > 1 and use_kernel:
        raise ValueError(
            "tensor-parallel packed serving (tp > 1) runs the jnp "
            "reference paths; use_kernel=True is not supported"
        )
    if S == 1:
        return params

    def walk(tree, path=""):
        if is_dsp_tuned_leaf(tree):
            return _wrap_tuned(tree, path, mesh, S, axis)
        if is_packed_leaf(tree):
            return _wrap_int4(tree, path, mesh, S, axis)
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        return tree

    return walk(params)


# ---- apply (decode / prefill) ---------------------------------------------


def _tuned_col(w: TpLinear, x):
    from ..kernels import ops

    leaf = w.inner
    m = x.shape[0]
    specs = jax.tree.map(lambda a: _last_axis_pspec(a, w.axis), leaf)

    def body(xl, lf):
        local = ops.dsp_tuned_matmul_prepacked_f32(
            xl, lf.words, lf.wsc, lf.zp_row, lf.scale, lf.w_f32,
            spec=lf.spec, block=lf.block_for(m), use_kernel=False,
            exact_f32=lf.w_f32 is not None,
        )
        return jax.lax.all_gather(local, w.axis, axis=1, tiled=True)

    return shard_map(
        body, mesh=w.mesh, in_specs=(P(None, None), specs),
        out_specs=P(None, None), check_vma=False,
    )(x, leaf)


def _tuned_row(w: TpLinear, x):
    from ..core.quantize import quantize_unsigned

    leaf = w.inner
    spec = leaf.spec
    S = w.n_shards
    m = x.shape[0]

    if leaf.w_f32 is not None:
        # exact-f32 shard path: quantize the FULL activation row outside
        # the shard_map (the per-row scale sees every channel, exactly as
        # on one device), contract per K-shard, reduce in f32 — exact,
        # because every partial sum is an exact integer (mantissa bound
        # guarded at prepack) and exact sums are order-independent
        zp = 1 << (spec.bits_a - 1)
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        x_scale = jnp.maximum(amax, 1e-8) / (zp - 1)
        q = jnp.round(x / x_scale) + zp

        def gemm(ql, wl):
            return jax.lax.psum(ql @ wl, w.axis)

        acc = shard_map(
            gemm, mesh=w.mesh,
            in_specs=(P(None, w.axis), P(w.axis, None)),
            out_specs=P(None, None), check_vma=False,
        )(q, leaf.w_f32)
        acc = acc - leaf.zp_row.astype(jnp.float32)[None, :]
        return acc * x_scale * leaf.scale

    # packed-word path (mr / overpacked plans): the reduction runs on
    # int32 words BEFORE extraction — the widened-spec arithmetic the
    # build certified (module docstring).
    xq = quantize_unsigned(x, bits=spec.bits_a, axis=-1)
    x_u = xq.values.astype(jnp.int32)
    kw = leaf.words.shape[-3] * S * spec.chunk  # the widened chunk grid
    pad = kw - x_u.shape[1]
    if pad:
        x_u = jnp.pad(x_u, ((0, 0), (0, pad)))

    def body(xl, words, wsc):
        # words: (C, n_pairs, n) — this shard's slice of every widened
        # chunk's merged pairs axis (= local chunk c*S + shard_index)
        idx = jax.lax.axis_index(w.axis)
        npair = spec.n_pairs
        c, _, n = words.shape
        acc = jnp.zeros((xl.shape[0], n), jnp.int32)
        for j in range(spec.n_columns):
            xa = ref.slice_column(xl, spec, j).reshape(xl.shape[0], kw // 2, 2)
            a_words = (xa[:, :, 0] + (xa[:, :, 1] << spec.p)).reshape(
                xl.shape[0], c, S * npair
            )
            a_local = jax.lax.dynamic_slice_in_dim(
                a_words, idx * npair, npair, axis=2
            )
            partial = jax.lax.dot_general(
                a_local, words, (((2,), (1,)), ((1,), (0,))),
                preferred_element_type=jnp.int32,
            )
            # int32 wrapping addition is associative/commutative: the
            # psum'd word is bit-identical to one device accumulating
            # all S*n_pairs products (the widened spec's word)
            partial = jax.lax.psum(partial, w.axis)
            contam = None
            if spec.uses_mr:
                xa4 = xa.reshape(xl.shape[0], c, S * npair, 2)
                xa_l = jax.lax.dynamic_slice_in_dim(
                    xa4, idx * npair, npair, axis=2
                )
                # residues mod 2**mr_bits compose across shards:
                # psum the masked local terms, re-mask once
                contam = jax.lax.psum(
                    ref.contamination_terms(xa_l, wsc, spec), w.axis
                ) & jnp.int32(ref.contamination_mask(spec))
            # extraction parameters (p / extract width / correction) are
            # identical between the local and widened spec — n_pairs only
            # sizes the accumulation the psum just performed
            field = ref.extract_accumulated_field(partial, spec, contam)
            col = jnp.sum(field, axis=0)
            shift = spec.column_shift(j)
            acc = acc + (col << shift if shift else col)
        return acc

    if spec.uses_mr:
        acc = shard_map(
            body, mesh=w.mesh,
            in_specs=(P(None, None), P(None, w.axis, None),
                      P(None, w.axis, None, None)),
            out_specs=P(None, None), check_vma=False,
        )(x_u, leaf.words, leaf.wsc)
    else:
        acc = shard_map(
            lambda xl, ww: body(xl, ww, None), mesh=w.mesh,
            in_specs=(P(None, None), P(None, w.axis, None)),
            out_specs=P(None, None), check_vma=False,
        )(x_u, leaf.words)
    acc = acc - leaf.zp_row[None, :]
    return acc.astype(jnp.float32) * xq.scale * leaf.scale


def _int4_col(w: TpLinear, x):
    from ..kernels import ops

    d = w.inner

    def body(xl, w_f32, scale):
        local = ops.int4_prepacked_matmul_f32(xl, w_f32, scale)
        return jax.lax.all_gather(local, w.axis, axis=1, tiled=True)

    return shard_map(
        body, mesh=w.mesh,
        in_specs=(P(None, None), P(None, w.axis), P(None, w.axis)),
        out_specs=P(None, None), check_vma=False,
    )(x, d["w_f32"], d["scale"])


def _int4_row(w: TpLinear, x):
    from ..kernels.ops import _quantize_signed_f32

    d = w.inner
    q, x_scale = _quantize_signed_f32(x, bits=8)

    def gemm(ql, wl):
        return jax.lax.psum(ql @ wl, w.axis)

    acc = shard_map(
        gemm, mesh=w.mesh,
        in_specs=(P(None, w.axis), P(w.axis, None)),
        out_specs=P(None, None), check_vma=False,
    )(q, d["w_f32"])
    return acc * x_scale * d["scale"]


def apply_tp_linear(w: TpLinear, x, quant_spec):
    """Serve one wrapped linear: (m, d_in) float → (m, d_out) float.

    The tensor-parallel counterpart of the ``apply_linear`` packed
    branches — same quantize recipes, same scales, with the contraction
    reduced across the mesh per the module-docstring invariant.  Returns
    a fully replicated array (bit-identity contract).
    """
    from ..core.packed_params import is_dsp_tuned_leaf

    if getattr(quant_spec, "use_kernel", False):
        raise ValueError(
            "tensor-parallel serving runs the jnp reference paths; "
            "use_kernel=True is rejected at engine build"
        )
    # Pin the activation to fully-replicated BEFORE any TP arithmetic.
    # Without this anchor GSPMD back-propagates the shard_map's
    # P(None, "model") input spec through the quantize into the upstream
    # attention/MLP math, partitioning ops (rope, cache scatter) that
    # must stay replicated for bit-identity — observed as gross (O(1))
    # divergence on the 8-way host mesh, not mere reassociation noise.
    # One constraint at the boundary = one reshard, and everything
    # upstream compiles exactly as the single-device engine does.
    x = jax.lax.with_sharding_constraint(
        x, NamedSharding(w.mesh, P(None, None))
    )
    if is_dsp_tuned_leaf(w.inner):
        return _tuned_col(w, x) if w.kind == "col" else _tuned_row(w, x)
    return _int4_col(w, x) if w.kind == "col" else _int4_row(w, x)
