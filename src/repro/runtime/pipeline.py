"""GPipe-style pipeline parallelism over the "pod" mesh axis.

At 1000+ nodes the inter-pod links are the slow tier; pipelining the layer
stack across pods sends only per-microbatch activation boundaries over
those links ((mb, S, D) per tick) instead of FSDP parameter traffic.

Implementation: ``shard_map`` over the pod axis.  The layer-group stack is
split into ``n_stages`` contiguous stages (stage s owns groups
``[s·G/S, (s+1)·G/S)``, params sharded P('pod') on the leading axis).
Microbatches stream through the classic GPipe schedule: at tick ``t`` stage
``s`` runs microbatch ``t - s``; boundary activations hop one pod per tick
via ``ppermute``.  ``jax.grad`` differentiates straight through (the
transpose of ppermute is the reverse ppermute), so the same machinery
trains — this module provides the forward; the loss wrapper composes it.

CPU-testable: the correctness test runs the 2-stage schedule on 8 fake
host devices and asserts bit-equality with the sequential forward
(tests/test_pipeline.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models import transformer as T
from ..models.config import ModelConfig
from .jax_compat import shard_map

__all__ = ["split_stages", "pipeline_forward"]


def split_stages(params, n_stages: int):
    """Reshape the group stack (G, ...) → (n_stages, G/S, ...)."""
    def resh(x):
        g = x.shape[0]
        assert g % n_stages == 0, (g, n_stages)
        return x.reshape(n_stages, g // n_stages, *x.shape[1:])

    out = dict(params)
    out["groups"] = jax.tree.map(resh, params["groups"])
    return out


def pipeline_forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (n_micro, mb, S)
    mesh: Mesh,
    axis: str = "pod",
):
    """Pipelined forward over ``axis``.  Returns logits (n_micro, mb, S, V).

    ``params`` must already be stage-split (`split_stages`) with the stage
    axis sharded over ``axis``; embedding/norm/lm_head replicate.
    """
    n_stages = mesh.shape[axis]
    n_micro = tokens.shape[0]
    ticks = n_micro + n_stages - 1

    def stage_apply(stage_groups, x):
        def body(carry, gp):
            y, _, _ = T._apply_group(gp, carry, cfg, positions, None, None)
            return y, None

        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], (x.shape[0], x.shape[1])
        )
        x, _ = jax.lax.scan(body, x, stage_groups)
        return x

    embed = params["embed"]["w"]
    lm_head = params.get("lm_head")
    final_norm = params["final_norm"]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), params["groups"]), P()),
        out_specs=P(),
        check_vma=False,
    )
    def run(stage_groups, toks):
        stage_groups = jax.tree.map(lambda t: t[0], stage_groups)  # local stage
        sid = jax.lax.axis_index(axis)
        mb, s = toks.shape[1:]
        d = cfg.d_model
        dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]

        buf = jnp.zeros((mb, s, d), dtype)  # incoming activation register
        outputs = jnp.zeros((n_micro, mb, s, d), dtype)

        def tick(t, carry):
            buf, outputs = carry
            # stage 0 injects microbatch t (others use the received buffer)
            midx = jnp.clip(t, 0, n_micro - 1)
            injected = embed[toks[midx]].astype(dtype)
            x = jnp.where(sid == 0, injected, buf)
            y = stage_apply(stage_groups, x)
            # last stage commits microbatch t-(n_stages-1) when valid
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (sid == n_stages - 1)
            outputs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, n_micro - 1), 0
                ),
                lambda o: o,
                outputs,
            )
            # boundary hop: stage s -> s+1 (ring; last->0 value is unused)
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return buf, outputs

        _, outputs = jax.lax.fori_loop(0, ticks, tick, (buf, outputs))
        # only the last stage holds real outputs; share them along the axis
        outputs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis,
        )
        return outputs

    acts = run(params["groups"], tokens)

    # final norm + logits (replicated epilogue)
    from ..models.layers import rmsnorm

    x = rmsnorm(final_norm, acts, cfg.norm_eps)
    if cfg.tie_embeddings or lm_head is None:
        logits = x.astype(jnp.float32) @ embed.T.astype(jnp.float32)
    else:
        logits = x.astype(jnp.float32) @ lm_head["w"].astype(jnp.float32)
    return logits
