"""Deterministic synthetic LM data pipeline.

Production posture on 1000+ nodes: each host materializes only its own
shard of the global batch (``host_slice``), the stream is seeded so any
host can reproduce any step's batch independently (no data server round
trips), state is a single ``(seed, step)`` pair that checkpoints with the
model, and a background prefetch thread keeps ``prefetch`` batches ready.

The token stream is a mixture of Zipf-distributed unigrams and seeded
Markov bigram structure, so cross-entropy actually *decreases* under
training (integration tests assert this).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

__all__ = ["DataConfig", "SyntheticStream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: float = 0.8  # probability of following the bigram chain

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticStream:
    """Stateless-per-step synthetic stream with background prefetch."""

    def __init__(self, cfg: DataConfig, prefetch: int = 2):
        self.cfg = cfg
        self._step = 0
        # fixed bigram successor table (the learnable structure)
        rng = np.random.default_rng(cfg.seed ^ 0x5EED)
        self._succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size)
        self._queue: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ---- deterministic batch synthesis --------------------------------
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step, cfg.host_id))
        b, s = cfg.host_batch, cfg.seq_len
        zipf = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        tokens = np.minimum(zipf, cfg.vocab_size - 1)
        follow = rng.random((b, s)) < cfg.structure
        for t in range(1, s):
            chained = self._succ[tokens[:, t - 1]]
            tokens[:, t] = np.where(follow[:, t], chained, tokens[:, t])
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    # ---- iterator protocol with prefetch ------------------------------
    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def __next__(self):
        if self._thread is None:
            batch = self.batch_at(self._step)
            self._step += 1
            return batch
        step, batch = self._queue.get()
        self._step = step + 1
        return batch

    def __iter__(self):
        return self

    def stop(self):
        self._stop.set()

    # ---- checkpointable state ------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self._step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self._step = int(state["step"])
