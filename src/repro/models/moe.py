"""Mixture-of-Experts FFN (dbrx / moonshot / jamba styles).

Sort-based capacity dispatch (megablocks-style, not the dense GShard einsum
— that one costs O(T²·d) in dispatch alone and would poison the roofline):
token→expert assignments are argsorted, each expert processes a contiguous
capacity buffer ``C = ceil(T·k/E · capacity_factor)``, tokens beyond
capacity are dropped.  Dispatch/combine are O(T·k·d) gathers/scatters; the
expert-stacked weights shard over the "model" mesh axis (expert
parallelism) and compute FLOPs scale with active parameters only
(the MoE roofline model 6·N_active·D).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.packed_linear import LinearSpec, apply_linear, init_linear
from ..core.packed_params import materialize_weight
from ..runtime.act_sharding import constrain
from .config import ModelConfig
from .layers import Params

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff

    def stack(k, d_in, d_out):
        keys = jax.random.split(k, e)
        return jnp.stack(
            [init_linear(kk, d_in, d_out, dtype=dtype)["w"] for kk in keys]
        )

    return {
        "router": init_linear(ks[0], d, e, dtype=dtype),
        "up": stack(ks[1], d, f),      # (E, d, f)
        "gate": stack(ks[2], d, f),    # (E, d, f)
        "down": stack(ks[3], f, d),    # (E, f, d)
    }


def moe_ffn(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LinearSpec | None = None,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balancing_loss).

    ``valid`` (B, S) bool marks the serving path: padding tokens route to
    the overflow bin and produce zeros, and dispatch runs **dropless**
    (capacity = every valid assignment).  Shape-dependent capacity
    ``ceil(T·k/E·cf)`` would make a token's output depend on the static
    batch shape — chunked prefill would drop different tokens than
    chunk-1 prefill and lanes would couple through the capacity race,
    breaking both the recurrent-chunking invariant and cross-engine token
    identity.  Dropless serving makes each token's MoE output a pure
    function of its own hidden state.  Training (``valid=None``) keeps
    the capacity-factor drop semantics.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = t * k if valid is not None else int(
        max(1, (t * k / e) * cfg.capacity_factor)
    )
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ params["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    if valid is not None:
        # expert id ``e`` is a virtual "no expert": stable argsort parks these
        # entries after every real assignment, so real tokens' ranks (and
        # therefore capacity drops) are independent of padding lanes
        expert_idx = jnp.where(valid.reshape(t)[:, None], expert_idx, e)

    # ---- sort-based dispatch ------------------------------------------
    flat_e = expert_idx.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)  # buffer rank -> (token,choice)
    sorted_e = flat_e[order]
    # rank within the expert group = index - first index of that expert
    first = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(t * k) - first[jnp.minimum(sorted_e, e - 1)]
    keep = (rank < cap) & (sorted_e < e)
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)  # overflow bin

    token_of = order // k  # token feeding each sorted entry
    buf_src = jnp.full((e * cap + 1,), t, dtype=jnp.int32)  # t = padding row
    buf_src = buf_src.at[slot].set(token_of.astype(jnp.int32), mode="drop")
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    buf = constrain(xt_pad[buf_src[: e * cap]].reshape(e, cap, d), "expert")

    # ---- expert compute (EP-shardable over the leading E axis) --------
    up_w, gate_w, down_w = params["up"], params["gate"], params["down"]
    if isinstance(up_w, dict):
        # per-expert serving leaves (core.packed_params.split_expert_stacks):
        # each expert's capacity buffer routes through apply_linear so every
        # expert runs ITS OWN packed plan — per-expert mixed widths
        spec = spec if spec is not None else LinearSpec()
        outs = []
        for i in range(e):
            key = f"e{i}"
            u = apply_linear({"w": up_w[key]}, buf[i], spec)
            g = apply_linear({"w": gate_w[key]}, buf[i], spec)
            outs.append(apply_linear({"w": down_w[key]}, jax.nn.silu(g) * u, spec))
        out_buf = jnp.stack(outs)  # (E, cap, d)
    else:
        up = jnp.einsum("ecd,edf->ecf", buf, materialize_weight(up_w, x.dtype).astype(x.dtype))
        gate = jnp.einsum("ecd,edf->ecf", buf, materialize_weight(gate_w, x.dtype).astype(x.dtype))
        act = jax.nn.silu(gate) * up
        out_buf = jnp.einsum("ecf,efd->ecd", act, materialize_weight(down_w, x.dtype).astype(x.dtype))

    # ---- combine -------------------------------------------------------
    # invert the sort: where did (token, choice) land?
    inv_slot = jnp.zeros((t * k,), dtype=jnp.int32).at[order].set(
        slot.astype(jnp.int32)
    )
    inv_keep = jnp.zeros((t * k,), dtype=bool).at[order].set(keep)
    flat_buf = jnp.concatenate(
        [out_buf.reshape(e * cap, d), jnp.zeros((1, d), out_buf.dtype)], axis=0
    )
    per_choice = flat_buf[jnp.where(inv_keep, inv_slot, e * cap)]  # (T*k, d)
    weighted = per_choice.reshape(t, k, d) * gate_vals[..., None].astype(x.dtype)
    out = jnp.sum(weighted, axis=1).reshape(b, s, d)

    # Switch-style load-balance aux loss
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)
    density = jnp.mean(onehot.sum(1), axis=0)  # (E,)
    router_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * router_prob) / k
    return out, aux
