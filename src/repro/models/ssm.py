"""State-space / recurrent mixers: Mamba (jamba) and xLSTM (sLSTM + mLSTM).

All are O(L) in sequence length with O(1)-per-token decode state — these are
the families that make the ``long_500k`` cells runnable (DESIGN.md §5).

Training/prefill uses chunked scans (``lax.scan`` over chunks of
``CHUNK`` tokens, parallel math within a chunk) to bound activation memory
and keep the lowered HLO small; decode advances the carried state one step.
Projections route through PackedLinear like every other matmul.

Serving chunked prefill passes ``valid`` (a per-row *prefix* mask over the
chunk): the mixer then runs a strictly sequential per-token scan that
re-applies the exact single-token chunk math and gates the carried state with
``where(valid_t, new, old)``.  Because each step is literally the chunk
computation at length 1, a chunk of C tokens is bit-for-bit identical to C
single-token calls — the invariant the serving engines' recurrent-state
chunking (and preemption resume) is built on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.packed_linear import apply_linear, init_linear
from .config import ModelConfig
from .layers import Params, rmsnorm, init_rmsnorm

CHUNK = 256

__all__ = [
    "init_mamba", "mamba", "init_mamba_cache",
    "init_mlstm", "mlstm", "init_mlstm_cache",
    "init_slstm", "slstm", "init_slstm_cache",
]


# ---- Mamba (selective SSM) -------------------------------------------------


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    di = d * cfg.mamba_expand
    ds, dc, dr = cfg.mamba_d_state, cfg.mamba_d_conv, _dt_rank(cfg)
    a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (dc, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_linear(ks[2], di, dr + 2 * ds, dtype=dtype),
        "dt_proj": init_linear(ks[3], dr, di, bias=True, dtype=dtype),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ks[4], di, d, dtype=dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di = cfg.d_model * cfg.mamba_expand
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.mamba_d_state), dtype),
    }


def _causal_conv(x, w, b, prev):
    """Depthwise causal conv1d.  x: (B, L, di); prev: (B, dc-1, di)."""
    dc = w.shape[0]
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(dc)
    )
    return out + b[None, None, :], xp[:, -(dc - 1):, :]


def mamba(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    cache: Params | None = None,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    b, l, d = x.shape
    di = d * cfg.mamba_expand
    ds, dr = cfg.mamba_d_state, _dt_rank(cfg)
    spec = cfg.quant

    xz = apply_linear(params["in_proj"], x, spec)
    xin, z = jnp.split(xz, 2, axis=-1)

    prev = (
        cache["conv"]
        if cache is not None
        else jnp.zeros((b, cfg.mamba_d_conv - 1, di), xin.dtype)
    )
    xc, conv_state = _causal_conv(
        xin, params["conv_w"].astype(xin.dtype), params["conv_b"].astype(xin.dtype), prev
    )
    xc = jax.nn.silu(xc)

    proj = apply_linear(params["x_proj"], xc, spec).astype(jnp.float32)
    dt_in, bmat, cmat = jnp.split(proj, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(
        apply_linear(params["dt_proj"], dt_in.astype(x.dtype), spec).astype(jnp.float32)
    )  # (B, L, di)
    a = -jnp.exp(params["a_log"])  # (di, ds)

    h0 = (
        cache["h"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((b, di, ds), jnp.float32)
    )
    xf = xc.astype(jnp.float32)

    def chunk_step(h, args):
        dt_c, b_c, c_c, u_c = args  # (B, C, di) / (B, C, ds) / ...
        decay = jnp.exp(dt_c[..., None] * a[None, None])  # (B, C, di, ds)
        drive = (dt_c * u_c)[..., None] * b_c[:, :, None, :]  # (B, C, di, ds)
        # within-chunk associative scan over the time axis
        def combine(p, q):
            return (p[0] * q[0], p[1] * q[0] + q[1])
        dec_cum, drv_cum = jax.lax.associative_scan(
            combine, (decay, drive), axis=1
        )
        h_t = dec_cum * h[:, None] + drv_cum  # (B, C, di, ds)
        y = jnp.einsum("bcds,bcs->bcd", h_t, c_c)
        return h_t[:, -1], y

    if valid is not None:
        # sequential masked prefill: one chunk_step per token (cl=1), carry
        # gated so padding tails never advance the state (see module docstring)
        def tok_step(h, args):
            dt_t, b_t, c_t, u_t, ok = args
            h_new, y = chunk_step(h, (dt_t, b_t, c_t, u_t))
            return jnp.where(ok[:, None, None], h_new, h), y

        per_tok = lambda v: v.reshape(b, l, 1, v.shape[-1]).swapaxes(0, 1)
        h_fin, ys = jax.lax.scan(
            tok_step,
            h0,
            (per_tok(dt), per_tok(bmat), per_tok(cmat), per_tok(xf),
             valid.swapaxes(0, 1)),
        )
        y = ys.swapaxes(0, 1).reshape(b, l, di)
        # conv window after the last *valid* token (pure gather — bit-exact):
        # xp = [prev ++ xin]; after n valid tokens the window is xp[n : n+dc-1]
        dc = cfg.mamba_d_conv
        xp = jnp.concatenate([prev, xin], axis=1)
        n_valid = jnp.sum(valid, axis=1, dtype=jnp.int32)
        idx = n_valid[:, None] + jnp.arange(dc - 1, dtype=jnp.int32)[None]
        conv_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    else:
        n_chunks = max(1, l // CHUNK)
        cl = l // n_chunks
        assert cl * n_chunks == l, (l, CHUNK)
        resh = lambda v: v.reshape(b, n_chunks, cl, v.shape[-1]).swapaxes(0, 1)
        h_fin, ys = jax.lax.scan(
            chunk_step, h0, (resh(dt), resh(bmat), resh(cmat), resh(xf))
        )
        y = ys.swapaxes(0, 1).reshape(b, l, di)
    y = y + xf * params["d_skip"][None, None, :]
    out = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = apply_linear(params["out_proj"], out, spec)
    new_cache = (
        {"conv": conv_state.astype(prev.dtype), "h": h_fin.astype(h0.dtype)}
        if cache is not None
        else None
    )
    return out, new_cache


# ---- mLSTM (matrix-memory LSTM, chunkwise) ---------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    return {
        "wq": init_linear(ks[0], d, d, dtype=dtype),
        "wk": init_linear(ks[1], d, d, dtype=dtype),
        "wv": init_linear(ks[2], d, d, dtype=dtype),
        "wi": init_linear(ks[3], d, cfg.n_heads, bias=True, dtype=dtype),
        "wf": init_linear(ks[4], d, cfg.n_heads, bias=True, dtype=dtype),
        "wo": init_linear(ks[5], d, d, dtype=dtype),
        "norm": init_rmsnorm(d, dtype),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    hd = cfg.d_model // cfg.n_heads
    return {
        "c": jnp.zeros((batch, cfg.n_heads, hd, hd), dtype),
        "n": jnp.zeros((batch, cfg.n_heads, hd), dtype),
        "m": jnp.zeros((batch, cfg.n_heads), dtype),
    }


def mlstm(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    cache: Params | None = None,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    """Chunkwise stabilized mLSTM: C_t = f C_{t-1} + i v kᵀ; y = Cq/max(n·q,1)."""
    b, l, d = x.shape
    h = cfg.n_heads
    hd = d // h
    spec = cfg.quant

    def heads(v):
        return v.reshape(b, l, h, hd).transpose(0, 2, 1, 3)  # (B, H, L, hd)

    q = heads(apply_linear(params["wq"], x, spec)).astype(jnp.float32) * hd**-0.5
    k = heads(apply_linear(params["wk"], x, spec)).astype(jnp.float32) * hd**-0.5
    v = heads(apply_linear(params["wv"], x, spec)).astype(jnp.float32)
    ig = apply_linear(params["wi"], x, spec).astype(jnp.float32).transpose(0, 2, 1)
    fg = apply_linear(params["wf"], x, spec).astype(jnp.float32).transpose(0, 2, 1)
    logf = -jax.nn.softplus(-fg)  # log sigmoid(f̃)  (B, H, L)

    if cache is not None:
        c0 = cache["c"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
    else:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -30.0, jnp.float32)

    def chunk_step(carry, args):
        c, n, m = carry
        q_c, k_c, v_c, i_c, lf_c = args  # (B,H,C,·)
        cl = q_c.shape[2]
        csum = jnp.cumsum(lf_c, axis=-1)  # Σ_{t<=j} log f_t  (B,H,C)
        total = csum[..., -1]
        # per-position stabilizer: m_j = g_j + csum_j with
        # g_j = max(m_carry, cummax_{t<=j}(i_t - csum_t)); every exponent
        # used below is then <= 0 (xLSTM stabilization, chunkwise form).
        g = jnp.maximum(
            m[..., None], jax.lax.cummax(i_c - csum, axis=i_c.ndim - 1)
        )  # (B,H,C)
        # inter-chunk: carried state contribution at each position
        dec_q = jnp.exp(m[..., None] - g)  # (B,H,C)  = exp(csum+m-m_pos)
        y_inter = jnp.einsum("bhcd,bhde->bhce", q_c, c) * dec_q[..., None]
        n_inter = jnp.einsum("bhcd,bhd->bhc", q_c, n) * dec_q
        # intra-chunk: masked decay-weighted attention term
        gates = (i_c - csum)[:, :, None, :] - g[..., None]  # (B,H,row,col)
        mask = jnp.tril(jnp.ones((cl, cl), bool))
        w_att = jnp.where(mask[None, None], jnp.exp(gates), 0.0)
        scores = jnp.einsum("bhcd,bhed->bhce", q_c, k_c) * w_att
        y_intra = jnp.einsum("bhce,bhed->bhcd", scores, v_c)
        n_intra = jnp.sum(scores, axis=-1)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-g - csum))
        y = (y_inter + y_intra) / denom[..., None]
        # carry update for the next chunk (stabilizer m_last = g_last+total)
        g_last = g[..., -1]
        dec_c = jnp.exp(m - g_last)
        add_w = jnp.exp(i_c - csum - g_last[..., None])
        c_new = c * dec_c[..., None, None] + jnp.einsum(
            "bhc,bhcd,bhce->bhde", add_w, k_c, v_c
        )
        n_upd = n * dec_c[..., None] + jnp.einsum("bhc,bhcd->bhd", add_w, k_c)
        return (c_new, n_upd, g_last + total), y

    if valid is not None:
        # sequential masked prefill: chunk_step at cl=1 per token, carry gated
        # per row (see module docstring for the bit-for-bit invariant)
        def tok_step(carry, args):
            q_t, k_t, v_t, i_t, lf_t, ok = args
            new_carry, y = chunk_step(carry, (q_t, k_t, v_t, i_t, lf_t))
            gated = tuple(
                jnp.where(ok.reshape((b,) + (1,) * (nw.ndim - 1)), nw, old)
                for nw, old in zip(new_carry, carry)
            )
            return gated, y

        per_tok = lambda t: t.reshape(b, h, l, 1, *t.shape[3:]).transpose(
            2, 0, 1, 3, *range(4, t.ndim + 1)
        )
        q_s, k_s, v_s = (per_tok(t) for t in (q, k, v))
        i_s = ig.reshape(b, h, l, 1).transpose(2, 0, 1, 3)
        f_s = logf.reshape(b, h, l, 1).transpose(2, 0, 1, 3)
        (c_f, n_f, m_f), ys = jax.lax.scan(
            tok_step, (c0, n0, m0), (q_s, k_s, v_s, i_s, f_s, valid.swapaxes(0, 1))
        )
    else:
        n_chunks = max(1, l // CHUNK)
        cl = l // n_chunks
        assert cl * n_chunks == l
        resh = lambda t: t.reshape(b, h, n_chunks, cl, *t.shape[3:]).transpose(
            2, 0, 1, 3, *range(4, t.ndim + 1)
        )
        q_s, k_s, v_s = (resh(t) for t in (q, k, v))
        i_s = ig.reshape(b, h, n_chunks, cl).transpose(2, 0, 1, 3)
        f_s = logf.reshape(b, h, n_chunks, cl).transpose(2, 0, 1, 3)
        (c_f, n_f, m_f), ys = jax.lax.scan(chunk_step, (c0, n0, m0), (q_s, k_s, v_s, i_s, f_s))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, l, hd).transpose(0, 2, 1, 3)
    y = y.reshape(b, l, d).astype(x.dtype)
    out = apply_linear(params["wo"], rmsnorm(params["norm"], y), spec)
    new_cache = (
        {"c": c_f.astype(cache["c"].dtype), "n": n_f.astype(cache["n"].dtype), "m": m_f.astype(cache["m"].dtype)}
        if cache is not None
        else None
    )
    return out, new_cache


# ---- sLSTM (scalar-memory LSTM, sequential) --------------------------------


def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    return {
        "wz": init_linear(ks[0], d, d, bias=True, dtype=dtype),
        "wi": init_linear(ks[1], d, d, bias=True, dtype=dtype),
        "wf": init_linear(ks[2], d, d, bias=True, dtype=dtype),
        "wo_gate": init_linear(ks[3], d, d, bias=True, dtype=dtype),
        "wo": init_linear(ks[4], d, d, dtype=dtype),
        "norm": init_rmsnorm(d, dtype),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, d), -30.0, dtype),
    }


def slstm(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    cache: Params | None = None,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    b, l, d = x.shape
    spec = cfg.quant
    z = jnp.tanh(apply_linear(params["wz"], x, spec)).astype(jnp.float32)
    ig = apply_linear(params["wi"], x, spec).astype(jnp.float32)
    fg = apply_linear(params["wf"], x, spec).astype(jnp.float32)
    og = jax.nn.sigmoid(apply_linear(params["wo_gate"], x, spec)).astype(jnp.float32)

    if cache is not None:
        c0, n0, m0 = (cache[k].astype(jnp.float32) for k in ("c", "n", "m"))
    else:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, d), -30.0, jnp.float32)

    def step(carry, args):
        c, n, m = carry
        z_t, i_t, f_t, o_t = args
        logf = -jax.nn.softplus(-f_t)  # exp-gate via log sigmoid (stabilized)
        m_new = jnp.maximum(logf + m, i_t)
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z_t
        n_new = f_s * n + i_s
        h = o_t * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new), h

    xs = tuple(t.swapaxes(0, 1) for t in (z, ig, fg, og))  # (L, B, d)
    if valid is not None:
        # already sequential — just gate the carry on the per-row prefix mask
        def masked_step(carry, args):
            new_carry, hh = step(carry, args[:4])
            ok = args[4][:, None]
            gated = tuple(jnp.where(ok, nw, old) for nw, old in zip(new_carry, carry))
            return gated, hh

        (c_f, n_f, m_f), hs = jax.lax.scan(
            masked_step, (c0, n0, m0), xs + (valid.swapaxes(0, 1),)
        )
    else:
        (c_f, n_f, m_f), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    y = hs.swapaxes(0, 1).astype(x.dtype)
    out = apply_linear(params["wo"], rmsnorm(params["norm"], y), spec)
    new_cache = (
        {"c": c_f.astype(cache["c"].dtype), "n": n_f.astype(cache["n"].dtype), "m": m_f.astype(cache["m"].dtype)}
        if cache is not None
        else None
    )
    return out, new_cache
