"""ModelConfig — one dataclass describes every assigned architecture family."""

from __future__ import annotations

import dataclasses

from ..core.packed_linear import LinearSpec

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # SWA (h2o-danube); None = full attention
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_variant: str = "swiglu"  # swiglu (3-matrix) | gelu (2-matrix)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # hybrid (jamba): one attention layer per `attn_every` layers, rest Mamba
    attn_every: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # xlstm: one sLSTM per `slstm_every` layers, rest mLSTM
    slstm_every: int = 0

    # encoder-decoder (whisper): encoder depth + fixed source length
    n_encoder_layers: int = 0
    encoder_len: int = 1500

    # vlm (llava): stub patch embeddings prepended to the sequence
    n_patches: int = 0

    # compilation / memory policy
    scan_layers: bool = True
    remat: str = "dots"  # none | dots | full
    # flash-style online-softmax attention chunk (0 = off; train/prefill
    # only).  Off by default so baselines measure the naive S² attention;
    # the optimized configs flip it (EXPERIMENTS.md §Perf iteration 4).
    attention_chunk: int = 0
    dtype: str = "bfloat16"
    quant: LinearSpec = LinearSpec()

    # ---- derived -------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        """Layers per scan group (identical structure within a group)."""
        if self.family == "hybrid" and self.attn_every:
            return self.attn_every
        if self.family == "ssm" and self.slstm_every:
            return self.slstm_every
        return 1

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (
            self.n_layers,
            self.group_size,
        )
        return self.n_layers // self.group_size

    # Exact parameter counts are computed from the eval_shape'd param tree in
    # ``repro.launch.dryrun`` (MoE active share derived from expert leaves).
