"""Unified LM covering all assigned families (dense / moe / ssm / hybrid /
encdec / vlm).

Layers are organized into *scan groups*: every group has identical pytree
structure, group params are stacked along a leading ``n_groups`` axis, and
the forward pass is one ``lax.scan`` over that axis.  This keeps the lowered
HLO size O(1) in depth — essential for compiling 80-layer models on the
1-core dry-run host — and gives the remat boundary (one group).

Family → group structure:
  dense   1 layer:   attn + SwiGLU (or GELU) MLP
  moe     1 layer:   attn + top-k MoE FFN
  ssm     ``slstm_every`` layers: 1 sLSTM + (g-1) mLSTM blocks (no outer FFN)
  hybrid  ``attn_every`` layers (jamba): attention at the middle slot, Mamba
          elsewhere; MoE FFN on odd slots, dense MLP on even slots
  encdec  decoder group: self-attn + cross-attn + GELU MLP (encoder separate)
  vlm     dense backbone; stub patch embeddings are prepended to the sequence
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..core.packed_linear import apply_linear, init_linear
from ..runtime.act_sharding import constrain, constrain_group_params
from .config import ModelConfig
from .layers import (
    Params,
    attention,
    gelu_mlp,
    init_attention,
    init_gelu_mlp,
    init_kv_cache,
    init_mlp,
    init_paged_kv_cache,
    init_rmsnorm,
    mlp,
    rmsnorm,
)
from .moe import init_moe, moe_ffn
from .ssm import (
    init_mamba,
    init_mamba_cache,
    init_mlstm,
    init_mlstm_cache,
    init_slstm,
    init_slstm_cache,
    mamba,
    mlstm,
    slstm,
)

__all__ = [
    "init_params", "forward", "encode", "init_cache", "init_paged_cache",
    "Model",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_group(key, cfg: ModelConfig, dtype, cross_attn: bool = False) -> Params:
    fam = cfg.family
    d = cfg.d_model
    if fam in ("dense", "vlm") or (fam == "encdec" and not cross_attn):
        ks = jax.random.split(key, 2)
        make_mlp = (
            init_gelu_mlp
            if (fam == "encdec" or cfg.mlp_variant == "gelu")
            else init_mlp
        )
        return {
            "ln1": init_rmsnorm(d, dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "ln2": init_rmsnorm(d, dtype),
            "mlp": make_mlp(ks[1], cfg, dtype),
        }
    if fam == "encdec":  # decoder group
        ks = jax.random.split(key, 3)
        return {
            "ln1": init_rmsnorm(d, dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "ln_x": init_rmsnorm(d, dtype),
            "xattn": init_attention(ks[1], cfg, dtype),
            "ln2": init_rmsnorm(d, dtype),
            "mlp": init_gelu_mlp(ks[2], cfg, dtype),
        }
    if fam == "moe":
        ks = jax.random.split(key, 2)
        return {
            "ln1": init_rmsnorm(d, dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "ln2": init_rmsnorm(d, dtype),
            "moe": init_moe(ks[1], cfg, dtype),
        }
    if fam == "ssm":
        g = cfg.group_size
        ks = jax.random.split(key, g)
        ml = [init_mlstm(k, cfg, dtype) for k in ks[1:]]
        return {
            "ln_s": init_rmsnorm(d, dtype),
            "slstm": init_slstm(ks[0], cfg, dtype),
            "ln_m": {"scale": jnp.ones((g - 1, d), dtype)},
            "mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *ml),
        }
    if fam == "hybrid":
        g = cfg.attn_every
        ks = jax.random.split(key, 2 * g + 2)
        n_mamba = g - 1
        n_moe = g // 2
        n_mlp = g - n_moe
        mam = [init_mamba(ks[i], cfg, dtype) for i in range(n_mamba)]
        moes = [init_moe(ks[n_mamba + i], cfg, dtype) for i in range(n_moe)]
        mlps = [init_mlp(ks[n_mamba + n_moe + i], cfg, dtype) for i in range(n_mlp)]
        stack = lambda xs: jax.tree.map(lambda *t: jnp.stack(t), *xs)
        return {
            "ln_mix": {"scale": jnp.ones((g, d), dtype)},
            "ln_ffn": {"scale": jnp.ones((g, d), dtype)},
            "attn": init_attention(ks[-1], cfg, dtype),
            "mamba": stack(mam),
            "moe": stack(moes),
            "mlp": stack(mlps),
        }
    raise ValueError(f"unknown family {fam}")


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 6)
    d, v = cfg.d_model, cfg.vocab_size
    group_keys = jax.random.split(keys[0], cfg.n_groups)
    groups = jax.vmap(
        lambda k: _init_group(k, cfg, dtype, cross_attn=cfg.family == "encdec")
    )(group_keys)
    params: Params = {
        "embed": {"w": jax.random.normal(keys[1], (v, d), dtype) * 0.02},
        "groups": groups,
        "final_norm": init_rmsnorm(d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[2], d, v, dtype=dtype)
    if cfg.family == "encdec":
        enc_cfg = cfg  # same width; encoder groups are plain attn+mlp
        ekeys = jax.random.split(keys[3], cfg.n_encoder_layers)
        params["encoder"] = {
            "groups": jax.vmap(
                lambda k: _init_group(k, enc_cfg, dtype, cross_attn=False)
            )(ekeys),
            "final_norm": init_rmsnorm(d, dtype),
        }
    if cfg.family == "vlm":
        params["patch_proj"] = init_linear(keys[4], d, d, dtype=dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Stacked (n_groups, ...) decode cache matching the scan layout.

    ``dtype=None`` derives the KV dtype from the model's compute dtype, so a
    float32 model gets a float32 cache (bit-exact cached decode) while bf16
    models keep the bandwidth-saving bf16 cache.
    """
    if dtype is None:
        dtype = _dtype(cfg)

    def one_group():
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            return {"attn": init_kv_cache(cfg, batch, max_len, dtype)}
        if fam == "encdec":
            return {"attn": init_kv_cache(cfg, batch, max_len, dtype)}
        if fam == "ssm":
            g = cfg.group_size
            ml = [init_mlstm_cache(cfg, batch, jnp.float32) for _ in range(g - 1)]
            return {
                "slstm": init_slstm_cache(cfg, batch, jnp.float32),
                "mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *ml),
            }
        if fam == "hybrid":
            g = cfg.attn_every
            mam = [init_mamba_cache(cfg, batch, jnp.float32) for _ in range(g - 1)]
            return {
                "attn": init_kv_cache(cfg, batch, max_len, dtype),
                "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *mam),
            }
        raise ValueError(fam)

    one = one_group()
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape).copy(), one
    )


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=None, batch: int | None = None):
    """Stacked (n_groups, ...) paged decode cache: per-layer physical page
    pools written/read through a per-lane page table (the continuous
    serving engine's cache — see ``serving.paged_cache``).

    Full-attention KV pages through the table.  Recurrent state
    (ssm / hybrid mamba) has no positional layout to page — it is O(1) per
    lane — so it rides along as ordinary per-lane state arrays with a
    ``batch`` (= n_lanes) leading axis, exactly the ``init_cache`` layout;
    hybrid caches mix both kinds of leaf in one tree.  Sliding-window KV
    pages the ring buffer itself: page tables address ring slots
    ``pos % window`` rather than absolute positions, so the pool per lane
    is bounded by the window.
    """
    if dtype is None:
        dtype = _dtype(cfg)
    fam = cfg.family
    if fam in ("ssm", "hybrid") and batch is None:
        raise ValueError(
            f"family {fam!r} keeps per-lane recurrent state in its paged "
            "cache: pass batch=<n_lanes>"
        )
    if fam == "ssm":
        # no KV anywhere: the "paged" cache is pure per-lane state
        return init_cache(cfg, batch, 1, dtype)
    if fam == "hybrid":
        g = cfg.attn_every
        mam = [init_mamba_cache(cfg, batch, jnp.float32) for _ in range(g - 1)]
        one = {
            "attn": init_paged_kv_cache(cfg, n_pages, page_size, dtype),
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *mam),
        }
    else:
        one = {"attn": init_paged_kv_cache(cfg, n_pages, page_size, dtype)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape).copy(), one
    )


# ---------------------------------------------------------------------------
# group apply
# ---------------------------------------------------------------------------


def _apply_group(
    gp: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Params | None,
    encoder_out: jax.Array | None,
    causal: bool = True,
    page_table: jax.Array | None = None,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, Params | None, jax.Array]:
    """Returns (x, new_cache, aux_loss).

    ``valid`` (B, S) bool is the serving engines' per-row prefix mask:
    recurrent mixers gate their carried state on it (so a chunked prefill
    advances each lane's state by exactly its valid tokens — see
    ``models.ssm``) and MoE dispatch drops invalid tokens from the
    capacity competition.  ``None`` (training / full-batch eval) keeps the
    chunked/batched fast paths.
    """
    fam = cfg.family
    spec = cfg.quant
    aux = jnp.zeros((), jnp.float32)
    # every residual join is pinned to the (dp, None, None) layout in compute
    # dtype so deferred row-parallel psums/gathers move bf16, not f32
    # (EXPERIMENTS.md §Perf iteration 2)
    add = lambda a, b: constrain(a + b, "residual")

    if fam in ("dense", "vlm") or (fam == "encdec" and encoder_out is None and cache is None and not causal):
        h, new_kv = attention(
            gp["attn"], rmsnorm(gp["ln1"], x, cfg.norm_eps), cfg, positions,
            cache=None if cache is None else cache["attn"], causal=causal,
            page_table=page_table,
        )
        x = add(x, h)
        x = add(x, mlp(gp["mlp"], rmsnorm(gp["ln2"], x, cfg.norm_eps), spec))
        return x, None if new_kv is None else {"attn": new_kv}, aux

    if fam == "moe":
        h, new_kv = attention(
            gp["attn"], rmsnorm(gp["ln1"], x, cfg.norm_eps), cfg, positions,
            cache=None if cache is None else cache["attn"],
            page_table=page_table,
        )
        x = add(x, h)
        y, aux = moe_ffn(
            gp["moe"], rmsnorm(gp["ln2"], x, cfg.norm_eps), cfg, spec,
            valid=valid,
        )
        return add(x, y), None if new_kv is None else {"attn": new_kv}, aux

    if fam == "encdec":  # decoder group
        h, new_kv = attention(
            gp["attn"], rmsnorm(gp["ln1"], x, cfg.norm_eps), cfg, positions,
            cache=None if cache is None else cache["attn"],
            page_table=page_table,
        )
        x = add(x, h)
        h, _ = attention(
            gp["xattn"], rmsnorm(gp["ln_x"], x, cfg.norm_eps), cfg, positions,
            causal=False, kv_x=encoder_out,
        )
        x = add(x, h)
        x = add(x, gelu_mlp(gp["mlp"], rmsnorm(gp["ln2"], x, cfg.norm_eps), spec))
        return x, None if new_kv is None else {"attn": new_kv}, aux

    if fam == "ssm":
        g = cfg.group_size
        h, new_s = slstm(
            gp["slstm"], rmsnorm(gp["ln_s"], x, cfg.norm_eps), cfg,
            cache=None if cache is None else cache["slstm"], valid=valid,
        )
        x = add(x, h)
        new_ml = []
        for i in range(g - 1):
            sub = jax.tree.map(lambda t: t[i], gp["mlstm"])
            c_i = None if cache is None else jax.tree.map(lambda t: t[i], cache["mlstm"])
            h, nc = mlstm(
                sub, rmsnorm({"scale": gp["ln_m"]["scale"][i]}, x, cfg.norm_eps),
                cfg, cache=c_i, valid=valid,
            )
            x = add(x, h)
            new_ml.append(nc)
        new_cache = None
        if cache is not None:
            new_cache = {
                "slstm": new_s,
                "mlstm": jax.tree.map(lambda *t: jnp.stack(t), *new_ml),
            }
        return x, new_cache, aux

    if fam == "hybrid":
        g = cfg.attn_every
        attn_slot = g // 2
        mamba_i = moe_i = mlp_i = 0
        new_mam = []
        new_kv = None
        for slot in range(g):
            ln_mix = {"scale": gp["ln_mix"]["scale"][slot]}
            ln_ffn = {"scale": gp["ln_ffn"]["scale"][slot]}
            if slot == attn_slot:
                h, new_kv = attention(
                    gp["attn"], rmsnorm(ln_mix, x, cfg.norm_eps), cfg, positions,
                    cache=None if cache is None else cache["attn"],
                    page_table=page_table,
                )
                x = add(x, h)
            else:
                sub = jax.tree.map(lambda t: t[mamba_i], gp["mamba"])
                c_i = (
                    None
                    if cache is None
                    else jax.tree.map(lambda t: t[mamba_i], cache["mamba"])
                )
                h, nc = mamba(
                    sub, rmsnorm(ln_mix, x, cfg.norm_eps), cfg, cache=c_i,
                    valid=valid,
                )
                x = add(x, h)
                new_mam.append(nc)
                mamba_i += 1
            if slot % 2 == 1 and cfg.n_experts:
                sub = jax.tree.map(lambda t: t[moe_i], gp["moe"])
                y, a = moe_ffn(
                    sub, rmsnorm(ln_ffn, x, cfg.norm_eps), cfg, spec,
                    valid=valid,
                )
                x = add(x, y)
                aux = aux + a
                moe_i += 1
            else:
                sub = jax.tree.map(lambda t: t[mlp_i], gp["mlp"])
                x = add(x, mlp(sub, rmsnorm(ln_ffn, x, cfg.norm_eps), spec))
                mlp_i += 1
        new_cache = None
        if cache is not None:
            new_cache = {
                "attn": new_kv,
                "mamba": jax.tree.map(lambda *t: jnp.stack(t), *new_mam),
            }
        return x, new_cache, aux

    raise ValueError(fam)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)  # "full": save nothing


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _scan_groups(
    groups, x, cfg, positions, cache, encoder_out, causal=True,
    page_table=None, valid=None,
):
    def body(carry, xs):
        gp, cache_g = xs
        gp = constrain_group_params(gp)
        y, new_c, aux = _apply_group(
            gp, constrain(carry, "residual"), cfg, positions, cache_g,
            encoder_out, causal, page_table=page_table, valid=valid,
        )
        return constrain(y, "residual"), (new_c, aux)

    body = _remat(body, cfg.remat)
    if cfg.scan_layers:
        x, (new_cache, auxes) = jax.lax.scan(body, x, (groups, cache))
        return x, new_cache, jnp.sum(auxes)
    n = jax.tree.leaves(groups)[0].shape[0]
    new_cs, aux_t = [], 0.0
    for i in range(n):
        gp = jax.tree.map(lambda t: t[i], groups)
        cg = None if cache is None else jax.tree.map(lambda t: t[i], cache)
        x, (nc, aux) = body(x, (gp, cg))
        new_cs.append(nc)
        aux_t = aux_t + aux
    new_cache = (
        None
        if cache is None
        else jax.tree.map(lambda *t: jnp.stack(t), *new_cs)
    )
    return x, new_cache, aux_t


def _sinusoidal(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
    angle = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, T, d)."""
    frames = frames.astype(_dtype(cfg))
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model)[None].astype(frames.dtype)
    positions = jnp.arange(frames.shape[1])[None]

    # encoder groups are plain bidirectional attn+mlp; reuse dense group path
    enc_cfg = cfg
    x, _, _ = _scan_groups(
        params["encoder"]["groups"], x, enc_cfg, positions, None, None, causal=False
    )
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    positions: jax.Array | None = None,
    cache: Params | None = None,
    encoder_out: jax.Array | None = None,
    patch_embeds: jax.Array | None = None,
    logits_dtype=jnp.float32,
    return_hidden: bool = False,
    page_table: jax.Array | None = None,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, Params | None, jax.Array]:
    """Token ids → logits.  Returns (logits, new_cache, aux_loss).

    decode: ``tokens`` is (B, 1) and ``cache`` holds the stacked KV/state.
    vlm: ``patch_embeds`` (B, P, d) is prepended to the embedded tokens.
    ``return_hidden`` skips the lm_head and returns the post-final-norm
    hidden states instead of logits — serving prefill projects only the
    last prompt position, not every position of every chunk.
    ``page_table`` (B, max_blocks) routes KV writes/reads through a paged
    cache (``init_paged_cache``) instead of per-lane dense windows.
    ``valid`` (B, S) bool marks which token slots are real (serving
    engines' per-row prefix mask): recurrent state advances only on valid
    tokens and MoE capacity ignores invalid ones.
    """
    x = params["embed"]["w"][tokens].astype(_dtype(cfg))
    if patch_embeds is not None:
        pe = apply_linear(params["patch_proj"], patch_embeds.astype(x.dtype), cfg.quant)
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    x, new_cache, aux = _scan_groups(
        params["groups"], x, cfg, positions, cache, encoder_out,
        page_table=page_table, valid=valid,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, new_cache, aux
    if cfg.tie_embeddings:
        logits = x.astype(logits_dtype) @ params["embed"]["w"].T.astype(logits_dtype)
    else:
        logits = apply_linear(params["lm_head"], x, cfg.quant).astype(logits_dtype)
    return logits, new_cache, aux


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


class Model:
    """Thin OO veneer over the functional API (used by examples/serving)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key, dtype=jnp.float32) -> Params:
        return init_params(key, self.cfg, dtype)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        return init_cache(self.cfg, batch, max_len, dtype)

    def __call__(self, params, tokens, **kw):
        return forward(params, self.cfg, tokens, **kw)
