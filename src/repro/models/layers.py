"""Shared transformer layers (pure JAX, functional, pytree params).

Every projection goes through ``PackedLinear`` so the paper's packed
low-precision arithmetic is selectable per-model via ``cfg.quant``.

KV caches:
  * full attention — cache shape (B, S_max, n_kv, hd), written at ``pos``.
  * sliding-window (SWA) — ring buffer of ``window`` slots written at
    ``pos % window``; decode attends over at most ``window`` keys, making
    long-context decode O(window) (sub-quadratic — DESIGN.md §5).
  * paged — physical pages (n_pages, page_size, n_kv, hd) shared by every
    lane; a per-lane ``page_table`` (B, max_blocks) maps logical block
    ``pos // page_size`` to its physical page.  Writes scatter through the
    table (OOB sentinel entries drop the write — the serving engine masks
    lanes by handing them an all-invalid table row), reads gather the
    lane's logical view back and attend with the same validity mask as the
    dense cache, so paged and dense decode are token-identical
    (``serving.paged_cache`` owns the allocation).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..core.packed_linear import LinearSpec, apply_linear, init_linear
from ..runtime.act_sharding import constrain
from .config import ModelConfig

Params = dict[str, Any]

NEG_INF = -1e9  # mask value safe in bf16


# ---- norms ---------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    # Variance is accumulated in f32 WITHOUT materializing an f32 copy of x
    # (a (B,S,D) f32 intermediate would double the residual-stream collective
    # bytes under TP — EXPERIMENTS.md §Perf iteration 2); the normalization
    # itself runs in the compute dtype.
    var = (
        jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
        / x.shape[-1]
    )
    scale = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * scale * params["scale"].astype(x.dtype)


# ---- rotary embeddings -----------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,).

    Angles are formed in f32 (huge positions at 500k context), but the
    rotation itself runs in the compute dtype: an f32 rotation would
    materialize f32 (B,S,H,hd) tensors whose gathers/cotangents dominate
    TP collective bytes (EXPERIMENTS.md §Perf iteration 2).
    """
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    if angles.ndim == 2:  # (S, hd/2) -> broadcast over batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---- attention -------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, dtype=dtype),
    }


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, s, kv, n_rep, hd)
    ).reshape(b, s, kv * n_rep, hd)


def attention(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Params | None = None,
    causal: bool = True,
    kv_x: jax.Array | None = None,
    page_table: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    """GQA attention. ``cache=None`` → full-sequence (train/prefill).

    ``kv_x`` switches to cross-attention (whisper decoder): K/V come from
    ``kv_x`` and neither causality nor cache updates apply to the source.
    A paged cache (``pages_k``/``pages_v`` leaves) needs ``page_table``
    (B, max_blocks) int32 mapping each lane's logical blocks to physical
    pages; entries == n_pages mark unallocated blocks / masked lanes.
    """
    b, s, _ = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    spec = cfg.quant

    if "wqkv" in params:
        # engine-build fused projection (packed_params.fuse_projection_weights):
        # one GEMV for q/k/v per decode step; per-output-channel quantization
        # makes the fused matmul bit-identical per column to the unfused one.
        # Cross-attention never fuses (q and k/v read different inputs).
        assert kv_x is None, "fused qkv is self-attention only"
        qkv = apply_linear(params["wqkv"], x, spec)
        qe = nh * hd
        q, k, v = jnp.split(qkv, (qe, qe + nkv * hd), axis=-1)
        q = constrain(_split_heads(q, nh, hd), "heads")
        k = constrain(_split_heads(k, nkv, hd), "heads")
        v = constrain(_split_heads(v, nkv, hd), "heads")
    else:
        q = constrain(_split_heads(apply_linear(params["wq"], x, spec), nh, hd), "heads")
        src = x if kv_x is None else kv_x
        k = constrain(_split_heads(apply_linear(params["wk"], src, spec), nkv, hd), "heads")
        v = constrain(_split_heads(apply_linear(params["wv"], src, spec), nkv, hd), "heads")

    if kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and "pages_k" in cache:
        # paged/block KV cache: scatter this call's K/V through the lane's
        # page table, gather the logical view back, attend with the dense
        # validity mask.  Unallocated blocks and masked lanes carry the
        # OOB sentinel (== n_pages): their writes are DROPPED (JAX OOB
        # scatter semantics) and their gathered junk is masked to NEG_INF,
        # whose exp underflows to exactly 0 — so paged attention is
        # bit-identical to the dense cache over the valid positions.
        assert page_table is not None, "paged cache needs a page_table"
        pages_k, pages_v = cache["pages_k"], cache["pages_v"]
        n_pages, ps = pages_k.shape[0], pages_k.shape[1]
        max_blocks = page_table.shape[1]
        if positions.ndim == 2:
            row_pos = positions[:, 0]
        else:
            row_pos = jnp.broadcast_to(positions.reshape(-1)[:1], (b,))
        pos = row_pos[:, None] + jnp.arange(s)[None]          # (B, S)
        if cfg.sliding_window:
            # ring-buffer pages: the logical slot wraps at the window, so a
            # lane's pool is bounded by ceil(window/page_size) pages.  The
            # serving engines keep chunk-1 prefill for sliding windows
            # (chunked prefill over a ring overwrites slots still needed by
            # earlier in-chunk queries), so s == 1 whenever wrapping can
            # occur.
            slot = pos % cfg.sliding_window
        else:
            slot = pos
        blk = slot // ps
        page = jnp.take_along_axis(
            page_table, jnp.clip(blk, 0, max_blocks - 1), axis=1
        )
        # positions past the logical window must not clamp into a live
        # block: force them to the drop sentinel
        page = jnp.where(blk < max_blocks, page, n_pages)
        off = slot % ps
        pages_k = pages_k.at[page, off].set(
            k.astype(pages_k.dtype), mode="drop"
        )
        pages_v = pages_v.at[page, off].set(
            v.astype(pages_v.dtype), mode="drop"
        )
        new_cache = {"pages_k": pages_k, "pages_v": pages_v}
        # gather the lane's logical view (invalid entries clamp to junk
        # pages — masked below exactly like unwritten dense positions)
        window = max_blocks * ps
        k = pages_k[page_table].reshape(b, window, nkv, hd)
        v = pages_v[page_table].reshape(b, window, nkv, hd)
        cache_positions = jnp.arange(window)
        qidx = jnp.arange(s)
        if cfg.sliding_window:
            # every written ring slot is in-window (dense ring branch
            # semantics); gathered slots past the ring are never written
            ring = cfg.sliding_window
            valid = (
                (cache_positions[None, None, :] <= slot[:, :, None])
                | (pos[:, :, None] >= ring)
            ) & (cache_positions[None, None, :] < ring)
        else:
            valid = (
                cache_positions[None, None, :]
                <= row_pos[:, None, None] + qidx[None, :, None]
            )
        mask = jnp.where(valid[:, None, :, :], 0.0, NEG_INF)
    elif cache is not None:
        # decode (s==1) or cached chunked prefill (s>1, full attention only):
        # write K/V at each row's own position, attend over the cache.  Rows
        # (serving slots) may sit at different depths, so writes and masks
        # are per-row (vmapped update slice).
        window = cache["k"].shape[1]
        if positions.ndim == 2:
            row_pos = positions[:, 0]
        else:
            row_pos = jnp.broadcast_to(positions.reshape(-1)[:1], (b,))
        slot = row_pos % window if cfg.sliding_window else row_pos
        upd = lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0))
        k_all = jax.vmap(upd)(cache["k"], k.astype(cache["k"].dtype), slot)
        v_all = jax.vmap(upd)(cache["v"], v.astype(cache["v"].dtype), slot)
        new_cache = {"k": k_all, "v": v_all}
        k, v = k_all, v_all
        cache_positions = jnp.arange(window)
        qidx = jnp.arange(s)
        if cfg.sliding_window:
            # ring buffer (decode): every slot written so far is in-window
            valid = (cache_positions[None, :] <= slot[:, None]) | (
                row_pos[:, None] >= window
            )
            valid = jnp.broadcast_to(valid[:, None, :], (b, s, window))
        else:
            valid = (
                cache_positions[None, None, :]
                <= row_pos[:, None, None] + qidx[None, :, None]
            )
        mask = jnp.where(valid[:, None, :, :], 0.0, NEG_INF)
    elif causal:
        ii = positions if positions.ndim == 2 else positions[None]
        qi = ii[:, :, None]
        ki = ii[:, None, :]
        ok = ki <= qi
        if cfg.sliding_window:
            ok &= ki > qi - cfg.sliding_window
        mask = jnp.where(ok[:, None, :, :], 0.0, NEG_INF)
    else:
        mask = None

    k = _repeat_kv(k, nh // nkv)
    v = _repeat_kv(v, nh // nkv)
    use_chunked = (
        cache is None
        and kv_x is None
        and causal
        and cfg.attention_chunk
        and s > cfg.attention_chunk
        and s % cfg.attention_chunk == 0
    )
    if use_chunked:
        out = _chunked_causal_attention(
            q, k, v, positions, cfg.attention_chunk, cfg.sliding_window
        )
    else:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * hd**-0.5
        if cache is not None:
            scores = constrain(scores, "scores_decode")
        if mask is not None:
            scores = scores + mask
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = out.reshape(b, s, nh * hd)
    return apply_linear(params["wo"], out, spec), new_cache


def _chunked_causal_attention(q, k, v, positions, chunk: int, window: int | None):
    """Online-softmax (flash-style) causal attention, O(S·chunk) memory.

    Scans KV chunks with running (max, denom, acc) — the S×S f32 score
    matrix is never materialized, which is what makes the 4k/32k train and
    prefill cells fit HBM (EXPERIMENTS.md §Perf iteration 4).  Positions
    must be the standard arange layout (asserted by the caller's shapes).
    """
    b, s, h, hd = q.shape
    scale = hd**-0.5
    n_chunks = s // chunk
    q_pos = jnp.arange(s)
    kc = k.reshape(b, n_chunks, chunk, h, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, h, hd).swapaxes(0, 1)

    def body(carry, xs):
        m, l, acc = carry  # (B,H,S), (B,H,S), (B,H,S,hd)
        k_i, v_i, idx = xs
        k_pos = idx * chunk + jnp.arange(chunk)
        scores = (
            jnp.einsum("bqhd,bkhd->bhqk", q, k_i).astype(jnp.float32) * scale
        )  # (B,H,S,chunk)
        ok = k_pos[None, :] <= q_pos[:, None]
        if window:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(ok[None, None], scores, NEG_INF)
        m_i = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m, m_i)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, h, s), NEG_INF, jnp.float32),
        jnp.zeros((b, h, s), jnp.float32),
        jnp.zeros((b, h, s, hd), jnp.float32),
    )
    # checkpoint: the backward pass recomputes the (B,H,S,chunk) score block
    # instead of storing one per chunk (flash-attention memory profile);
    # full unroll keeps XLA cost analysis exact (loop bodies count once).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), init, (kc, vc, jnp.arange(n_chunks)),
        unroll=n_chunks,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)  # (B,S,H,hd)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    window = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, window, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_cache(
    cfg: ModelConfig, n_pages: int, page_size: int, dtype=jnp.bfloat16
):
    """Physical page pool for one attention layer: every serving lane's
    K/V lives in fixed-size pages mapped through a per-lane page table
    (``serving.paged_cache.PageAllocator`` owns the mapping)."""
    shape = (n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    return {"pages_k": jnp.zeros(shape, dtype), "pages_v": jnp.zeros(shape, dtype)}


# ---- MLP -------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, dtype=jnp.float32, d_ff: int | None = None) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "up": init_linear(ks[0], d, f, dtype=dtype),
        "gate": init_linear(ks[1], d, f, dtype=dtype),
        "down": init_linear(ks[2], f, d, dtype=dtype),
    }


def mlp(params: Params, x: jax.Array, spec: LinearSpec) -> jax.Array:
    if "upgate" in params:
        # engine-build fused up|gate (packed_params.fuse_projection_weights):
        # one GEMV instead of two, bit-identical per output column
        ug = constrain(apply_linear(params["upgate"], x, spec), "hidden")
        up, gate = jnp.split(ug, 2, axis=-1)
        return apply_linear(params["down"], jax.nn.silu(gate) * up, spec)
    if "gate" not in params:  # 2-matrix GELU variant (whisper/starcoder)
        return gelu_mlp(params, x, spec)
    up = constrain(apply_linear(params["up"], x, spec), "hidden")
    gate = constrain(apply_linear(params["gate"], x, spec), "hidden")
    return apply_linear(params["down"], jax.nn.silu(gate) * up, spec)


def init_gelu_mlp(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    """Whisper/starcoder-style 2-matrix GELU MLP."""
    ks = jax.random.split(key, 2)
    return {
        "up": init_linear(ks[0], cfg.d_model, cfg.d_ff, dtype=dtype),
        "down": init_linear(ks[1], cfg.d_ff, cfg.d_model, dtype=dtype),
    }


def gelu_mlp(params: Params, x: jax.Array, spec: LinearSpec) -> jax.Array:
    hidden = constrain(apply_linear(params["up"], x, spec), "hidden")
    return apply_linear(params["down"], jax.nn.gelu(hidden), spec)
