"""``--arch`` registry: name → ModelConfig (full + smoke variants)."""

from __future__ import annotations

from .config import ModelConfig

__all__ = ["get_config", "list_archs", "FULL_CONFIGS", "SMOKE_CONFIGS"]


def _load():
    from .. import configs as _configs

    full = {m.FULL.name: m.FULL for m in _configs.ALL.values()}
    smoke = {m.FULL.name: m.SMOKE for m in _configs.ALL.values()}
    return full, smoke


FULL_CONFIGS, SMOKE_CONFIGS = _load()


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_CONFIGS if smoke else FULL_CONFIGS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(table)}")
    return table[name]


def list_archs() -> list[str]:
    return sorted(FULL_CONFIGS)
